"""Paper Fig 9: execution-time breakdown (compute / staging / other memory)
for FP and BP at different sizes and device counts.

The paper's "pinning" bin has no TPU/JAX analogue (DESIGN.md SS8); our
bins are compute (kernel + overlapped copies), staging (host->device
prefetch), and other_memory (final gather, frees)."""

from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax

from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel, plan_backward, plan_forward
from repro.core.streaming import (Timeline, stream_backward, stream_forward)


def run(sizes=(32, 64), device_counts=(1, 2), budget_mib=16.0):
    rows: List[Dict] = []
    avail = jax.local_device_count()
    mem = MemoryModel(device_bytes=int(budget_mib * 2 ** 20),
                      usable_fraction=1.0)
    for n in sizes:
        geo = ConeGeometry.nice(n)
        angles = circular_angles(n)
        rng = np.random.default_rng(0)
        vol = rng.standard_normal(geo.n_voxel).astype(np.float32)
        proj = rng.standard_normal((n,) + geo.n_detector).astype(np.float32)
        for nd in device_counts:
            if nd > avail:
                continue
            devs = jax.local_devices()[:nd]
            for op, runner, planner, data in (
                    ("fp", stream_forward, plan_forward, vol),
                    ("bp", stream_backward, plan_backward, proj)):
                plan = planner(geo, n, nd, mem)
                runner(data, geo, angles, plan, devices=devs)  # warm-up
                tl = Timeline()
                runner(data, geo, angles, plan, devices=devs, timeline=tl)
                fr = tl.fractions()
                rows.append({"op": op, "N": n, "n_dev": nd,
                             "compute": fr.get("compute", 0.0),
                             "staging": fr.get("staging", 0.0),
                             "other_memory": fr.get("other_memory", 0.0)})
    return rows


def main():
    rows = run()
    print("op,N,n_dev,compute,staging,other_memory")
    for r in rows:
        print(f"{r['op']},{r['N']},{r['n_dev']},{r['compute']:.3f},"
              f"{r['staging']:.3f},{r['other_memory']:.3f}")


if __name__ == "__main__":
    main()
