"""Pallas-kernel micro-bench: interpret-mode correctness deltas + jnp-path
wall time for the three CT hot-spot kernels and flash attention.

Interpret mode executes the kernel body in Python (no TPU), so the
*reported numbers are correctness deltas and XLA-path reference timings*,
not kernel speed -- kernel perf on hardware is covered by the roofline
analysis."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.geometry import ConeGeometry, circular_angles, \
    dominant_axis_mask
from repro.kernels import ref
from repro.kernels.bp_voxel import bp_voxel_pallas
from repro.kernels.fp_ray import fp_ray_pallas
from repro.kernels.tv_grad import tv_grad_pallas
from repro.kernels.flash_attention import flash_attention


def _t(fn):
    fn()
    t0 = time.monotonic()
    fn()
    return time.monotonic() - t0


def run(n: int = 32):
    geo = ConeGeometry.nice(n)
    a = circular_angles(8)
    ax = a[np.nonzero(dominant_axis_mask(a))[0]]
    vol = jax.random.normal(jax.random.PRNGKey(0), geo.n_voxel, jnp.float32)
    proj = jax.random.normal(jax.random.PRNGKey(1), (8,) + geo.n_detector)

    rows = []
    got = fp_ray_pallas(vol, geo, ax, slab_planes=8, interpret=True)
    want = ref.fp_ray_ref(vol, geo, ax)
    rows.append({"kernel": "fp_ray", "max_err": float(jnp.max(jnp.abs(
        got - want))), "ref_s": _t(lambda: jax.block_until_ready(
            ref.fp_ray_ref(vol, geo, ax)))})

    got = bp_voxel_pallas(proj, geo, a, z_block=8, angle_chunk=4,
                          interpret=True)
    want = ref.bp_voxel_ref(proj, geo, a)
    rows.append({"kernel": "bp_voxel", "max_err": float(jnp.max(jnp.abs(
        got - want))), "ref_s": _t(lambda: jax.block_until_ready(
            ref.bp_voxel_ref(proj, geo, a)))})

    got = tv_grad_pallas(vol, z_block=8, interpret=True)
    want = ref.tv_grad_ref(vol)
    rows.append({"kernel": "tv_grad", "max_err": float(jnp.max(jnp.abs(
        got - want))), "ref_s": _t(lambda: jax.block_until_ready(
            ref.tv_grad_ref(vol)))})

    q = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 256, 64))
    got = flash_attention(q, k, v, causal=True, block_q=128, block_kv=128,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    rows.append({"kernel": "flash_attention", "max_err": float(jnp.max(
        jnp.abs(got - want))), "ref_s": _t(lambda: jax.block_until_ready(
            ref.flash_attention_ref(q, k, v, causal=True)))})
    return rows


def main():
    rows = run()
    print("kernel,max_abs_err_vs_ref,ref_jnp_seconds")
    for r in rows:
        print(f"{r['kernel']},{r['max_err']:.2e},{r['ref_s']:.4f}")


if __name__ == "__main__":
    main()
