"""Paper SS4 napkin numbers: the largest N (N^3 volume, N^2 detector,
N angles) a single device of given memory can process per operator, under
the double-buffer budget -- reproduced from the splitting planner."""

from __future__ import annotations

from repro.core.splitting import MemoryModel, paper_size_limits


def run():
    rows = []
    for gib, label in ((11, "GTX 1080 Ti (paper)"), (16, "TPU v5e"),
                       (32, "TPU v5p-class")):
        lims = paper_size_limits(MemoryModel(device_bytes=gib * (1 << 30)),
                                 angle_chunk_fp=9)    # paper's N_angles=9
        rows.append({"device": label, "gib": gib, **lims})
    return rows


def main():
    rows = run()
    print("device,GiB,N_forward_max,N_backward_max")
    for r in rows:
        print(f"{r['device']},{r['gib']},{r['forward']},{r['backward']}")
    print("# paper SS4 reports N~17000 (FP) / N~8500 (BP) at 11 GiB")


if __name__ == "__main__":
    main()
