"""Ref-vs-Pallas operator throughput through the unified plan/backend stack.

Measures forward projection (``A``) and backprojection (``At``) wall time
for each kernel backend in each ``CTOperator`` execution mode — the same
plan routed onto different kernels — and reports throughput plus the
pallas/ref speedup, so the backend registry's claimed win is *measured*,
not asserted:

* ``plain``  — monolithic jitted operators (volume resident);
* ``stream`` — the paper's out-of-core executor under a budget that
  forces several slabs (the Pallas kernels running inside the
  out-of-core path is new with the backend registry);
* ``dist``   — shard_map over the local device mesh (skipped unless the
  host exposes >= 2 devices and ``--modes`` asks for it).

On CPU hosts the Pallas kernels run in *interpret mode*: numbers there
are correctness/parity checks and pipeline-overhead measurements, not
kernel speed (same caveat as ``bench_kernels.py``).  On a TPU host the
same command compiles the kernels with Mosaic and the speedup column is
real.  ``--smoke`` is the CI gate: tiny shapes, parity asserted, one
repeat.

Usage::

    PYTHONPATH=src python benchmarks/bench_operators.py [--n 32]
        [--angles 12] [--repeats 3] [--modes plain,stream] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np
import jax

from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.operator import CTOperator
from repro.core.plan import plan as plan_execution
from repro.core.splitting import MemoryModel

try:
    from benchmarks import schema
except ImportError:           # run as a script: benchmarks/ is sys.path[0]
    import schema

#: parity gates (pallas vs ref), loose enough for interpret-mode float32
RTOL, ATOL = 2e-4, 5e-3


def _time(fn, repeats: int) -> float:
    """Median wall seconds over ``repeats`` (after one warmup that also
    pays tracing/compilation)."""
    out = fn()
    np.asarray(out)                      # block: streams return numpy
    times = []
    for _ in range(repeats):
        t0 = time.monotonic()
        np.asarray(fn())
        times.append(time.monotonic() - t0)
    return float(np.median(times))


def _stream_memory(geo: ConeGeometry, n_angles: int) -> MemoryModel:
    """A budget that forces the planner to split: ~1/4 of the volume plus
    the projection double buffers."""
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    budget = (nz * ny * nx) + 8 * n_angles * nv * nu   # bytes/4 * 4
    return MemoryModel(device_bytes=budget, usable_fraction=1.0)


def run(n: int = 32, n_angles: int = 12, repeats: int = 3,
        modes=("plain", "stream"), check: bool = True):
    geo = ConeGeometry.nice(n)
    angles = circular_angles(n_angles)
    vol = np.asarray(jax.random.normal(jax.random.PRNGKey(0), geo.n_voxel),
                     np.float32)
    proj = np.asarray(jax.random.normal(
        jax.random.PRNGKey(1), (n_angles,) + geo.n_detector), np.float32)
    mvox = geo.n_voxel[0] * geo.n_voxel[1] * geo.n_voxel[2] * n_angles / 1e6

    rows = []
    for mode in modes:
        kwargs = {}
        if mode == "stream":
            kwargs["memory"] = _stream_memory(geo, n_angles)
            p = plan_execution(geo, n_angles, 1, kwargs["memory"])
            print(f"# stream {p.describe()}")
        elif mode == "dist":
            from repro.core.compat import make_mesh
            n_dev = jax.local_device_count()
            if n_dev < 2:
                print("# dist skipped: single-device host")
                continue
            kwargs["mesh"] = make_mesh((n_dev // 2, 2), ("data", "model"))
        outs = {}
        for backend in ("ref", "pallas"):
            op = CTOperator(geo, angles, mode=mode, backend=backend,
                            **kwargs)
            ctx = kwargs["mesh"] if mode == "dist" else None
            if ctx is not None:
                ctx.__enter__()
            try:
                t_fp = _time(lambda: op.A(vol), repeats)
                t_bp = _time(lambda: op.At(proj, weight="fdk"), repeats)
                # the matched-adjoint arm: ref times its jax.vjp adjoint,
                # pallas its native transpose-shaped scatter kernel — the
                # pair CGLS/FISTA actually iterate with
                t_at = _time(lambda: op.At(proj, weight="matched"), repeats)
                a_out = np.asarray(op.A(vol))
                at_out = np.asarray(op.At(proj, weight="matched"))
                outs[backend] = (a_out, np.asarray(op.At(proj,
                                                         weight="fdk")),
                                 at_out)
            finally:
                if ctx is not None:
                    ctx.__exit__(None, None, None)
            # adjoint defect of the matched pair on this (vol, proj) draw:
            # | <Ax,y> - <x,At y> | / max(|.|) — fp32-exact pairs sit ~1e-6
            lhs = float(np.vdot(a_out.astype(np.float64).ravel(),
                                proj.astype(np.float64).ravel()))
            rhs = float(np.vdot(vol.astype(np.float64).ravel(),
                                at_out.astype(np.float64).ravel()))
            defect = abs(lhs - rhs) / max(abs(lhs), abs(rhs), 1e-30)
            rows.append({"mode": mode, "backend": backend,
                         "fp_s": t_fp, "bp_s": t_bp,
                         "at_matched_s": t_at,
                         "pair_s": t_fp + t_at,
                         "adjoint_rel_defect": defect,
                         "fp_mvox_s": mvox / t_fp, "bp_mvox_s": mvox / t_bp})
        if check:
            for i, what in enumerate(("A", "At[fdk]", "At[matched]")):
                np.testing.assert_allclose(
                    outs["pallas"][i], outs["ref"][i], rtol=RTOL, atol=ATOL,
                    err_msg=f"{mode}/{what}: pallas disagrees with ref")
            for r in rows:
                if r["mode"] == mode:
                    assert r["adjoint_rel_defect"] < 1e-4, \
                        (f"{mode}/{r['backend']}: matched pair is not an "
                         f"adjoint (defect {r['adjoint_rel_defect']:.3g})")
            print(f"# {mode}: pallas == ref within tolerance "
                  f"(rtol={RTOL}, atol={ATOL}); matched adjoint defect "
                  "< 1e-4 on both backends")
    return rows


def run_autotune(n: int = 32, n_angles: int = 12, repeats: int = 3,
                 check: bool = True):
    """Autotuned-vs-heuristic block arm (pallas, plain mode).

    Times the pallas matched pair under the static divisor-or-pad
    heuristic and again under the measured autotuner, reporting both
    block configs.  The tuner's candidates are floored at the heuristic,
    so every tuned block must be >= its heuristic counterpart — asserted
    here so the floor guarantee is continuously bench-checked.
    """
    from repro.core.backend import get_backend
    from repro.kernels import autotune

    geo = ConeGeometry.nice(n)
    angles = circular_angles(n_angles)
    vol = np.asarray(jax.random.normal(jax.random.PRNGKey(0), geo.n_voxel),
                     np.float32)
    proj = np.asarray(jax.random.normal(
        jax.random.PRNGKey(1), (n_angles,) + geo.n_detector), np.float32)
    bk = get_backend("pallas")

    rows = []
    was_enabled = autotune.enabled()
    try:
        for arm, on in (("pallas_heuristic", False),
                        ("pallas_autotuned", True)):
            autotune.enable(on)
            blocks = bk.kernel_config(geo, planes=geo.n_voxel[0])
            op = CTOperator(geo, angles, backend="pallas")
            t_fp = _time(lambda: op.A(vol), repeats)
            t_at = _time(lambda: op.At(proj, weight="matched"), repeats)
            rows.append({"arm": arm, "blocks": blocks,
                         "fp_s": t_fp, "at_matched_s": t_at,
                         "pair_s": t_fp + t_at})
    finally:
        autotune.enable(True if was_enabled else None)
    if check:
        heur = rows[0]["blocks"]
        tuned = rows[1]["blocks"]
        for k, hv in heur.items():
            if k == "autotuned":
                continue
            assert tuned[k] >= hv, \
                f"autotuned {k}={tuned[k]} below heuristic {hv}"
        print(f"# autotune: tuned blocks >= heuristic on every axis "
              f"({ {k: v for k, v in tuned.items() if k != 'autotuned'} } "
              f"vs { {k: v for k, v in heur.items() if k != 'autotuned'} })")
    return rows


def report(rows) -> None:
    print("mode,backend,fp_seconds,bp_seconds,at_matched_s,pair_s,"
          "adjoint_defect,fp_Mvox/s,bp_Mvox/s")
    for r in rows:
        print(f"{r['mode']},{r['backend']},{r['fp_s']:.4f},{r['bp_s']:.4f},"
              f"{r['at_matched_s']:.4f},{r['pair_s']:.4f},"
              f"{r['adjoint_rel_defect']:.2e},"
              f"{r['fp_mvox_s']:.2f},{r['bp_mvox_s']:.2f}")
    by_mode = {}
    for r in rows:
        by_mode.setdefault(r["mode"], {})[r["backend"]] = r
    for mode, b in by_mode.items():
        if "ref" in b and "pallas" in b:
            print(f"# {mode}: pallas/ref speedup "
                  f"fp={b['ref']['fp_s'] / b['pallas']['fp_s']:.2f}x "
                  f"bp={b['ref']['bp_s'] / b['pallas']['bp_s']:.2f}x "
                  f"matched-pair="
                  f"{b['ref']['pair_s'] / b['pallas']['pair_s']:.2f}x"
                  + ("  (interpret mode: parity gate, not kernel speed)"
                     if jax.default_backend() != "tpu" else ""))


def report_autotune(rows) -> None:
    print("arm,fp_seconds,at_matched_s,pair_s,blocks")
    for r in rows:
        blocks = ";".join(f"{k}={v}" for k, v in sorted(r["blocks"].items())
                          if k != "autotuned")
        print(f"{r['arm']},{r['fp_s']:.4f},{r['at_matched_s']:.4f},"
              f"{r['pair_s']:.4f},{blocks}")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="ref-vs-pallas operator throughput per execution mode")
    ap.add_argument("--n", type=int, default=32, help="N^3 volume, N^2 det")
    ap.add_argument("--angles", type=int, default=12)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--modes", default="plain,stream",
                    help="comma list of plain,stream,dist")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: tiny shapes, 1 repeat, parity asserted")
    ap.add_argument("--json", default="", dest="json_out",
                    help="write the result rows (plus shape metadata) as "
                         "machine-readable JSON here ('-' for stdout)")
    ap.add_argument("--trace", default="",
                    help="enable tracing and write a Chrome-trace JSON of "
                         "the benchmark here (see docs/observability.md)")
    args = ap.parse_args(argv)
    if args.trace:
        from repro import obs
        obs.get_tracer().enable()
    if args.smoke:
        n, angles, repeats, modes = 16, 8, 1, ("plain", "stream")
    else:
        n, angles, repeats = args.n, args.angles, args.repeats
        modes = tuple(args.modes.split(","))
    rows = run(n=n, n_angles=angles, repeats=repeats, modes=modes)
    report(rows)
    at_rows = run_autotune(n=n, n_angles=angles, repeats=repeats)
    report_autotune(at_rows)
    if args.smoke:
        assert len(rows) == 4, "smoke expected plain+stream x ref+pallas"
        matched = [r for r in rows if r["adjoint_rel_defect"] < 1e-4]
        assert len(matched) == len(rows), "matched-pair arm missing/broken"
        assert len(at_rows) == 2, "autotune arm missing"
        print("SMOKE OK: ref-vs-pallas parity + matched-adjoint pair + "
              "autotune floor held in plain + stream modes")
    if args.json_out:
        params = {"n": n, "angles": angles, "repeats": repeats,
                  "modes": list(modes), "smoke": args.smoke,
                  "jax_backend": jax.default_backend()}
        metrics = []
        for r in rows:
            pre = f"{r['mode']}.{r['backend']}"
            metrics.append(schema.metric(f"{pre}.fp_s", r["fp_s"], "s",
                                         "lower", repeats))
            metrics.append(schema.metric(f"{pre}.bp_s", r["bp_s"], "s",
                                         "lower", repeats))
            metrics.append(schema.metric(f"{pre}.at_matched_s",
                                         r["at_matched_s"], "s",
                                         "lower", repeats))
            metrics.append(schema.metric(f"{pre}.adjoint_rel_defect",
                                         r["adjoint_rel_defect"], "rel",
                                         "lower", repeats))
            metrics.append(schema.metric(f"{pre}.fp_mvox_s",
                                         r["fp_mvox_s"], "Mvox/s",
                                         "higher", repeats))
        by_mode = {}
        for r in rows:
            by_mode.setdefault(r["mode"], {})[r["backend"]] = r
        for mode, b in by_mode.items():
            if "ref" in b and "pallas" in b:
                metrics.append(schema.metric(
                    f"{mode}.matched_pair_speedup",
                    b["ref"]["pair_s"] / b["pallas"]["pair_s"], "x",
                    "higher", repeats))
        for r in at_rows:
            metrics.append(schema.metric(f"autotune.{r['arm']}.pair_s",
                                         r["pair_s"], "s", "lower",
                                         repeats))
        doc = schema.envelope("operators", config=params, metrics=metrics,
                              smoke=args.smoke, params=params, rows=rows,
                              autotune_rows=at_rows)
        if args.json_out == "-":
            json.dump(doc, sys.stdout, indent=2)
            print()
        else:
            with open(args.json_out, "w") as f:
                json.dump(doc, f, indent=2)
            print(f"# json -> {args.json_out}")
    if args.trace:
        from repro import obs
        obs.write_chrome_trace(args.trace)
        print(f"# chrome trace -> {args.trace} "
              f"(load at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
