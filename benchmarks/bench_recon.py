"""Paper SS3.2: end-to-end large reconstructions (CGLS coffee-bean /
OS-SART ichthyosaur stand-ins) on the streaming out-of-core backend.

The measured scans are not redistributable; the Shepp-Logan phantom at a
size that exceeds the simulated per-device budget reproduces the paper's
point: iterative reconstruction of a volume that does NOT fit in device
memory, at quality matching the in-memory reference."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.launch.recon import reconstruct


def run(n: int = 48, angles: int = 64, iters: int = 5,
        budget_kib: int = 256):
    rows: List[Dict] = []
    for alg in ("cgls", "ossart"):
        for mode, budget in (("plain", 0), ("stream", budget_kib * 1024)):
            t0 = time.monotonic()
            _, rel = reconstruct(alg, n=n, n_angles=angles,
                                 iters=iters if alg == "cgls" else 2,
                                 mode=mode, device_bytes=budget,
                                 verbose=False)
            rows.append({"alg": alg, "mode": mode, "N": n,
                         "rel_err": rel,
                         "seconds": time.monotonic() - t0})
    return rows


def main():
    rows = run()
    print("alg,mode,N,rel_err,seconds")
    for r in rows:
        print(f"{r['alg']},{r['mode']},{r['N']},{r['rel_err']:.4f},"
              f"{r['seconds']:.2f}")
    # the paper's claim: out-of-core quality == in-memory quality
    by = {(r["alg"], r["mode"]): r["rel_err"] for r in rows}
    for alg in ("cgls", "ossart"):
        d = abs(by[(alg, "stream")] - by[(alg, "plain")])
        print(f"# {alg}: |stream - plain| rel_err delta = {d:.5f}")


if __name__ == "__main__":
    main()
