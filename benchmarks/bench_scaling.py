"""Paper Fig 7/8: FP/BP wall time and multi-device speedup vs problem size.

N^3 volumes, N^2 detectors, N angles, on 1/2/4 emulated devices (CPU host
devices stand in for the paper's GTX 1080 Ti's; the *scaling shape* -- ratio
to 1-device time -- is the reproduced quantity, absolute times are
hardware-specific).  Timing includes host<->device transfer, as in the
paper.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel, plan_backward, plan_forward
from repro.core.streaming import stream_backward, stream_forward


def _time(fn, repeats=2):
    fn()                                   # warm-up / compile
    ts = []
    for _ in range(repeats):
        t0 = time.monotonic()
        fn()
        ts.append(time.monotonic() - t0)
    return min(ts)


def run(sizes=(32, 64, 96), device_counts=(1, 2, 4), budget_mib=64.0):
    """Returns rows: (op, N, n_dev, seconds, pct_vs_1dev)."""
    rows: List[Dict] = []
    avail = jax.local_device_count()
    mem = MemoryModel(device_bytes=int(budget_mib * 2 ** 20),
                      usable_fraction=1.0)
    for n in sizes:
        geo = ConeGeometry.nice(n)
        angles = circular_angles(n)
        rng = np.random.default_rng(0)
        vol = rng.standard_normal(geo.n_voxel).astype(np.float32)
        proj = rng.standard_normal((n,) + geo.n_detector).astype(np.float32)
        base = {}
        for nd in device_counts:
            if nd > avail:
                continue
            devs = jax.local_devices()[:nd]
            pf = plan_forward(geo, n, nd, mem)
            tf = _time(lambda: stream_forward(vol, geo, angles, pf,
                                              devices=devs))
            pb = plan_backward(geo, n, nd, mem)
            tb = _time(lambda: stream_backward(proj, geo, angles, pb,
                                               devices=devs))
            for op, t, plan in (("fp", tf, pf), ("bp", tb, pb)):
                base.setdefault(op, t if nd == 1 else None)
                rows.append({
                    "op": op, "N": n, "n_dev": nd, "seconds": t,
                    "n_slabs": plan.n_slabs,
                    "pct_vs_1dev": 100.0 * t / base[op]
                    if base[op] else float("nan"),
                })
    return rows


def main():
    import os
    rows = run()
    print("op,N,n_dev,n_slabs,seconds,pct_vs_1dev")
    for r in rows:
        print(f"{r['op']},{r['N']},{r['n_dev']},{r['n_slabs']},"
              f"{r['seconds']:.4f},{r['pct_vs_1dev']:.1f}")
    if os.cpu_count() == 1:
        print("# NOTE: 1 physical core -- emulated devices timeshare it, "
              "so pct_vs_1dev ~= 100 is expected here; the reproduced "
              "quantity is the plan structure (angle ranges / slab "
              "counts); wall-clock speedup requires real devices")


if __name__ == "__main__":
    main()
