"""Paper Fig 7/8: FP/BP wall time, multi-device speedup, and overlap win.

N^3 volumes, N^2 detectors, N angles, on 1/2/4 emulated devices (CPU host
devices stand in for the paper's GTX 1080 Ti's; the *scaling shape* -- ratio
to 1-device time -- is the reproduced quantity, absolute times are
hardware-specific).  Timing includes host<->device transfer, as in the
paper.

Each configuration is timed twice through the same CommSchedule
interpreter: the **overlap** arm runs the plan's default schedule
(``prefetch_depth=1`` -- staging of the next slab/chunk is issued while
the current compute is in flight) and the **serial** arm runs
``plan.with_prefetch(0)`` (the no-prefetch reference the parity tests
compare against).  Both arms are asserted bit-identical before timing --
the schedule changes *when* bytes move, never the accumulation order --
so the reported ``speedup = serial_s / overlap_s`` is a pure
communication-overlap win.

``--smoke`` is the CI gate: tiny shapes, one repeat, bit-identity
asserted, JSON validated by ``tools/validate_trace.py --bench-json``.

Usage::

    PYTHONPATH=src python benchmarks/bench_scaling.py
        [--sizes 32,64,96] [--devices 1,2,4] [--budget-mib 64]
        [--repeats 2] [--json out.json] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from typing import Dict, List

# the whole point is multi-device scaling: emulate host devices when the
# caller has not already chosen a device topology (must precede jax import)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import numpy as np
import jax

from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.plan import plan as plan_execution
from repro.core.splitting import MemoryModel
from repro.core.streaming import stream_backward, stream_forward

try:
    from benchmarks import schema
except ImportError:           # run as a script: benchmarks/ is sys.path[0]
    import schema


def _time(fn, repeats=2):
    fn()                                   # warm-up / compile
    ts = []
    for _ in range(repeats):
        t0 = time.monotonic()
        fn()
        ts.append(time.monotonic() - t0)
    return min(ts)


def run(sizes=(32, 64, 96), device_counts=(1, 2, 4), budget_mib=64.0,
        repeats=2):
    """Returns rows: one per (op, N, n_dev) with overlap-on/off seconds."""
    rows: List[Dict] = []
    avail = jax.local_device_count()
    mem = MemoryModel(device_bytes=int(budget_mib * 2 ** 20),
                      usable_fraction=1.0)
    for n in sizes:
        geo = ConeGeometry.nice(n)
        angles = circular_angles(n)
        rng = np.random.default_rng(0)
        vol = rng.standard_normal(geo.n_voxel).astype(np.float32)
        proj = rng.standard_normal((n,) + geo.n_detector).astype(np.float32)
        base = {}
        for nd in device_counts:
            if nd > avail:
                continue
            devs = jax.local_devices()[:nd]
            p = plan_execution(geo, n, nd, mem)
            serial = p.with_prefetch(0)
            arms = {
                "fp": (lambda pl: stream_forward(vol, geo, angles, pl,
                                                 devices=devs),
                       p.forward.n_slabs),
                "bp": (lambda pl: stream_backward(proj, geo, angles, pl,
                                                  devices=devs),
                       p.backward.n_slabs),
            }
            for op, (fn, n_slabs) in arms.items():
                # overlap must not change a single bit before it is timed
                np.testing.assert_array_equal(fn(p), fn(serial))
                t_overlap = _time(lambda: fn(p), repeats)
                t_serial = _time(lambda: fn(serial), repeats)
                base.setdefault(op, t_overlap if nd == 1 else None)
                rows.append({
                    "op": op, "N": n, "n_dev": nd, "n_slabs": n_slabs,
                    "overlap_s": t_overlap, "serial_s": t_serial,
                    "speedup": t_serial / t_overlap if t_overlap else
                    float("nan"),
                    "pct_vs_1dev": 100.0 * t_overlap / base[op]
                    if base[op] else float("nan"),
                })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="streaming scaling + communication-overlap benchmark")
    ap.add_argument("--sizes", default="32,64,96")
    ap.add_argument("--devices", default="1,2,4")
    ap.add_argument("--budget-mib", type=float, default=64.0)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--json", default="", dest="json_out",
                    help="write rows as JSON ('-' for stdout)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: tiny shapes, one repeat")
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(","))
    devices = tuple(int(s) for s in args.devices.split(","))
    budget, repeats = args.budget_mib, args.repeats
    if args.smoke:
        # 0.15 MiB forces several slabs on a 32^3 volume (3 FP / 6 BP),
        # so the smoke actually exercises the prefetch/buffer machinery
        sizes, devices, budget, repeats = (32,), (1, 2), 0.15, 1

    rows = run(sizes, devices, budget, repeats)
    print("op,N,n_dev,n_slabs,overlap_s,serial_s,speedup,pct_vs_1dev")
    for r in rows:
        print(f"{r['op']},{r['N']},{r['n_dev']},{r['n_slabs']},"
              f"{r['overlap_s']:.4f},{r['serial_s']:.4f},"
              f"{r['speedup']:.2f},{r['pct_vs_1dev']:.1f}")
    best = max(rows, key=lambda r: r["speedup"])
    print(f"# best overlap win: {best['op']} N={best['N']} "
          f"n_dev={best['n_dev']}: {best['speedup']:.2f}x vs no-prefetch")
    if os.cpu_count() == 1:
        print("# NOTE: 1 physical core -- emulated devices timeshare it, "
              "so pct_vs_1dev ~= 100 and overlap ~ 1x are expected here; "
              "the reproduced quantity is the schedule structure, "
              "wall-clock wins require real devices")
    if args.smoke:
        assert rows, "smoke produced no rows"
        assert all(r["overlap_s"] > 0 and r["serial_s"] > 0 for r in rows)
    if args.json_out:
        metrics = []
        for r in rows:
            pre = f"{r['op']}.N{r['N']}.d{r['n_dev']}"
            for name, val, units, direction in (
                    ("overlap_s", r["overlap_s"], "s", "lower"),
                    ("speedup", r["speedup"], "x", "higher")):
                if math.isfinite(val):   # degenerate cells stay in rows
                    metrics.append(schema.metric(f"{pre}.{name}", val,
                                                 units, direction,
                                                 repeats))
        doc = schema.envelope(
            "scaling",
            config={"sizes": list(sizes), "devices": list(devices),
                    "budget_mib": budget, "repeats": repeats},
            metrics=metrics, smoke=args.smoke,
            budget_mib=budget, rows=rows)
        if args.json_out == "-":
            json.dump(doc, sys.stdout, indent=2)
            sys.stdout.write("\n")
        else:
            with open(args.json_out, "w") as f:
                json.dump(doc, f, indent=2)
            print(f"# wrote {args.json_out}")


if __name__ == "__main__":
    main()
