"""Serving benchmark: aggregate throughput + latency under a mixed
small/large reconstruction workload (jobs/sec, p50/p95 latency).

Single-pod section — three configurations over the *same* job set:

* ``serial``      -- one device, one job at a time (the pre-scheduler
  world: every reconstruction runs alone, back to back).
* ``cooperative`` -- a pool of ``--devices`` simulated small-memory
  devices stepped by the single-thread ``Scheduler.run()`` loop: jobs are
  packed and interleaved, but only one device computes at a time.
* ``threaded``    -- the same pool driven by the ``AsyncDriver`` (one
  worker thread per device): per-device step loops overlap on the host
  the way per-GPU queues overlap in the paper, so *wall-clock* jobs/sec
  improves, not just the modeled makespan.

Multi-pod section — heavier in-core jobs (``make_multipod_workload``)
under an *imbalanced* arrival pattern (most tenants pinned to pod 0, the
static-partitioning world where each tenant has "their" host group):

* ``static``   -- two pods, no stealing: pod 0 grinds through its
  backlog while pod 1 idles after its own few jobs.
* ``stealing`` -- identical pinning, but idle pods steal parked jobs
  from loaded ones (checkpoint -> manifest+COMMIT transfer ->
  bit-identical resume), so the fleet's wall jobs/sec approaches the
  balanced optimum.  Every stolen job's final volume is re-run unstolen
  on a fresh single scheduler and asserted bit-identical.

Every step blocks on its compute (no async-dispatch mis-timing), so
both the wall numbers and the per-device busy clocks are honest.  The
modeled makespan (max over device busy clocks) remains the stand-in for
real multi-accelerator wall-clock on a single-host rig, exactly like the
paper's per-GPU timelines (Fig 3/5).

    PYTHONPATH=src python benchmarks/bench_serve.py --small 12 --large 1
"""

from __future__ import annotations

import argparse
import tempfile
from typing import Dict, List

import numpy as np

from repro.core.geometry import ConeGeometry, circular_angles
from repro.core import phantoms
from repro.core.splitting import MemoryModel
from repro.serve import (AsyncDriver, DevicePool, MultiPodDriver,
                         MultiPodScheduler, Pod, PodSpec, ReconJob,
                         Scheduler)

KIB = 1024


def make_workload(n_small: int, n_large: int) -> List[ReconJob]:
    """Deterministic mixed workload: small in-core jobs (alternating CGLS /
    OS-SART, mixed priorities) + large jobs that must stream."""
    geo_s = ConeGeometry.nice(16)
    ang_s = circular_angles(12)
    proj_s = phantoms.sphere_projection_analytic(geo_s, ang_s)
    geo_l = ConeGeometry.nice(32)
    ang_l = circular_angles(16)
    proj_l = phantoms.sphere_projection_analytic(geo_l, ang_l)

    jobs = []
    for i in range(n_small):
        if i % 2 == 0:
            jobs.append(ReconJob("cgls", geo_s, ang_s, proj_s, n_iter=2,
                                 priority=i % 3))
        else:
            jobs.append(ReconJob("ossart", geo_s, ang_s, proj_s, n_iter=2,
                                 priority=i % 3,
                                 params={"subset_size": 6}))
    for _ in range(n_large):
        jobs.append(ReconJob("ossart", geo_l, ang_l, proj_l, n_iter=1,
                             params={"subset_size": 16}))
    return jobs


def run_config(name: str, jobs: List[ReconJob], n_devices: int,
               budget_kib: int, threaded: bool = False) -> Dict:
    mem = MemoryModel(device_bytes=budget_kib * KIB, usable_fraction=1.0)
    max_per_dev = 1 if name == "serial" else None
    pool = DevicePool(n_devices=n_devices, memory=mem,
                      max_jobs_per_device=max_per_dev)
    sched = Scheduler(pool=pool)
    for j in jobs:
        sched.submit(j)
    if threaded:
        AsyncDriver(sched).run()
    else:
        sched.run()
    s = sched.summary()
    assert s["completed"] == len(jobs), \
        (name, s, [r.error for r in sched.records.values() if r.error])
    return s


CONFIGS = (("serial", 1, False),
           ("cooperative", None, False),
           ("threaded", None, True))


# ---------------------------------------------------------------------------
# multi-pod: static per-pod partitioning vs work stealing
# ---------------------------------------------------------------------------

def make_multipod_workload(n_jobs: int) -> List[ReconJob]:
    """Heavier in-core jobs (32^3 under an 800 KiB budget) for the
    multi-pod comparison: each step carries enough real compute to
    release the GIL, so two pod worker threads genuinely overlap on a
    small host and the wall-clock numbers measure balancing, not Python
    dispatch contention."""
    geo = ConeGeometry.nice(32)
    ang = circular_angles(16)
    proj = phantoms.sphere_projection_analytic(geo, ang)
    jobs = []
    for i in range(n_jobs):
        if i % 2 == 0:
            jobs.append(ReconJob("cgls", geo, ang, proj, n_iter=2,
                                 priority=i % 3))
        else:
            jobs.append(ReconJob("ossart", geo, ang, proj, n_iter=2,
                                 priority=i % 3,
                                 params={"subset_size": 8}))
    return jobs


def imbalanced_pins(n_jobs: int, n_pods: int, skew: int = 5) -> List[int]:
    """Tenant-affinity pinning where only every ``skew``-th job lands off
    pod 0 — the imbalanced arrival pattern stealing exists to fix."""
    if n_pods == 1:
        return [0] * n_jobs
    pins = []
    for i in range(n_jobs):
        if i % skew == skew - 1:
            pins.append(1 + (i // skew) % (n_pods - 1))
        else:
            pins.append(0)
    return pins


def run_multipod(name: str, jobs: List[ReconJob], n_pods: int,
                 devices_per_pod: int, budget_kib: int,
                 steal: bool) -> Dict:
    mem = MemoryModel(device_bytes=budget_kib * KIB, usable_fraction=1.0)
    mps = MultiPodScheduler(
        [Pod(PodSpec(f"pod{i}", n_devices=devices_per_pod, memory=mem))
         for i in range(n_pods)],
        steal=steal, transfer_dir=tempfile.mkdtemp(prefix="bench-steal-"))
    pins = imbalanced_pins(len(jobs), n_pods)
    by_id = {}
    for job, pin in zip(jobs, pins):
        by_id[mps.submit(job, pod=pin)] = job
    MultiPodDriver(mps).run(timeout=600)
    s = mps.summary()
    assert s["completed"] == len(jobs), (name, s)
    if steal:
        # acceptance: a stolen job's final volume must be bit-identical
        # to the same job run unstolen (fresh single-pod scheduler with
        # the same memory model => identical mode decision + numerics)
        for jid in mps.stolen_jobs:
            solo = Scheduler(pool=DevicePool(n_devices=1, memory=mem))
            solo.submit(by_id[jid])
            solo.run()
            np.testing.assert_array_equal(mps.result(jid),
                                          solo.result(jid))
        s["stolen_verified"] = len(mps.stolen_jobs)
    return s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", type=int, default=12)
    ap.add_argument("--large", type=int, default=1)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--budget-kib", type=int, default=220,
                    help="per-device budget; 220 KiB fits two 16^3 jobs "
                         "and forces the 32^3 jobs out-of-core")
    ap.add_argument("--pods", type=int, default=2,
                    help="pods in the multi-pod section (0 skips it)")
    ap.add_argument("--devices-per-pod", type=int, default=1,
                    help="slots per pod; keep pods*devices_per_pod <= "
                         "physical cores so the wall-clock comparison is "
                         "honest (oversubscribed worker threads hide the "
                         "idle capacity stealing exists to reclaim)")
    ap.add_argument("--mp-budget-kib", type=int, default=800,
                    help="per-device budget in the multi-pod section: 800 "
                         "KiB holds one 32^3 job resident per device")
    args = ap.parse_args()

    # Unmeasured warm-up pass: the scheduler's shared operator cache (and
    # jit compilation) is populated once, so all measured configurations
    # run at the steady-state cost a long-lived serving process sees.
    # Without this, whichever configuration runs first pays all compiles.
    run_config("warmup", make_workload(args.small, args.large),
               args.devices, args.budget_kib)

    results = {}
    for name, ndev, threaded in CONFIGS:
        jobs = make_workload(args.small, args.large)
        results[name] = run_config(name, jobs, ndev or args.devices,
                                   args.budget_kib, threaded=threaded)

    print("config,devices,jobs,steps,streamed,wall_s,modeled_makespan_s,"
          "jobs_per_sec_wall,jobs_per_sec_modeled,latency_p50_s,"
          "latency_p95_s")
    for name, ndev, _ in CONFIGS:
        s = results[name]
        print(f"{name},{ndev or args.devices},{s['completed']},{s['steps']},"
              f"{s['streamed_jobs']},{s['wall_seconds']:.2f},"
              f"{s['modeled_makespan_seconds']:.2f},"
              f"{s['jobs_per_sec_wall']:.3f},"
              f"{s['jobs_per_sec_modeled']:.3f},{s['latency_p50']:.2f},"
              f"{s['latency_p95']:.2f}")
    packed_speedup = (results["cooperative"]["jobs_per_sec_modeled"]
                      / max(results["serial"]["jobs_per_sec_modeled"], 1e-12))
    threaded_speedup = (results["threaded"]["jobs_per_sec_wall"]
                        / max(results["cooperative"]["jobs_per_sec_wall"],
                              1e-12))
    p95_ratio = (results["cooperative"]["latency_p95"]
                 / max(results["threaded"]["latency_p95"], 1e-12))
    print(f"# cooperative vs serial (modeled device-parallel jobs/sec): "
          f"{packed_speedup:.2f}x")
    print(f"# threaded vs cooperative (WALL jobs/sec): "
          f"{threaded_speedup:.2f}x; p95 latency {p95_ratio:.2f}x lower")

    if args.pods >= 2:
        n_mp_jobs = args.small + args.large
        # separate warm-up: the shared operator cache keys on the memory
        # model, so the multi-pod budget needs its own compile pass
        run_config("mp-warmup", make_multipod_workload(2), 1,
                   args.mp_budget_kib)
        print("\nconfig,pods,jobs,stolen,wall_s,jobs_per_sec_wall,"
              "latency_p95_s")
        mp = {}
        for name, steal in (("static", False), ("stealing", True)):
            jobs = make_multipod_workload(n_mp_jobs)
            mp[name] = run_multipod(name, jobs, args.pods,
                                    args.devices_per_pod,
                                    args.mp_budget_kib, steal=steal)
            s = mp[name]
            print(f"{name},{args.pods},{s['completed']},"
                  f"{s['stolen_in']},{s['wall_seconds']:.2f},"
                  f"{s['jobs_per_sec_wall']:.3f},{s['latency_p95']:.2f}")
        steal_speedup = (mp["stealing"]["jobs_per_sec_wall"]
                         / max(mp["static"]["jobs_per_sec_wall"], 1e-12))
        print(f"# stealing vs static partitioning (WALL jobs/sec, "
              f"imbalanced arrivals): {steal_speedup:.2f}x; "
              f"{mp['stealing']['stolen_in']} jobs stolen, "
              f"{mp['stealing'].get('stolen_verified', 0)} verified "
              f"bit-identical to unstolen runs")


if __name__ == "__main__":
    main()
