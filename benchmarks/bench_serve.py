"""Serving benchmark: aggregate throughput + latency under a mixed
small/large reconstruction workload (jobs/sec, p50/p95 latency).

Three configurations over the *same* job set:

* ``serial``      -- one device, one job at a time (the pre-scheduler
  world: every reconstruction runs alone, back to back).
* ``cooperative`` -- a pool of ``--devices`` simulated small-memory
  devices stepped by the single-thread ``Scheduler.run()`` loop: jobs are
  packed and interleaved, but only one device computes at a time.
* ``threaded``    -- the same pool driven by the ``AsyncDriver`` (one
  worker thread per device): per-device step loops overlap on the host
  the way per-GPU queues overlap in the paper, so *wall-clock* jobs/sec
  improves, not just the modeled makespan.

Every step now blocks on its compute (no async-dispatch mis-timing), so
both the wall numbers and the per-device busy clocks are honest.  The
modeled makespan (max over device busy clocks) remains the stand-in for
real multi-accelerator wall-clock on a single-host rig, exactly like the
paper's per-GPU timelines (Fig 3/5).

    PYTHONPATH=src python benchmarks/bench_serve.py --small 12 --large 1
"""

from __future__ import annotations

import argparse
from typing import Dict, List

from repro.core.geometry import ConeGeometry, circular_angles
from repro.core import phantoms
from repro.core.splitting import MemoryModel
from repro.serve import AsyncDriver, DevicePool, ReconJob, Scheduler

KIB = 1024


def make_workload(n_small: int, n_large: int) -> List[ReconJob]:
    """Deterministic mixed workload: small in-core jobs (alternating CGLS /
    OS-SART, mixed priorities) + large jobs that must stream."""
    geo_s = ConeGeometry.nice(16)
    ang_s = circular_angles(12)
    proj_s = phantoms.sphere_projection_analytic(geo_s, ang_s)
    geo_l = ConeGeometry.nice(32)
    ang_l = circular_angles(16)
    proj_l = phantoms.sphere_projection_analytic(geo_l, ang_l)

    jobs = []
    for i in range(n_small):
        if i % 2 == 0:
            jobs.append(ReconJob("cgls", geo_s, ang_s, proj_s, n_iter=2,
                                 priority=i % 3))
        else:
            jobs.append(ReconJob("ossart", geo_s, ang_s, proj_s, n_iter=2,
                                 priority=i % 3,
                                 params={"subset_size": 6}))
    for _ in range(n_large):
        jobs.append(ReconJob("ossart", geo_l, ang_l, proj_l, n_iter=1,
                             params={"subset_size": 16}))
    return jobs


def run_config(name: str, jobs: List[ReconJob], n_devices: int,
               budget_kib: int, threaded: bool = False) -> Dict:
    mem = MemoryModel(device_bytes=budget_kib * KIB, usable_fraction=1.0)
    max_per_dev = 1 if name == "serial" else None
    pool = DevicePool(n_devices=n_devices, memory=mem,
                      max_jobs_per_device=max_per_dev)
    sched = Scheduler(pool=pool)
    for j in jobs:
        sched.submit(j)
    if threaded:
        AsyncDriver(sched).run()
    else:
        sched.run()
    s = sched.summary()
    assert s["completed"] == len(jobs), \
        (name, s, [r.error for r in sched.records.values() if r.error])
    return s


CONFIGS = (("serial", 1, False),
           ("cooperative", None, False),
           ("threaded", None, True))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", type=int, default=12)
    ap.add_argument("--large", type=int, default=1)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--budget-kib", type=int, default=220,
                    help="per-device budget; 220 KiB fits two 16^3 jobs "
                         "and forces the 32^3 jobs out-of-core")
    args = ap.parse_args()

    # Unmeasured warm-up pass: the scheduler's shared operator cache (and
    # jit compilation) is populated once, so all measured configurations
    # run at the steady-state cost a long-lived serving process sees.
    # Without this, whichever configuration runs first pays all compiles.
    run_config("warmup", make_workload(args.small, args.large),
               args.devices, args.budget_kib)

    results = {}
    for name, ndev, threaded in CONFIGS:
        jobs = make_workload(args.small, args.large)
        results[name] = run_config(name, jobs, ndev or args.devices,
                                   args.budget_kib, threaded=threaded)

    print("config,devices,jobs,steps,streamed,wall_s,modeled_makespan_s,"
          "jobs_per_sec_wall,jobs_per_sec_modeled,latency_p50_s,"
          "latency_p95_s")
    for name, ndev, _ in CONFIGS:
        s = results[name]
        print(f"{name},{ndev or args.devices},{s['completed']},{s['steps']},"
              f"{s['streamed_jobs']},{s['wall_seconds']:.2f},"
              f"{s['modeled_makespan_seconds']:.2f},"
              f"{s['jobs_per_sec_wall']:.3f},"
              f"{s['jobs_per_sec_modeled']:.3f},{s['latency_p50']:.2f},"
              f"{s['latency_p95']:.2f}")
    packed_speedup = (results["cooperative"]["jobs_per_sec_modeled"]
                      / max(results["serial"]["jobs_per_sec_modeled"], 1e-12))
    threaded_speedup = (results["threaded"]["jobs_per_sec_wall"]
                        / max(results["cooperative"]["jobs_per_sec_wall"],
                              1e-12))
    p95_ratio = (results["cooperative"]["latency_p95"]
                 / max(results["threaded"]["latency_p95"], 1e-12))
    print(f"# cooperative vs serial (modeled device-parallel jobs/sec): "
          f"{packed_speedup:.2f}x")
    print(f"# threaded vs cooperative (WALL jobs/sec): "
          f"{threaded_speedup:.2f}x; p95 latency {p95_ratio:.2f}x lower")


if __name__ == "__main__":
    main()
