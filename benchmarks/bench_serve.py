"""Serving benchmark: aggregate throughput + latency under a mixed
small/large reconstruction workload (jobs/sec, p50/p95 latency).

Single-pod section — three configurations over the *same* job set:

* ``serial``      -- one device, one job at a time (the pre-scheduler
  world: every reconstruction runs alone, back to back).
* ``cooperative`` -- a pool of ``--devices`` simulated small-memory
  devices stepped by the single-thread ``Scheduler.run()`` loop: jobs are
  packed and interleaved, but only one device computes at a time.
* ``threaded``    -- the same pool driven by the ``AsyncDriver`` (one
  worker thread per device): per-device step loops overlap on the host
  the way per-GPU queues overlap in the paper, so *wall-clock* jobs/sec
  improves, not just the modeled makespan.

Multi-pod section — heavier in-core jobs (``make_multipod_workload``)
under an *imbalanced* arrival pattern (most tenants pinned to pod 0, the
static-partitioning world where each tenant has "their" host group):

* ``static``   -- two pods, no stealing: pod 0 grinds through its
  backlog while pod 1 idles after its own few jobs.
* ``stealing`` -- identical pinning, but idle pods steal parked jobs
  from loaded ones (checkpoint -> manifest+COMMIT transfer ->
  bit-identical resume), so the fleet's wall jobs/sec approaches the
  balanced optimum.  Every stolen job's final volume is re-run unstolen
  on a fresh single scheduler and asserted bit-identical.

Bursty-trace section — the same jobs arrive in *bursts* separated by
idle gaps (the demand pattern autoscaling exists for):

* ``static-max``  -- a fleet of ``--max-pods`` pods, all online for the
  whole trace: peak capacity, but every pod burns pod-seconds through
  every idle gap.
* ``autoscaled``  -- one seed pod plus an ``Autoscaler`` growing the
  fleet from a PodSpec template pool while the backlog is high and
  draining + retiring the least-loaded pod (preempt -> export ->
  bit-identical resume on a survivor) while it is low.  The claim: wall
  jobs/sec tracks the static max fleet (>= 0.9x) at a fraction of the
  pod-seconds (<= 0.7x), and every job a scale-down drain moved is
  re-run undrained and asserted bit-identical.

Zero-loss section — the kill -9 drill: a snapshotting scheduler is
killed repeatedly mid-run (each death lands after a copy-on-checkpoint
snapshot of its *running* jobs plus further doomed progress) and rebuilt
purely from disk; the section reports ``iterations_lost`` — committed
iterations that regressed across any death — which must be exactly 0,
with every job's final volume bit-identical to an uninterrupted run.

Every step blocks on its compute (no async-dispatch mis-timing), so
both the wall numbers and the per-device busy clocks are honest.  The
modeled makespan (max over device busy clocks) remains the stand-in for
real multi-accelerator wall-clock on a single-host rig, exactly like the
paper's per-GPU timelines (Fig 3/5).

    PYTHONPATH=src python benchmarks/bench_serve.py --small 12 --large 1
    PYTHONPATH=src python benchmarks/bench_serve.py --smoke   # tiny CI gate
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.core.geometry import ConeGeometry, circular_angles
from repro.core import phantoms
from repro.core.splitting import MemoryModel
from repro.serve import (AsyncDriver, Autoscaler, AutoscalePolicy,
                         DevicePool, MultiPodDriver, MultiPodScheduler,
                         Pod, PodSpec, ReconJob, Scheduler)

try:
    from benchmarks import schema
except ImportError:           # run as a script: benchmarks/ is sys.path[0]
    import schema

KIB = 1024


def make_workload(n_small: int, n_large: int) -> List[ReconJob]:
    """Deterministic mixed workload: small in-core jobs (alternating CGLS /
    OS-SART, mixed priorities) + large jobs that must stream."""
    geo_s = ConeGeometry.nice(16)
    ang_s = circular_angles(12)
    proj_s = phantoms.sphere_projection_analytic(geo_s, ang_s)
    geo_l = ConeGeometry.nice(32)
    ang_l = circular_angles(16)
    proj_l = phantoms.sphere_projection_analytic(geo_l, ang_l)

    jobs = []
    for i in range(n_small):
        if i % 2 == 0:
            jobs.append(ReconJob("cgls", geo_s, ang_s, proj_s, n_iter=2,
                                 priority=i % 3))
        else:
            jobs.append(ReconJob("ossart", geo_s, ang_s, proj_s, n_iter=2,
                                 priority=i % 3,
                                 params={"subset_size": 6}))
    for _ in range(n_large):
        jobs.append(ReconJob("ossart", geo_l, ang_l, proj_l, n_iter=1,
                             params={"subset_size": 16}))
    return jobs


def run_config(name: str, jobs: List[ReconJob], n_devices: int,
               budget_kib: int, threaded: bool = False) -> Dict:
    mem = MemoryModel(device_bytes=budget_kib * KIB, usable_fraction=1.0)
    max_per_dev = 1 if name == "serial" else None
    pool = DevicePool(n_devices=n_devices, memory=mem,
                      max_jobs_per_device=max_per_dev)
    sched = Scheduler(pool=pool)
    for j in jobs:
        sched.submit(j)
    if threaded:
        AsyncDriver(sched).run()
    else:
        sched.run()
    s = sched.summary()
    assert s["completed"] == len(jobs), \
        (name, s, [r.error for r in sched.records.values() if r.error])
    return s


CONFIGS = (("serial", 1, False),
           ("cooperative", None, False),
           ("threaded", None, True))


# ---------------------------------------------------------------------------
# multi-pod: static per-pod partitioning vs work stealing
# ---------------------------------------------------------------------------

def make_multipod_workload(n_jobs: int) -> List[ReconJob]:
    """Heavier in-core jobs (32^3 under an 800 KiB budget) for the
    multi-pod comparison: each step carries enough real compute to
    release the GIL, so two pod worker threads genuinely overlap on a
    small host and the wall-clock numbers measure balancing, not Python
    dispatch contention."""
    geo = ConeGeometry.nice(32)
    ang = circular_angles(16)
    proj = phantoms.sphere_projection_analytic(geo, ang)
    jobs = []
    for i in range(n_jobs):
        if i % 2 == 0:
            jobs.append(ReconJob("cgls", geo, ang, proj, n_iter=2,
                                 priority=i % 3))
        else:
            jobs.append(ReconJob("ossart", geo, ang, proj, n_iter=2,
                                 priority=i % 3,
                                 params={"subset_size": 8}))
    return jobs


def imbalanced_pins(n_jobs: int, n_pods: int, skew: int = 5) -> List[int]:
    """Tenant-affinity pinning where only every ``skew``-th job lands off
    pod 0 — the imbalanced arrival pattern stealing exists to fix."""
    if n_pods == 1:
        return [0] * n_jobs
    pins = []
    for i in range(n_jobs):
        if i % skew == skew - 1:
            pins.append(1 + (i // skew) % (n_pods - 1))
        else:
            pins.append(0)
    return pins


def run_multipod(name: str, jobs: List[ReconJob], n_pods: int,
                 devices_per_pod: int, budget_kib: int,
                 steal: bool) -> Dict:
    mem = MemoryModel(device_bytes=budget_kib * KIB, usable_fraction=1.0)
    mps = MultiPodScheduler(
        [Pod(PodSpec(f"pod{i}", n_devices=devices_per_pod, memory=mem))
         for i in range(n_pods)],
        steal=steal, transfer_dir=tempfile.mkdtemp(prefix="bench-steal-"))
    pins = imbalanced_pins(len(jobs), n_pods)
    by_id = {}
    for job, pin in zip(jobs, pins):
        by_id[mps.submit(job, pod=pin)] = job
    MultiPodDriver(mps).run(timeout=600)
    s = mps.summary()
    assert s["completed"] == len(jobs), (name, s)
    if steal:
        # acceptance: a stolen job's final volume must be bit-identical
        # to the same job run unstolen (fresh single-pod scheduler with
        # the same memory model => identical mode decision + numerics)
        for jid in mps.stolen_jobs:
            solo = Scheduler(pool=DevicePool(n_devices=1, memory=mem))
            solo.submit(by_id[jid])
            solo.run()
            np.testing.assert_array_equal(mps.result(jid),
                                          solo.result(jid))
        s["stolen_verified"] = len(mps.stolen_jobs)
    return s


# ---------------------------------------------------------------------------
# bursty trace: autoscaled fleet vs static max-size fleet
# ---------------------------------------------------------------------------

def make_burst(n_jobs: int) -> List[ReconJob]:
    """One burst of the multipod workload (heavier 32^3 in-core jobs, so
    worker threads genuinely overlap and the backlog signal is real)."""
    return make_multipod_workload(n_jobs)


def run_bursty(name: str, n_bursts: int, jobs_per_burst: int,
               gap_seconds: float, max_pods: int, budget_kib: int,
               autoscale: bool, smoke: bool = False) -> Dict:
    """Drive one fleet configuration through the bursty trace: submit a
    burst, wait for the fleet to go idle, sleep through the gap, repeat.
    Both configurations see the identical arrival pattern; only the
    capacity management differs."""
    mem = MemoryModel(device_bytes=budget_kib * KIB, usable_fraction=1.0)
    asc = None
    if autoscale:
        mps = MultiPodScheduler(
            [Pod(PodSpec("seed", n_devices=1, memory=mem))],
            transfer_dir=tempfile.mkdtemp(prefix="bench-as-"))
        # thresholds in modeled seconds per device: a whole burst queued
        # on one pod is far above the high watermark (scale up), an
        # empty fleet during a gap is below the low one (drain + retire)
        asc = Autoscaler(
            mps, [PodSpec("burst", n_devices=1, memory=mem)],
            AutoscalePolicy(scale_up_backlog_seconds=0.5,
                            scale_down_backlog_seconds=0.05,
                            up_window_seconds=0.0,
                            down_window_seconds=0.05,
                            cooldown_seconds=0.05,
                            min_pods=1, max_pods=max_pods))
        driver = MultiPodDriver(mps, autoscaler=asc)
    else:
        mps = MultiPodScheduler(
            [Pod(PodSpec(f"st{i}", n_devices=1, memory=mem))
             for i in range(max_pods)],
            transfer_dir=tempfile.mkdtemp(prefix="bench-st-"))
        driver = MultiPodDriver(mps)
    by_id: Dict[str, ReconJob] = {}
    driver.start()
    t0 = time.monotonic()
    for b in range(n_bursts):
        for job in make_burst(jobs_per_burst):
            by_id[mps.submit(job)] = job
        deadline = time.monotonic() + 600
        while not mps.idle and time.monotonic() < deadline:
            time.sleep(0.005)
        if b < n_bursts - 1:
            time.sleep(gap_seconds)   # the idle gap autoscaling reclaims
    driver.wait(timeout=600)
    wall = time.monotonic() - t0
    # give the autoscaler the tail gap to shrink back before measuring
    if autoscale:
        tail = time.monotonic() + (2.0 if not smoke else 0.5)
        while len(mps.pods) > 1 and time.monotonic() < tail:
            time.sleep(0.01)
    driver.stop()
    s = mps.summary()
    assert s["completed"] == len(by_id), (name, s)
    s["trace_wall_seconds"] = wall
    s["trace_jobs_per_sec"] = len(by_id) / wall
    if asc is not None:
        # acceptance: every job a scale-down drain moved mid-flight must
        # finish bit-identically to the same job never having been
        # drained (fresh single-pod scheduler, same memory model)
        for jid in asc.drained_jobs:
            solo = Scheduler(pool=DevicePool(n_devices=1, memory=mem))
            solo.submit(by_id[jid])
            solo.run()
            np.testing.assert_array_equal(mps.result(jid),
                                          solo.result(jid))
        s["drained_verified"] = len(asc.drained_jobs)
        s["scale_events"] = [(e.direction, e.pod) for e in asc.events]
    return s


def bursty_section(args, smoke: bool = False) -> Dict[str, Dict]:
    print("\nconfig,pods_peak,jobs,wall_s,jobs_per_sec_wall,pod_seconds,"
          "scale_up,scale_down,drained_verified")
    results = {}
    for name, autoscale in (("static-max", False), ("autoscaled", True)):
        s = run_bursty(name, args.bursts, args.jobs_per_burst,
                       args.gap_seconds, args.max_pods,
                       args.mp_budget_kib, autoscale, smoke=smoke)
        results[name] = s
        print(f"{name},{s['pods_online_peak']},{s['completed']},"
              f"{s['trace_wall_seconds']:.2f},"
              f"{s['trace_jobs_per_sec']:.3f},{s['pod_seconds']:.2f},"
              f"{s['scale_up_events']},{s['scale_down_events']},"
              f"{s.get('drained_verified', 0)}")
    thr_ratio = (results["autoscaled"]["trace_jobs_per_sec"]
                 / max(results["static-max"]["trace_jobs_per_sec"], 1e-12))
    ps_ratio = (results["autoscaled"]["pod_seconds"]
                / max(results["static-max"]["pod_seconds"], 1e-12))
    print(f"# autoscaled vs static-max (bursty trace): "
          f"{thr_ratio:.2f}x wall jobs/sec (target >= 0.9x) at "
          f"{ps_ratio:.2f}x pod-seconds (target <= 0.7x); "
          f"{results['autoscaled'].get('drained_verified', 0)} "
          f"drained jobs verified bit-identical to undrained reruns")
    return results


def run_zero_loss(name: str, n_jobs: int, n_kills: int,
                  budget_kib: int = 220, n_iter: int = 3) -> Dict:
    """Kill -9 drill: a snapshotting scheduler is killed ``n_kills``
    times mid-run — each kill lands *after* a copy-on-checkpoint
    snapshot of the running jobs and after further (doomed) progress,
    simulated by discarding the live scheduler and rebuilding purely
    from disk.  Accounts committed iterations across every death:
    ``iterations_lost`` must be exactly 0 (nothing a snapshot committed
    ever regresses), and every job's final volume must be bit-identical
    to an uninterrupted single-shot reconstruction."""
    geo = ConeGeometry.nice(16)
    ang = circular_angles(12)
    proj = phantoms.sphere_projection_analytic(geo, ang)
    mem = MemoryModel(device_bytes=budget_kib * KIB, usable_fraction=1.0)
    snap = tempfile.mkdtemp(prefix="bench-zero-loss-")

    sched = Scheduler(n_devices=2, memory=mem, snapshot_dir=snap)
    ids = [sched.submit(ReconJob("cgls", geo, ang, proj, n_iter=n_iter))
           for _ in range(n_jobs)]
    results: Dict[str, np.ndarray] = {}

    def harvest():
        for j in ids:
            rec = sched.records.get(j)
            if j not in results and rec is not None and rec.done:
                results[j] = np.asarray(sched.result(j))

    t0 = time.monotonic()
    kills = lost = quanta = 0
    while not sched.idle:
        sched.step_quantum()
        quanta += 1
        assert quanta < 500, "zero-loss drill failed to converge"
        harvest()
        if kills < n_kills and not sched.idle:
            sched.snapshot(snap)               # running jobs included
            committed = {j: sched.records[j].iterations_done
                         for j in ids
                         if j in sched.records and not sched.records[j].done}
            sched.step_quantum()               # doomed progress, then die
            quanta += 1
            sched = Scheduler(n_devices=2, memory=mem, snapshot_dir=snap)
            sched.restore(snap)
            for j, it in committed.items():
                lost += max(0, it - sched.records[j].iterations_done)
            kills += 1
    harvest()
    wall = time.monotonic() - t0

    ref = np.asarray(cgls_reference(geo, ang, proj, n_iter))
    verified = 0
    for j in ids:
        np.testing.assert_array_equal(results[j], ref)
        verified += 1
    assert lost == 0, f"{name}: {lost} committed iterations lost"
    return {"jobs": n_jobs, "kills": kills, "iterations_lost": lost,
            "verified_bit_identical": verified, "wall_seconds": wall}


def cgls_reference(geo, ang, proj, n_iter):
    """Uninterrupted reference for the zero-loss drill."""
    from repro.core.algorithms import cgls
    return cgls(proj, geo, ang, n_iter=n_iter)


def zero_loss_section(smoke: bool = False) -> Dict[str, Dict]:
    print("\nconfig,jobs,injected_kills,iterations_lost,"
          "verified_bit_identical,wall_s")
    n_jobs, n_kills = (3, 1) if smoke else (6, 3)
    s = run_zero_loss("zero-loss", n_jobs, n_kills)
    print(f"zero-loss,{s['jobs']},{s['kills']},{s['iterations_lost']},"
          f"{s['verified_bit_identical']},{s['wall_seconds']:.2f}")
    print(f"# zero-loss drill: {s['kills']} mid-run kills, "
          f"{s['iterations_lost']} committed iterations lost (target: 0); "
          f"{s['verified_bit_identical']}/{s['jobs']} jobs bit-identical "
          f"to uninterrupted runs")
    return {"zero-loss": s}


def smoke_main() -> Dict[str, Dict]:
    """Tiny end-to-end gate for CI: one threaded single-pod config and
    one 2-burst autoscaled trace must run to completion (the asserts
    inside run_config / run_bursty are the check)."""
    ns = argparse.Namespace(bursts=2, jobs_per_burst=3, gap_seconds=0.6,
                            max_pods=2, mp_budget_kib=800)
    run_config("warmup", make_workload(2, 0), 2, 220)
    threaded = run_config("threaded", make_workload(4, 0), 2, 220,
                          threaded=True)
    run_config("mp-warmup", make_multipod_workload(2), 1, 800)
    bursty = bursty_section(ns, smoke=True)
    zero_loss = zero_loss_section(smoke=True)
    print("SMOKE OK")
    return {"configs": {"threaded": threaded}, "bursty": bursty,
            "zero_loss": zero_loss}


def _write_json(doc: Dict, path: str) -> None:
    if path == "-":
        json.dump(doc, sys.stdout, indent=2, default=list)
        print()
        return
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=list)
    print(f"# json -> {path}")


def _doc_metrics(sections: Dict) -> List[Dict]:
    """Flatten the section summaries into the shared metric list
    (:mod:`benchmarks.schema`) the trajectory tracker consumes."""
    out = []
    for group in ("configs", "multipod"):
        for name, s in sections.get(group, {}).items():
            out.append(schema.metric(f"{name}.jobs_per_sec_wall",
                                     s["jobs_per_sec_wall"], "jobs/s",
                                     "higher"))
            out.append(schema.metric(f"{name}.latency_p95_s",
                                     s["latency_p95"], "s", "lower"))
            out.append(schema.metric(f"{name}.wall_s",
                                     s["wall_seconds"], "s", "lower"))
    for name, s in sections.get("bursty", {}).items():
        out.append(schema.metric(f"bursty.{name}.jobs_per_sec",
                                 s["trace_jobs_per_sec"], "jobs/s",
                                 "higher"))
        out.append(schema.metric(f"bursty.{name}.pod_seconds",
                                 s["pod_seconds"], "s", "lower"))
    zl = sections.get("zero_loss", {}).get("zero-loss")
    if zl:
        out.append(schema.metric("zero_loss.wall_s", zl["wall_seconds"],
                                 "s", "lower"))
        out.append(schema.metric("zero_loss.iterations_lost",
                                 zl["iterations_lost"], "iterations",
                                 "lower"))
    return out


def _attach_observability(env: Dict, traced: bool) -> None:
    """Embed the calibration / SLO / memory report in the JSON output
    when the run was traced (the ledger reads the fleet event log, which
    only exists with tracing on)."""
    if not traced:
        return
    from repro.obs import CalibrationLedger, memory_calibration, slo_report
    env["calibration"] = CalibrationLedger.from_events().report()
    env["slo"] = slo_report()
    env["memory_calibration"] = [m.as_dict()
                                 for m in memory_calibration()]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", type=int, default=12)
    ap.add_argument("--large", type=int, default=1)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--budget-kib", type=int, default=220,
                    help="per-device budget; 220 KiB fits two 16^3 jobs "
                         "and forces the 32^3 jobs out-of-core")
    ap.add_argument("--pods", type=int, default=2,
                    help="pods in the multi-pod section (0 skips it)")
    ap.add_argument("--devices-per-pod", type=int, default=1,
                    help="slots per pod; keep pods*devices_per_pod <= "
                         "physical cores so the wall-clock comparison is "
                         "honest (oversubscribed worker threads hide the "
                         "idle capacity stealing exists to reclaim)")
    ap.add_argument("--mp-budget-kib", type=int, default=800,
                    help="per-device budget in the multi-pod section: 800 "
                         "KiB holds one 32^3 job resident per device")
    ap.add_argument("--bursts", type=int, default=3,
                    help="bursts in the autoscaling trace (0 skips it)")
    ap.add_argument("--jobs-per-burst", type=int, default=6)
    ap.add_argument("--gap-seconds", type=float, default=2.0,
                    help="idle gap between bursts — the capacity the "
                         "autoscaler reclaims")
    ap.add_argument("--max-pods", type=int, default=3,
                    help="static fleet size / autoscaler ceiling in the "
                         "bursty section")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end trace for CI: asserts the "
                         "serving + autoscaling paths run to completion, "
                         "prints SMOKE OK")
    ap.add_argument("--json", default="", dest="json_out",
                    help="write every section's summaries as machine-"
                         "readable JSON here ('-' for stdout)")
    ap.add_argument("--trace", default="",
                    help="enable tracing and write a Chrome-trace JSON of "
                         "the whole benchmark here (per-pod process "
                         "tracks; see docs/observability.md)")
    args = ap.parse_args(argv)

    if args.trace:
        from repro import obs
        obs.get_tracer().enable()

    if args.smoke:
        sections = smoke_main()
        if args.json_out:
            env = schema.envelope(
                "serve",
                config={"smoke": True, "devices": 2, "budget_kib": 220,
                        "bursts": 2, "jobs_per_burst": 3, "max_pods": 2},
                metrics=_doc_metrics(sections), smoke=True, **sections)
            _attach_observability(env, bool(args.trace))
            _write_json(env, args.json_out)
        if args.trace:
            from repro import obs
            obs.write_chrome_trace(args.trace)
            print(f"# chrome trace -> {args.trace}")
        return

    # Unmeasured warm-up pass: the scheduler's shared operator cache (and
    # jit compilation) is populated once, so all measured configurations
    # run at the steady-state cost a long-lived serving process sees.
    # Without this, whichever configuration runs first pays all compiles.
    run_config("warmup", make_workload(args.small, args.large),
               args.devices, args.budget_kib)

    results = {}
    for name, ndev, threaded in CONFIGS:
        jobs = make_workload(args.small, args.large)
        results[name] = run_config(name, jobs, ndev or args.devices,
                                   args.budget_kib, threaded=threaded)

    print("config,devices,jobs,steps,streamed,wall_s,modeled_makespan_s,"
          "jobs_per_sec_wall,jobs_per_sec_modeled,latency_p50_s,"
          "latency_p95_s")
    for name, ndev, _ in CONFIGS:
        s = results[name]
        print(f"{name},{ndev or args.devices},{s['completed']},{s['steps']},"
              f"{s['streamed_jobs']},{s['wall_seconds']:.2f},"
              f"{s['modeled_makespan_seconds']:.2f},"
              f"{s['jobs_per_sec_wall']:.3f},"
              f"{s['jobs_per_sec_modeled']:.3f},{s['latency_p50']:.2f},"
              f"{s['latency_p95']:.2f}")
    packed_speedup = (results["cooperative"]["jobs_per_sec_modeled"]
                      / max(results["serial"]["jobs_per_sec_modeled"], 1e-12))
    threaded_speedup = (results["threaded"]["jobs_per_sec_wall"]
                        / max(results["cooperative"]["jobs_per_sec_wall"],
                              1e-12))
    p95_ratio = (results["cooperative"]["latency_p95"]
                 / max(results["threaded"]["latency_p95"], 1e-12))
    print(f"# cooperative vs serial (modeled device-parallel jobs/sec): "
          f"{packed_speedup:.2f}x")
    print(f"# threaded vs cooperative (WALL jobs/sec): "
          f"{threaded_speedup:.2f}x; p95 latency {p95_ratio:.2f}x lower")

    sections = {"configs": results, "multipod": {}, "bursty": {}}
    if args.pods >= 2:
        n_mp_jobs = args.small + args.large
        # separate warm-up: the shared operator cache keys on the memory
        # model, so the multi-pod budget needs its own compile pass
        run_config("mp-warmup", make_multipod_workload(2), 1,
                   args.mp_budget_kib)
        print("\nconfig,pods,jobs,stolen,wall_s,jobs_per_sec_wall,"
              "latency_p95_s")
        mp = {}
        for name, steal in (("static", False), ("stealing", True)):
            jobs = make_multipod_workload(n_mp_jobs)
            mp[name] = run_multipod(name, jobs, args.pods,
                                    args.devices_per_pod,
                                    args.mp_budget_kib, steal=steal)
            s = mp[name]
            print(f"{name},{args.pods},{s['completed']},"
                  f"{s['stolen_in']},{s['wall_seconds']:.2f},"
                  f"{s['jobs_per_sec_wall']:.3f},{s['latency_p95']:.2f}")
        steal_speedup = (mp["stealing"]["jobs_per_sec_wall"]
                         / max(mp["static"]["jobs_per_sec_wall"], 1e-12))
        print(f"# stealing vs static partitioning (WALL jobs/sec, "
              f"imbalanced arrivals): {steal_speedup:.2f}x; "
              f"{mp['stealing']['stolen_in']} jobs stolen, "
              f"{mp['stealing'].get('stolen_verified', 0)} verified "
              f"bit-identical to unstolen runs")
        sections["multipod"] = mp

    if args.bursts >= 1 and args.max_pods >= 2:
        sections["bursty"] = bursty_section(args)

    sections["zero_loss"] = zero_loss_section()

    if args.json_out:
        env = schema.envelope(
            "serve",
            config={"small": args.small, "large": args.large,
                    "devices": args.devices,
                    "budget_kib": args.budget_kib, "pods": args.pods,
                    "mp_budget_kib": args.mp_budget_kib,
                    "bursts": args.bursts, "max_pods": args.max_pods},
            metrics=_doc_metrics(sections), smoke=False, **sections)
        _attach_observability(env, bool(args.trace))
        _write_json(env, args.json_out)
    if args.trace:
        from repro import obs
        obs.write_chrome_trace(args.trace)
        print(f"# chrome trace -> {args.trace}")


if __name__ == "__main__":
    main()
