"""Paper SS2.3: the halo-depth (N_in) trade-off for the split TV
regulariser.

Deeper halos buy more independent inner iterations between synchronisations
(fewer ppermute rounds) at the cost of redundant boundary compute; the
paper found N_in = 60 optimal on PCIe.  We sweep N_in on the host mesh and
report sync counts, redundant-compute fraction, and wall time -- on ICI
(50 GB/s links vs PCIe's 12) the optimum shifts to much shallower halos;
see EXPERIMENTS.md."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.regularization import dist_minimize_tv, halo_overhead, \
    minimize_tv


def run(shape=(64, 48, 48), n_iters: int = 24,
        halo_depths=(1, 2, 4, 8, 12)):
    from repro.core.compat import make_mesh
    n = jax.local_device_count()
    mesh = make_mesh((1, n), ("data", "model"))
    vol = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    want = minimize_tv(vol, hyper=0.1, n_iters=n_iters)
    rows: List[Dict] = []
    planes_local = shape[0] // n
    for d in halo_depths:
        fn = dist_minimize_tv(mesh, hyper=0.1, n_iters=n_iters, n_inner=d,
                              approx_norm=False)
        with mesh:
            fn(vol).block_until_ready()            # compile
            t0 = time.monotonic()
            got = fn(vol)
            got.block_until_ready()
            dt = time.monotonic() - t0
        err = float(jnp.max(jnp.abs(got - want)))
        rows.append({"n_inner": d, "syncs": -(-n_iters // d),
                     "overhead": halo_overhead(planes_local, d),
                     "seconds": dt, "max_abs_err": err})
    return rows


def main():
    rows = run()
    print("n_inner,syncs,redundant_compute_frac,seconds,max_abs_err")
    for r in rows:
        print(f"{r['n_inner']},{r['syncs']},{r['overhead']:.3f},"
              f"{r['seconds']:.4f},{r['max_abs_err']:.2e}")


if __name__ == "__main__":
    main()
