"""Perf-iteration probe: lower ONE (arch x shape) cell with optional
variant flags and print the three roofline terms -- the measurement tool
for the EXPERIMENTS.md SSPerf hypothesis loop.

    PYTHONPATH=src python -m benchmarks.perf_probe --arch xlstm-350m \
        --shape train_4k --flag mlstm_chunked=1
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_backend_optimization_level=0 "
    "--xla_llvm_disable_expensive_passes=true")

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--flag", action="append", default=[],
                    help="k=v perf flags (repro.models.perf.FLAGS)")
    ap.add_argument("--replace", action="append", default=[],
                    help="k=v ArchConfig overrides (bool/int only)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="data,model override (same 256 chips)")
    args = ap.parse_args()

    from repro.models import perf
    for kv in args.flag:
        k, v = kv.split("=")
        perf.FLAGS[k] = type(perf.FLAGS.get(k, ""))(int(v)) \
            if isinstance(perf.FLAGS.get(k), (bool, int)) else v
        if isinstance(perf.FLAGS.get(k), bool) or v in ("0", "1"):
            perf.FLAGS[k] = bool(int(v))
    print("flags:", perf.FLAGS)

    from repro.launch.dryrun import dryrun_cell
    from repro.launch.mesh import make_production_mesh

    overrides = {}
    for kv in args.replace:
        k, v = kv.split("=")
        overrides[k] = bool(int(v))
    if args.mesh:
        from repro.core.compat import make_mesh
        d, m = (int(v) for v in args.mesh.split(","))
        mesh = make_mesh((d, m), ("data", "model"))
        n_chips = d * m
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        n_chips = 512 if args.multi_pod else 256
    t0 = time.time()
    row = dryrun_cell(args.arch, args.shape, mesh, n_chips,
                      cfg_overrides=overrides or None)
    for k in ("t_compute_s", "t_memory_s", "t_collective_s", "bottleneck",
              "flops_per_dev", "coll_bytes_per_dev", "useful_ratio",
              "roofline_fraction", "peak_bytes_per_device", "coll_detail"):
        print(f"  {k}: {row.get(k)}")
    print(f"(total {time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
