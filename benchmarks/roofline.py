"""Roofline reader: renders the dry-run JSON reports into the
EXPERIMENTS.md SSRoofline table (all three terms, bottleneck, useful
ratio, roofline fraction)."""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List


def load(path: str) -> List[Dict]:
    with open(path) as f:
        return json.load(f)["rows"]


def fmt_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bound | useful | roofline-frac | peak GiB/dev |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                       f"skip: {r['reason']} | -- | -- | -- |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | "
                       f"{r.get('error', '?')} | | | |")
            continue
        ur = r.get("useful_ratio")
        rf = r.get("roofline_fraction")
        row = (f"| {r['arch']} | {r['shape']} "
               f"| {1e3 * r['t_compute_s']:.2f} "
               f"| {1e3 * r['t_memory_s']:.2f} "
               f"| {1e3 * r['t_collective_s']:.2f} "
               f"| {r['bottleneck']} ")
        row += f"| {ur:.2f} " if ur is not None else "| ? "
        row += f"| {rf:.3f} " if rf is not None else "| ? "
        row += f"| {r['peak_bytes_per_device'] / 2**30:.2f} |"
        out.append(row)
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default="experiments/dryrun_single.json")
    args = ap.parse_args(argv)
    if not os.path.exists(args.report):
        print(f"# roofline: no report at {args.report} "
              "(run repro.launch.dryrun first)")
        return
    print(fmt_table(load(args.report)))


if __name__ == "__main__":
    main()
