"""Benchmark umbrella: one section per paper table/figure.

Must be launched as ``PYTHONPATH=src python -m benchmarks.run``; it forces
4 host devices (the paper's 1-4 GPU axis) before jax initialises --
scoped to this process only, never to tests.
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse
import time


def _section(title):
    print(f"\n=== {title} ===", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sizes for CI-speed runs (runs the "
                         "argv-driven benches in --smoke mode)")
    args = ap.parse_args()
    t0 = time.time()

    from benchmarks import (bench_breakdown, bench_kernels, bench_limits,
                            bench_operators, bench_recon, bench_scaling,
                            bench_serve, bench_tv_halo, roofline)

    # benches with their own CLI get an explicit argv — never the
    # umbrella's sys.argv, which carries --fast they don't know
    fast_argv = ["--smoke"] if args.fast else []

    _section("Fig 7/8: FP/BP scaling vs N and device count "
             "(bench_scaling)")
    bench_scaling.main(list(fast_argv))

    _section("Fig 9: time breakdown compute/staging/other "
             "(bench_breakdown)")
    bench_breakdown.main()

    _section("SS3.2: end-to-end recon, plain vs out-of-core "
             "(bench_recon)")
    bench_recon.main()

    _section("SS2.3: TV halo-depth (N_in) trade-off (bench_tv_halo)")
    bench_tv_halo.main()

    _section("SS4: single-device size limits (bench_limits)")
    bench_limits.main()

    _section("Pallas kernels vs oracles (bench_kernels)")
    bench_kernels.main()

    _section("Ref-vs-Pallas operator throughput (bench_operators)")
    bench_operators.main(list(fast_argv))

    _section("Multi-tenant serving: packing/threading/stealing/"
             "autoscaling (bench_serve)")
    bench_serve.main(list(fast_argv))

    _section("Roofline table from the dry-run report (roofline)")
    roofline.main([])

    print(f"\n=== benchmarks done in {time.time() - t0:.0f}s ===")


if __name__ == "__main__":
    main()
