"""Shared result envelope for the benchmark ``--json`` outputs.

Every bench historically invented its own JSON shape, which made the
outputs machine-readable but not machine-*comparable* — nothing could
diff two runs without knowing each bench's private layout.  This module
defines the one envelope they all emit (and keep their legacy sections
inside, so older readers keep working):

.. code-block:: json

    {
      "schema": 1,
      "bench": "serve",
      "smoke": true,
      "config": {"devices": 4, "...": "..."},
      "metrics": [
        {"name": "threaded.jobs_per_sec_wall", "value": 3.1,
         "units": "jobs/s", "direction": "higher", "repeats": 1}
      ],
      "...": "legacy bench-specific sections ride along"
    }

``direction`` says which way is better, so a tracker
(:mod:`tools.bench_track`) can decide regression-vs-improvement without
a per-metric table.  Pure stdlib; importable both as
``benchmarks.schema`` (umbrella) and ``schema`` (script next door).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional

SCHEMA_VERSION = 1

DIRECTIONS = ("higher", "lower")


def metric(name: str, value: float, units: str,
           direction: str = "lower", repeats: int = 1) -> Dict:
    """One named measurement.  ``direction`` is which way is *better*."""
    if direction not in DIRECTIONS:
        raise ValueError(f"direction must be one of {DIRECTIONS}, "
                         f"got {direction!r}")
    v = float(value)
    if not math.isfinite(v):
        raise ValueError(f"metric {name!r}: value {value!r} is not finite")
    return {"name": str(name), "value": v, "units": str(units),
            "direction": direction, "repeats": int(repeats)}


def envelope(bench: str, config: Dict, metrics: List[Dict],
             smoke: bool = False, **extra) -> Dict:
    """The unified result document; ``extra`` carries each bench's
    legacy sections (``rows``, ``configs``, ...) unchanged."""
    doc = {"schema": SCHEMA_VERSION, "bench": str(bench),
           "smoke": bool(smoke), "config": dict(config),
           "metrics": [metric(**m) if not _is_metric(m) else m
                       for m in metrics]}
    for k, v in extra.items():
        if k in doc:
            raise ValueError(f"extra section {k!r} collides with an "
                             f"envelope field")
        doc[k] = v
    return doc


def _is_metric(m) -> bool:
    return (isinstance(m, dict)
            and {"name", "value", "units", "direction",
                 "repeats"} <= set(m))


def validate_envelope(doc: Dict) -> List[str]:
    """Structural check; returns a list of problems (empty = valid)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["envelope is not a JSON object"]
    if doc.get("schema") != SCHEMA_VERSION:
        errs.append(f"schema != {SCHEMA_VERSION}: {doc.get('schema')!r}")
    if not isinstance(doc.get("bench"), str) or not doc.get("bench"):
        errs.append("missing/empty 'bench'")
    if not isinstance(doc.get("config"), dict):
        errs.append("'config' must be an object")
    ms = doc.get("metrics")
    if not isinstance(ms, list):
        errs.append("'metrics' must be a list")
        return errs
    seen = set()
    for i, m in enumerate(ms):
        if not _is_metric(m):
            errs.append(f"metrics[{i}] missing required fields")
            continue
        if m["direction"] not in DIRECTIONS:
            errs.append(f"metrics[{i}] bad direction {m['direction']!r}")
        if not isinstance(m["value"], (int, float)) \
                or not math.isfinite(float(m["value"])):
            errs.append(f"metrics[{i}] non-finite value {m['value']!r}")
        if m["name"] in seen:
            errs.append(f"duplicate metric name {m['name']!r}")
        seen.add(m["name"])
    return errs


def metric_values(doc: Dict) -> Dict[str, Dict]:
    """name -> metric dict, for comparison tooling."""
    return {m["name"]: m for m in doc.get("metrics", [])
            if _is_metric(m)}


def merge_envelopes(docs: List[Dict],
                    bench: Optional[str] = None) -> Dict:
    """Combine several bench envelopes into one trajectory-point payload
    (metric names are prefixed ``<bench>.`` to stay unique)."""
    metrics: List[Dict] = []
    config: Dict = {}
    for d in docs:
        b = d.get("bench", "?")
        config[b] = d.get("config", {})
        for m in d.get("metrics", []):
            if _is_metric(m):
                metrics.append({**m, "name": f"{b}.{m['name']}"})
    return envelope(bench or "combined", config, metrics,
                    smoke=any(d.get("smoke") for d in docs))
