"""The paper's headline feature: reconstruct a volume that does NOT fit
in device memory.

We simulate a 1 MiB-device memory budget -- the 96^3 fp32 volume (3.4 MiB)
plus projections cannot fit, so the planner splits it into axial slabs and
the double-buffered executor streams them (paper Alg 1/2, Fig 3/5).  The
result is bit-compatible with the in-memory operator.

    PYTHONPATH=src python examples/large_volume_streaming.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.operator import CTOperator
from repro.core.splitting import MemoryModel, plan_backward, plan_forward
from repro.core.streaming import Timeline
from repro.core import phantoms
from repro.core.algorithms import ossart


def main():
    n = 96
    geo = ConeGeometry.nice(n)
    angles = circular_angles(64)
    vol = phantoms.shepp_logan(geo)
    budget = MemoryModel(device_bytes=1 << 20, usable_fraction=1.0)

    fp_plan = plan_forward(geo, len(angles), 1, budget)
    bp_plan = plan_backward(geo, len(angles), 1, budget)
    print(f"volume: {n}^3 fp32 = {n**3 * 4 / 2**20:.1f} MiB; "
          f"device budget: 1.0 MiB")
    print(f"FP plan: {fp_plan.n_slabs} slabs of "
          f"~{fp_plan.slab_ranges[0][1]} planes, "
          f"angle chunk {fp_plan.angle_chunk}")
    print(f"BP plan: {bp_plan.n_slabs} slabs, "
          f"angle chunk {bp_plan.angle_chunk}")

    op = CTOperator(geo, angles, mode="stream", memory=budget)
    proj = op.A(vol)
    print("forward projected out-of-core:", proj.shape)

    rec = ossart(proj, geo, angles, n_iter=2, subset_size=16, op=op,
                 bp_weight="fdk")
    rel = float(np.linalg.norm(np.asarray(rec) - vol)
                / np.linalg.norm(vol))
    print(f"OS-SART(2) out-of-core rel. error: {rel:.4f}")

    # reference: same algorithm fully in memory
    rec_ref = ossart(jnp.asarray(proj), geo, angles, n_iter=2,
                     subset_size=16, bp_weight="fdk")
    diff = float(np.max(np.abs(np.asarray(rec) - np.asarray(rec_ref))))
    print(f"max |out-of-core - in-memory| = {diff:.2e}  "
          "(the paper's exactness claim)")


if __name__ == "__main__":
    main()
