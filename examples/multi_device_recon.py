"""Multi-device distributed reconstruction via shard_map (paper's
multi-GPU layer as a TPU mesh).

Runs on emulated CPU devices; on a real pod the same code runs on the
(16, 16) production mesh (see repro.launch.mesh / dryrun).

    PYTHONPATH=src python examples/multi_device_recon.py
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp


def main():
    from repro.core import phantoms
    from repro.core.algorithms import ossart
    from repro.core.geometry import ConeGeometry, circular_angles
    from repro.core.operator import CTOperator
    from repro.core.regularization import dist_minimize_tv

    from repro.core.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {len(mesh.devices.flat)} devices")

    geo = ConeGeometry.nice(64)
    angles = circular_angles(64)
    vol = phantoms.shepp_logan(geo)
    from repro.core.projector import forward_project
    proj = forward_project(jnp.asarray(vol), geo, angles)

    op = CTOperator(geo, angles, mode="dist", mesh=mesh)
    with mesh:
        rec = ossart(proj, geo, angles, n_iter=2, subset_size=16, op=op)
        # halo-split TV smoothing pass (paper SS2.3)
        rec = dist_minimize_tv(mesh, hyper=0.05, n_iters=8, n_inner=4)(rec)
    rel = float(np.linalg.norm(np.asarray(rec) - vol)
                / np.linalg.norm(vol))
    print(f"distributed OS-SART + TV rel. error: {rel:.4f}")


if __name__ == "__main__":
    main()
