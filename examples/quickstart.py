"""Quickstart: simulate a cone-beam scan and reconstruct it three ways
(FDK, CGLS, OS-SART) with the plain in-memory backend.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import phantoms
from repro.core.algorithms import cgls, fdk, ossart
from repro.core.geometry import ConeGeometry, circular_angles


def main():
    # 64^3 volume, 64x64 detector, 96 angles -- laptop scale
    geo = ConeGeometry.nice(64)
    angles = circular_angles(96)
    vol = phantoms.shepp_logan(geo)
    print("simulating projections...")
    from repro.core.projector import forward_project
    proj = forward_project(jnp.asarray(vol), geo, angles)

    for name, rec in (
        ("FDK", fdk(proj, geo, angles)),
        ("CGLS(8)", cgls(proj, geo, angles, n_iter=8)),
        ("OS-SART(3)", ossart(proj, geo, angles, n_iter=3,
                              subset_size=12)),
    ):
        rel = float(np.linalg.norm(np.asarray(rec) - vol)
                    / np.linalg.norm(vol))
        print(f"{name:12s} rel. error vs phantom: {rel:.4f}")


if __name__ == "__main__":
    main()
