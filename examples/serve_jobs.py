"""Multi-tenant serving walkthrough (companion to ``docs/serve.md``).

Three tenants share a small-memory device pool:

* an *urgent* CGLS job (priority 5) — placed first, may preempt others;
* a *batch* OS-SART job (priority 0) — fills leftover capacity;
* an *oversized* OS-SART job whose volume does not fit a device — the
  scheduler routes it through the paper's out-of-core streaming path
  instead of rejecting it.

The default run drives one scheduler with the threaded ``AsyncDriver``
(one worker thread per device, so both simulated devices step their
resident jobs concurrently).  With ``--pods 2`` the same tenants are
served by a *fleet*: every job is pinned to pod 0 (tenant affinity), and
the idle pod steals parked work through the checkpoint-transfer protocol
— the printout then shows which pod each job actually completed on and
how many jobs moved.

With ``--autoscale`` the fleet is *elastic*: it starts as a single seed
pod and an ``Autoscaler`` grows it from a PodSpec template pool while
the modeled backlog is high, then drains and retires surplus pods once
the work is done — the printout shows every scale event and the
pod-seconds the elasticity saved versus keeping the peak fleet up.

Any variant can be *observed* live: ``--metrics-port 0`` enables
tracing, serves the full Prometheus exposition (tracer + calibration +
SLO families) over HTTP for the duration of the run, and prints the
calibration verdict at the end — how many modeled-vs-measured samples
the cost models produced, which pods (if any) drifted stale, and the
per-priority deadline attainment.

    PYTHONPATH=src python examples/serve_jobs.py
    PYTHONPATH=src python examples/serve_jobs.py --pods 2
    PYTHONPATH=src python examples/serve_jobs.py --autoscale
    PYTHONPATH=src python examples/serve_jobs.py --metrics-port 0
    PYTHONPATH=src python examples/serve_jobs.py --help
"""

import argparse
import tempfile
import time

import numpy as np

from repro import obs
from repro.core import phantoms
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel
from repro.serve import (AsyncDriver, Autoscaler, AutoscalePolicy,
                         MultiPodDriver, MultiPodScheduler, Pod, PodSpec,
                         ReconJob, Scheduler)

KIB = 1024


def build_jobs(iters: int):
    """The three tenants' jobs plus the ground-truth volumes used for
    the accuracy column in the report."""
    # -- small acquisition: a 16^3 sphere phantom, 12 projection angles.
    #    ~84 KiB resident footprint => two such jobs share one 220 KiB
    #    device.
    geo = ConeGeometry.nice(16)
    angles = circular_angles(12)
    vol = phantoms.sphere(geo)
    proj = phantoms.sphere_projection_analytic(geo, angles)

    # -- large acquisition: 32^3.  Its in-core footprint exceeds the
    #    device budget, so the planners will route it out-of-core
    #    (JobRecord.streamed becomes True).
    big_geo = ConeGeometry.nice(32)
    big_angles = circular_angles(16)
    big_vol = phantoms.sphere(big_geo)
    big_proj = phantoms.sphere_projection_analytic(big_geo, big_angles)

    jobs = {
        "urgent-cgls": ReconJob("cgls", geo, angles, proj,
                                n_iter=2 * iters, priority=5),
        "batch-ossart": ReconJob("ossart", geo, angles, proj,
                                 n_iter=iters, priority=0,
                                 params={"subset_size": 6}),
        "oversized-ossart": ReconJob("ossart", big_geo, big_angles,
                                     big_proj, n_iter=1, priority=1,
                                     params={"subset_size": 16}),
    }
    truth = {"urgent-cgls": vol, "batch-ossart": vol,
             "oversized-ossart": big_vol}
    return jobs, truth


def report(name, rec, truth, pod=""):
    """One line per job: placement, streaming route, status, accuracy."""
    rel = float(np.linalg.norm(rec.result - truth)
                / np.linalg.norm(truth))
    where = f"{pod + ':' if pod else ''}dev{rec.device}"
    print(f"{name:18s} {where:8s} streamed={rec.streamed!s:5s} "
          f"iters={rec.iterations_done} status={rec.status.value:9s} "
          f"rel_err={rel:.3f}")


def run_single_pool(jobs, truth, args):
    """docs/serve.md 'Execution model': one Scheduler, one AsyncDriver."""
    # The pool is *simulated* (slots with a byte budget only): placement
    # logic is identical to a real multi-GPU pool, which is how a laptop
    # demos the serving layer.
    sched = Scheduler(n_devices=args.devices,
                      memory=MemoryModel(device_bytes=args.budget_kib * KIB,
                                         usable_fraction=1.0),
                      name="pool")
    jids = {name: sched.submit(job) for name, job in jobs.items()}

    # AsyncDriver.run() = start worker threads, wait idle, stop.  Steps
    # overlap across devices; admission/preemption run on a background
    # scheduler thread (see docs/serve.md "Threading model").
    AsyncDriver(sched).run()

    for name, jid in jids.items():
        report(name, sched.records[jid], truth[name])
    s = sched.summary()
    print(f"\n{s['completed']} jobs, {s['steps']} interleaved steps, "
          f"modeled makespan {s['modeled_makespan_seconds']:.2f}s "
          f"(device busy: "
          f"{['%.2f' % b for b in s['device_busy_seconds']]}), "
          f"p95 latency {s['latency_p95']:.2f}s")


def run_pod_fleet(jobs, truth, args):
    """docs/serve.md 'Multi-pod fleets': one scheduler per pod, idle
    pods steal parked jobs (checkpoint -> manifest+COMMIT transfer ->
    bit-identical resume on the thief)."""
    # The *same* device count as the single-pool run, split into host
    # groups — e.g. --devices 2 --pods 2 is two one-device pods.  Pod 0
    # can then hold fewer tenants resident, parks the surplus, and the
    # idle pod steals it.
    devices_per_pod = max(1, args.devices // args.pods)
    pods = [Pod(PodSpec(f"pod{i}", n_devices=devices_per_pod,
                        memory=MemoryModel(
                            device_bytes=args.budget_kib * KIB,
                            usable_fraction=1.0)))
            for i in range(args.pods)]
    mps = MultiPodScheduler(pods,
                            transfer_dir=tempfile.mkdtemp(prefix="steal-"))

    # Pin every tenant to pod 0 — the static-partitioning arrival
    # pattern.  Without stealing, pod 1+ would idle; with it, parked
    # jobs migrate and the printout shows where each one really ran.
    jids = {name: mps.submit(job, pod=0) for name, job in jobs.items()}

    MultiPodDriver(mps).run()

    for name, jid in jids.items():
        report(name, mps.record(jid), truth[name], pod=mps.owner(jid).name)
    s = mps.summary()
    print(f"\n{s['completed']} jobs over {args.pods} pods, "
          f"{s['jobs_stolen']} stolen "
          f"(all submitted to pod0), fleet makespan "
          f"{s['modeled_makespan_seconds']:.2f}s, "
          f"p95 latency {s['latency_p95']:.2f}s")


def run_autoscaled_fleet(jobs, truth, args):
    """docs/serve.md 'Elastic fleets': start with one seed pod; the
    Autoscaler adds pods from a template pool while the modeled backlog
    is above the band, and drains + retires them (preempt -> export ->
    bit-identical resume on a survivor) once it falls below."""
    mem = MemoryModel(device_bytes=args.budget_kib * KIB,
                      usable_fraction=1.0)
    mps = MultiPodScheduler([Pod(PodSpec("seed", n_devices=1, memory=mem))],
                            transfer_dir=tempfile.mkdtemp(prefix="steal-"))
    # The policy is the whole knob surface: the backlog band (modeled
    # seconds per device), the persistence windows (hysteresis), the
    # cooldown between events (thrash guard) and the min/max fleet size.
    asc = Autoscaler(
        mps,
        templates=[PodSpec("burst", n_devices=1, memory=mem)],
        policy=AutoscalePolicy(scale_up_backlog_seconds=0.5,
                               scale_down_backlog_seconds=0.05,
                               down_window_seconds=0.1,
                               cooldown_seconds=0.1,
                               min_pods=1, max_pods=args.devices))
    driver = MultiPodDriver(mps, autoscaler=asc)
    driver.start()
    jids = {name: mps.submit(job) for name, job in jobs.items()}
    driver.wait(timeout=600)
    # give the autoscaler a beat to reclaim the now-idle burst pods
    tail = time.monotonic() + 2.0
    while len(mps.pods) > 1 and time.monotonic() < tail:
        time.sleep(0.02)
    driver.stop()

    for name, jid in jids.items():
        report(name, mps.record(jid), truth[name], pod=mps.owner(jid).name)
    for ev in asc.events:
        print(f"scale_{ev.direction:4s} {ev.pod:12s} "
              f"(backlog {ev.load:.2f}s/device -> {ev.n_pods} pods)")
    s = mps.summary()
    peak = s["pods_online_peak"]
    print(f"\n{s['completed']} jobs, peak {peak} pods, "
          f"{s['scale_up_events']} up / {s['scale_down_events']} down, "
          f"{len(asc.drained_jobs)} jobs moved by drains; "
          f"{s['pod_seconds']:.2f} pod-seconds vs "
          f"{peak * s['wall_seconds']:.2f} for a static peak fleet")


def calibration_verdict():
    """The cost-model report card the observability layer distills from
    the run's fleet events (docs/observability.md 'Calibration ledger')."""
    led = obs.CalibrationLedger.from_events()
    kinds = led.samples_by_kind()
    stale = led.stale_pods()
    print(f"\ncalibration: "
          + ", ".join(f"{k}={kinds[k]}" for k in sorted(kinds))
          + " modeled-vs-measured samples; "
          + (f"stale pods: {stale}" if stale
             else "no pod drifted past the threshold"))
    rep = obs.slo_report()
    print(f"SLO: overall deadline attainment "
          f"{rep['overall_attainment']:.0%} "
          f"({rep['deadline_jobs']} jobs declared one); per tier: "
          + ", ".join(f"p{t['priority']} lat_p95="
                      f"{t['latency_p95_s']:.2f}s" for t in rep["tiers"]))


def main():
    ap = argparse.ArgumentParser(
        description="Multi-tenant serving demo: three tenants (urgent / "
                    "batch / oversized-streaming) share a small-memory "
                    "pool; see docs/serve.md for the architecture this "
                    "walks through.")
    ap.add_argument("--devices", type=int, default=2,
                    help="simulated device slots in total (split across "
                         "pods with --pods > 1); each slot has its own "
                         "worker thread under the threaded driver")
    ap.add_argument("--budget-kib", type=int, default=220,
                    help="per-device memory budget in KiB; 220 holds two "
                         "16^3 jobs resident and forces the 32^3 job "
                         "through the out-of-core streaming path")
    ap.add_argument("--iters", type=int, default=3,
                    help="outer-iteration budget of the batch job (the "
                         "urgent job gets 2x this, the streamed job 1)")
    ap.add_argument("--pods", type=int, default=1,
                    help="1 = single scheduler (AsyncDriver); >1 = pod "
                         "fleet with every tenant pinned to pod 0 so "
                         "work stealing visibly rebalances the jobs")
    ap.add_argument("--autoscale", action="store_true",
                    help="serve through an elastic fleet instead: one "
                         "seed pod, grown up to --devices pods by the "
                         "Autoscaler while the backlog is high, drained "
                         "back down when it clears (see docs/serve.md "
                         "'Elastic fleets')")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="enable tracing and serve the live Prometheus "
                         "metrics (tracer + calibration + SLO families) "
                         "on this port for the whole run; 0 picks a free "
                         "port; also prints the calibration verdict at "
                         "the end")
    args = ap.parse_args()

    server = None
    if args.metrics_port >= 0:
        obs.get_tracer().enable()
        server = obs.MetricsServer(port=args.metrics_port)
        server.start()
        print(f"live metrics at {server.url} (scrape while it runs)\n")

    jobs, truth = build_jobs(args.iters)
    try:
        if args.autoscale:
            run_autoscaled_fleet(jobs, truth, args)
        elif args.pods > 1:
            run_pod_fleet(jobs, truth, args)
        else:
            run_single_pool(jobs, truth, args)
        if server is not None:
            calibration_verdict()
    finally:
        if server is not None:
            server.stop()
            obs.get_tracer().disable()


if __name__ == "__main__":
    main()
