"""Multi-tenant serving demo: three tenants share a small-memory pool.

Submits a mix of reconstruction jobs -- two small in-core jobs with
different priorities and one volume too large for a device (routed through
the paper's out-of-core streaming path) -- to the ``repro.serve``
scheduler, drives them with the threaded ``AsyncDriver`` (one worker
thread per device, so both simulated devices step their resident jobs
concurrently), then prints per-job placement, status and accuracy.

    PYTHONPATH=src python examples/serve_jobs.py
"""

import numpy as np

from repro.core import phantoms
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel
from repro.serve import AsyncDriver, ReconJob, Scheduler


def main():
    geo = ConeGeometry.nice(16)
    angles = circular_angles(12)
    vol = phantoms.sphere(geo)
    proj = phantoms.sphere_projection_analytic(geo, angles)

    big_geo = ConeGeometry.nice(32)
    big_angles = circular_angles(16)
    big_vol = phantoms.sphere(big_geo)
    big_proj = phantoms.sphere_projection_analytic(big_geo, big_angles)

    # two simulated 220 KiB devices: a 16^3 job is resident (~84 KiB),
    # a 32^3 job is not and must stream
    sched = Scheduler(n_devices=2,
                      memory=MemoryModel(device_bytes=220 * 1024,
                                         usable_fraction=1.0))
    jobs = {
        "urgent-cgls": sched.submit(ReconJob(
            "cgls", geo, angles, proj, n_iter=4, priority=5)),
        "batch-ossart": sched.submit(ReconJob(
            "ossart", geo, angles, proj, n_iter=3, priority=0,
            params={"subset_size": 6})),
        "oversized-ossart": sched.submit(ReconJob(
            "ossart", big_geo, big_angles, big_proj, n_iter=1, priority=1,
            params={"subset_size": 16})),
    }
    AsyncDriver(sched).run()

    truth = {"urgent-cgls": vol, "batch-ossart": vol,
             "oversized-ossart": big_vol}
    for name, jid in jobs.items():
        rec = sched.records[jid]
        t = truth[name]
        rel = float(np.linalg.norm(rec.result - t) / np.linalg.norm(t))
        print(f"{name:18s} dev={rec.device} streamed={rec.streamed!s:5s} "
              f"iters={rec.iterations_done} status={rec.status.value:9s} "
              f"rel_err={rel:.3f}")
    s = sched.summary()
    print(f"\n{s['completed']} jobs, {s['steps']} interleaved steps, "
          f"modeled makespan {s['modeled_makespan_seconds']:.2f}s "
          f"(device busy: "
          f"{['%.2f' % b for b in s['device_busy_seconds']]}), "
          f"p95 latency {s['latency_p95']:.2f}s")


if __name__ == "__main__":
    main()
