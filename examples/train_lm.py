"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a few
hundred steps on the synthetic pipeline, with checkpointing and fault
tolerance wired in.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The ~100M config is a scaled stablelm-family decoder; on CPU this takes a
while -- pass --small for a quick look.
"""

import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    import repro.configs as C
    from repro.launch import train as T
    from repro.models.lm import ArchConfig

    # ~100M params: 8 layers, d=768, untied 32k vocab
    cfg = ArchConfig(
        name="lm-100m", family="dense", n_layers=8, d_model=768,
        n_heads=12, n_kv=12, d_ff=3072, vocab=32000, pattern=("attn",),
        sub_quadratic=False)
    if args.small:
        cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, n_heads=4,
                                  n_kv=4, d_ff=512, vocab=2048)

    # register so train() can find it
    class _Mod:
        CONFIG = cfg

        @staticmethod
        def reduced():
            return cfg

    C._MODULES[cfg.name] = _Mod
    import numpy as np
    import jax
    n_params = sum(
        int(np.prod(l.shape)) for l in jax.tree.leaves(jax.eval_shape(
            lambda k: __import__("repro.models.lm", fromlist=["make_model"])
            .make_model(cfg).init(k),
            jax.ShapeDtypeStruct((2,), jax.numpy.uint32))))
    print(f"training {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps")
    T.train(cfg.name, steps=args.steps, use_reduced=False,
            batch=8, seq=256 if not args.small else 64,
            ckpt_dir=args.ckpt_dir, ckpt_every=100, lr=6e-4,
            log_every=10)


if __name__ == "__main__":
    main()
