"""repro: arbitrarily-large iterative tomographic reconstruction on TPU pods.

A JAX/Pallas production-framework reproduction of

    Biguri et al., "Arbitrarily large iterative tomographic reconstruction
    on multiple GPUs using the TIGRE toolbox" (2019).

Layout
------
``repro.core``        the paper's contribution: geometry, projectors, the
                      slab-splitting planner, the double-buffered streaming
                      executor, distributed (shard_map) operators, and the
                      halo-split TV regularizers.
``repro.core.algorithms``  FDK, SIRT, SART, OS-SART, CGLS, FISTA, ASD-POCS.
``repro.kernels``     Pallas TPU kernels (fp_ray, bp_voxel, tv_grad,
                      flash_attention) + jnp oracles.
``repro.models``      assigned-architecture zoo (10 LM-family archs).
``repro.configs``     one config per architecture + CT defaults.
``repro.launch``      production mesh, multi-pod dry-run, train/recon drivers.
"""

__version__ = "0.1.0"
