"""Checkpoint substrate: sharded save/restore with a manifest, elastic
resharding on restore, async save, and a preemption (SIGTERM) hook."""

from .sharded import (CheckpointManager, save_checkpoint, restore_checkpoint,
                      latest_step, manifest_target)
from .preemption import PreemptionGuard

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "latest_step", "manifest_target", "PreemptionGuard"]
