"""Preemption handling: SIGTERM -> checkpoint-at-next-step-boundary.

Cloud TPU/TRN preemptions deliver SIGTERM with a grace window; the guard
flips a flag the train loop polls each step, triggering a final blocking
checkpoint + clean exit (tests simulate via ``guard.trigger()``)."""

from __future__ import annotations

import signal
import threading
from typing import Optional


class PreemptionGuard:
    def __init__(self, install_handler: bool = True):
        self._event = threading.Event()
        self._prev = None
        if install_handler:
            try:
                self._prev = signal.signal(signal.SIGTERM, self._on_sigterm)
            except ValueError:
                # not on the main thread (tests) -- manual trigger only
                self._prev = None

    def _on_sigterm(self, signum, frame):
        self._event.set()

    def trigger(self):
        """Manual trigger (tests / external watchdogs)."""
        self._event.set()

    @property
    def preempted(self) -> bool:
        return self._event.is_set()

    def uninstall(self):
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)
            self._prev = None
