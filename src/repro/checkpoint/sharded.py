"""Sharded checkpointing with manifest + elastic resharding.

Layout (one directory per step)::

    ckpt_dir/
      step_000100/
        manifest.json        # pytree structure, shapes, dtypes, shard map
        leaf_00000.npy       # one file per leaf (np.save, fp32/bf16-as-u16)
        ...
        COMMIT               # written last: crash-safe commit marker

Design points mirrored from production systems:

* **Atomic commit**: a checkpoint without ``COMMIT`` is ignored by
  ``latest_step`` -- a node failure mid-save can never corrupt restart.
* **Elastic resharding**: leaves are saved as *full* logical arrays (host
  gathers its addressable shards; on multi-host each host saves its own
  shard files and the manifest records the offsets -- here single-process
  saves the full array).  On restore, arrays are ``device_put`` against the
  *new* mesh/sharding, so restarting on a different device count or mesh
  shape works (tests/test_checkpoint.py).
* **Async save**: the save runs on a background thread off a snapshot of
  host arrays; the train loop only blocks on the previous save
  (double-buffered, the paper's overlap idea applied to checkpoint I/O).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def _leaf_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def _to_numpy(x) -> np.ndarray:
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16)
    return x


def _from_numpy(x: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == _BF16:
        return x.view(jnp.bfloat16)
    return x


def save_checkpoint(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    """Synchronous sharded save with atomic commit.

    Manifest format (``manifest.json``)::

        {"step": <int>,
         "leaves": {"<keystr>": {"file":  "leaf_00000.npy",
                                 "shape": [..],
                                 "dtype": "float32" | "bfloat16" | ...}}}

    ``<keystr>`` is ``jax.tree_util.keystr`` of the leaf's path (for the
    flat dicts the serving layer persists: ``"['angles']"``,
    ``"['state.x']"``, ...).  Leaves are written one ``.npy`` per entry
    in sorted-key order; bf16 is stored as its u16 bit pattern with the
    true dtype recorded here so restore can re-view it.  ``COMMIT`` is
    written last inside a ``.tmp`` directory that is atomically renamed
    into place — readers trust only directories containing COMMIT."""
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "leaves": {}}
    for i, (path, leaf) in enumerate(sorted(leaves.items())):
        arr = _to_numpy(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][path] = {
            "file": fname,
            "shape": list(np.shape(leaf)),
            "dtype": str(np.asarray(leaf).dtype) if not hasattr(leaf, "dtype")
            else str(leaf.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    _write_commit(tmp)
    _publish(tmp, out)
    _gc(ckpt_dir, keep)
    return out


def _write_commit(tmp: str) -> None:
    """Write the COMMIT marker into a fully-written ``.tmp`` step
    directory.  A separate function so crash-injection tests can kill
    exactly here: leaves + manifest on disk, marker absent — the
    directory must stay invisible to :func:`latest_step`."""
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write(str(time.time()))


def _publish(tmp: str, out: str) -> None:
    """Atomically publish a committed ``.tmp`` step directory under its
    final name.  A separate function so crash-injection tests can kill
    exactly here: the commit marker exists but only inside ``.tmp``,
    which readers ignore — the previous published step stays intact."""
    if os.path.exists(out):
        shutil.rmtree(out)
    os.replace(tmp, out)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def manifest_target(ckpt_dir: str, step: int) -> Dict[str, np.ndarray]:
    """Rebuild a zeros pytree from a saved checkpoint's manifest.

    ``restore_checkpoint`` validates shapes against a *target* tree, which
    a restarted process that lost its in-memory state cannot supply.  For
    checkpoints whose tree is a flat ``{name: array}`` dict (the serving
    layer's job checkpoints), the manifest alone determines the structure:
    every leaf path is ``['name']``, so the dict can be reconstructed with
    placeholder zeros of the recorded shape/dtype and fed back to
    ``restore_checkpoint``.
    """
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    out: Dict[str, np.ndarray] = {}
    for path, meta in manifest["leaves"].items():
        if not (path.startswith("['") and path.endswith("']")) \
                or "']['" in path:
            raise ValueError(
                f"manifest leaf {path!r} is not a flat dict key; "
                f"manifest_target only supports flat {{name: array}} trees")
        name = path[2:-2]
        np_dtype = (np.uint16 if meta["dtype"] == _BF16
                    else np.dtype(meta["dtype"]))
        out[name] = np.zeros(tuple(meta["shape"]), np_dtype)
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Highest committed step, or None (uncommitted dirs are ignored)."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "COMMIT")):
            best = max(best or -1, int(d.split("_")[1]))
    return best


def restore_checkpoint(ckpt_dir: str, step: int, target_tree,
                       shardings=None):
    """Restore into the structure of ``target_tree`` (shapes validated).

    Reads the manifest written by :func:`save_checkpoint` (see there for
    the format): every manifest leaf must exist in ``target_tree`` and
    vice versa, each leaf file's shape is validated against both the
    manifest and the target, and bf16 u16 bit patterns are re-viewed to
    their true dtype.  Mismatches raise — a checkpoint is never
    partially or silently restored.

    ``shardings``: optional pytree of NamedSharding -- arrays are placed
    against it (elastic resharding: the saved mesh is irrelevant)."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = _leaf_paths(target_tree)
    shard_leaves = _leaf_paths(shardings) if shardings is not None else {}
    out = {}
    for path, meta in manifest["leaves"].items():
        if path not in leaves:
            raise KeyError(f"checkpoint leaf {path} missing from target")
        raw = np.load(os.path.join(src, meta["file"]))
        arr = _from_numpy(raw, meta["dtype"])
        expect = tuple(meta["shape"])
        if tuple(arr.shape) != expect:
            raise ValueError(f"{path}: shape {arr.shape} != {expect}")
        target_shape = tuple(np.shape(leaves[path])) \
            if hasattr(leaves[path], "shape") else None
        if target_shape is not None and target_shape != tuple(arr.shape):
            raise ValueError(
                f"{path}: checkpoint shape {arr.shape} != target "
                f"{target_shape}")
        if path in shard_leaves and shard_leaves[path] is not None:
            arr = jax.device_put(arr, shard_leaves[path])
        out[path] = arr
    missing = set(leaves) - set(manifest["leaves"])
    if missing:
        raise KeyError(f"target leaves missing from checkpoint: {missing}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    restored = [out[jax.tree_util.keystr(path)] for path, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, restored)


class CheckpointManager:
    """Async double-buffered checkpointing.

    ``save(step, tree)`` snapshots to host (blocking only on device->host
    copy), then writes on a background thread; a new save joins the
    previous thread first (at most one outstanding write -- the two-buffer
    discipline of the paper's Fig 3 applied to checkpoint I/O)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, step: int, tree, blocking: bool = False):
        host_tree = jax.tree.map(_to_numpy, tree)
        meta_dtypes = jax.tree.map(lambda x: str(x.dtype), tree)
        self.wait()

        def _write():
            # re-wrap bf16 views for correct manifest dtypes
            restored = jax.tree.map(
                lambda a, d: a.view(jnp.bfloat16) if d == _BF16 else a,
                host_tree, meta_dtypes)
            save_checkpoint(self.ckpt_dir, step, restored, keep=self.keep)
            self.last_saved = step

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, target_tree, shardings=None):
        step = latest_step(self.ckpt_dir)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.ckpt_dir, step, target_tree,
                                        shardings)
