"""Architecture configs (one module per assigned arch) + input-shape cells.

``get_config(name)`` returns the full published config; ``reduced(name)``
returns a smoke-test config of the same family (small widths/layers/experts)
for CPU tests.  ``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins
for every model input of a (arch x shape) cell -- no device allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import ArchConfig

from . import (codeqwen1_5_7b, deepseek_moe_16b, gemma2_9b, hubert_xlarge,
               llama3_2_vision_11b, minicpm3_4b, moonshot_v1_16b_a3b,
               stablelm_1_6b, xlstm_350m, zamba2_7b)

_MODULES = {
    "zamba2-7b": zamba2_7b,
    "gemma2-9b": gemma2_9b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "stablelm-1.6b": stablelm_1_6b,
    "minicpm3-4b": minicpm3_4b,
    "hubert-xlarge": hubert_xlarge,
    "llama-3.2-vision-11b": llama3_2_vision_11b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "xlstm-350m": xlstm_350m,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    return _MODULES[name].CONFIG


def reduced(name: str) -> ArchConfig:
    return _MODULES[name].reduced()


# --------------------------------------------------------------------------
# shape cells (seq_len, global_batch) -- assigned to every LM arch
# --------------------------------------------------------------------------

SHAPES: Dict[str, Tuple[int, int]] = {
    "train_4k": (4096, 256),
    "prefill_32k": (32768, 32),
    "decode_32k": (32768, 128),
    "long_500k": (524288, 1),
}

DECODE_SHAPES = ("decode_32k", "long_500k")


def cell_skip_reason(cfg: ArchConfig, shape: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the documented skip."""
    if cfg.encoder_only and shape in DECODE_SHAPES:
        return "encoder-only arch has no decode step"
    if shape == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch; 500k context needs sub-quadratic attn"
    return None


def input_specs(cfg: ArchConfig, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the lowered step.

    * train_*   -> {tokens/features, labels [, ctx]} for ``train_step``
    * prefill_* -> {tokens/features [, ctx]} for the prefill forward
    * decode_* / long_* -> {token, pos, caches [, ctx]} for ``serve_step``
    """
    seq, batch = SHAPES[shape]
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct

    def tok(b, s):
        if cfg.encoder_only or cfg.family == "audio":
            # stub frontend: precomputed frame embeddings
            return sds((b, s, cfg.d_model), cfg.dtype)
        return sds((b, s), i32)

    specs: Dict[str, Any] = {}
    if shape.startswith("train"):
        specs["tokens"] = tok(batch, seq)
        specs["labels"] = sds((batch, seq), i32)
    elif shape.startswith("prefill"):
        specs["tokens"] = tok(batch, seq)
    else:                                   # decode_32k / long_500k
        from repro.models.lm import make_model
        model = make_model(cfg)
        specs["token"] = tok(batch, 1)
        specs["pos"] = sds((), i32)
        specs["caches"] = jax.eval_shape(
            lambda: model.init_cache(batch, seq))
    if cfg.family == "vlm":
        specs["ctx"] = sds((batch if not shape.startswith("decode") and
                            not shape.startswith("long") else batch,
                            cfg.n_ctx_tokens, cfg.d_model), cfg.dtype)
    return specs
