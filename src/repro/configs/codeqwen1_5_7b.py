"""codeqwen1.5-7b [dense]: qwen1.5-arch decoder (hf:Qwen/CodeQwen1.5-7B).
32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416, rope theta 1e6."""

from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    d_ff=13440,
    vocab=92416,
    pattern=("attn",),
    rope_theta=1_000_000.0,
    sub_quadratic=False,
)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="codeqwen1.5-7b-smoke", family="dense", n_layers=2,
        d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
        pattern=("attn",), rope_theta=1_000_000.0, sub_quadratic=False,
    )
