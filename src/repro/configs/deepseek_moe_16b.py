"""deepseek-moe-16b [moe] (arXiv:2401.06066): fine-grained MoE with 2 shared
+ 64 routed experts top-6, expert d_ff=1408, first layer dense (d_ff=10944).
28L d_model=2048 16H (kv=16) vocab=102400."""

from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=10944,                 # dense first layer
    d_expert=1408,
    vocab=102400,
    n_experts=64,
    top_k=6,
    n_shared=2,
    prelude=("dense",),
    pattern=("moe",),
    sub_quadratic=False,
)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b-smoke", family="moe", n_layers=3,
        d_model=128, n_heads=4, n_kv=4, d_ff=256, d_expert=64, vocab=512,
        n_experts=8, top_k=2, n_shared=1, prelude=("dense",),
        pattern=("moe",), sub_quadratic=False,
    )
