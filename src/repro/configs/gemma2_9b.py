"""gemma2-9b [dense]: alternating local(4096-window)/global attention with
attention-logit softcap 50 and final-logit softcap 30, sandwich norms,
GeGLU, embedding scaling (arXiv:2408.00118).  42L d_model=3584 16H (kv=8)
head_dim=256 d_ff=14336 vocab=256000.  long_500k skipped (global layers are
full attention)."""

from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    pattern=("attn_local", "attn_global"),
    window=4096,
    softcap=50.0,
    final_softcap=30.0,
    activation="gelu_tanh",
    embed_scale=True,
    sub_quadratic=False,
)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="gemma2-9b-smoke", family="dense", n_layers=4,
        d_model=128, n_heads=4, n_kv=2, head_dim=32, d_ff=256, vocab=512,
        pattern=("attn_local", "attn_global"), window=16,
        softcap=50.0, final_softcap=30.0, activation="gelu_tanh",
        embed_scale=True, sub_quadratic=False,
    )
