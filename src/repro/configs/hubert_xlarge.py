"""hubert-xlarge [audio]: encoder-only transformer backbone
(arXiv:2106.07447); the conv waveform frontend is a STUB -- ``input_specs``
provides precomputed frame embeddings (B, S, d_model).  48L d_model=1280
16H (kv=16) d_ff=5120 vocab=504 (codebook targets).  LayerNorm + plain GELU
FFN.  decode_32k / long_500k skipped (no decode step)."""

from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv=16,
    d_ff=5120,
    vocab=504,
    pattern=("attn_bidir",),
    norm="layer",
    activation="gelu",
    encoder_only=True,
    sub_quadratic=False,
)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge-smoke", family="audio", n_layers=2,
        d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=64,
        pattern=("attn_bidir",), norm="layer", activation="gelu",
        encoder_only=True, sub_quadratic=False,
    )
