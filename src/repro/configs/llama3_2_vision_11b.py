"""llama-3.2-vision-11b [vlm] (hf:meta-llama/Llama-3.2-11B-Vision): 40-layer
text backbone with a gated cross-attention image layer every 5th layer
(8 sites).  The vision tower is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, n_patches, d_model).  40L d_model=4096
32H (kv=8) d_ff=14336 vocab=128256."""

from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=128256,
    pattern=("attn", "attn", "attn", "attn", "xattn"),
    rope_theta=500_000.0,
    n_ctx_tokens=1600,                # patch embeddings from the stub tower
    sub_quadratic=False,
)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-11b-smoke", family="vlm", n_layers=5,
        d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
        pattern=("attn", "attn", "attn", "attn", "xattn"),
        rope_theta=500_000.0, n_ctx_tokens=16, sub_quadratic=False,
    )
