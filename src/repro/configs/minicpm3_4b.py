"""minicpm3-4b [dense, MLA] (hf:openbmb/MiniCPM3-4B): multi-head latent
attention with q_lora 768 / kv_lora 256 / nope 64 / rope 32 / v 64.
62L d_model=2560 40H d_ff=6400 vocab=73448."""

from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv=40,
    d_ff=6400,
    vocab=73448,
    pattern=("mla",),
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    sub_quadratic=False,
)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b-smoke", family="dense", n_layers=2,
        d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
        pattern=("mla",), q_lora_rank=48, kv_lora_rank=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, sub_quadratic=False,
    )
