"""moonshot-v1-16b-a3b [moe] (hf:moonshotai/Moonlight-16B-A3B): fine-grained
MoE, 64 routed experts top-6 (per the assigned spec), expert d_ff=1408,
first layer dense.  48L d_model=2048 16H (kv=16) vocab=163840."""

from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=11264,                 # dense first layer
    d_expert=1408,
    vocab=163840,
    n_experts=64,
    top_k=6,
    n_shared=0,
    prelude=("dense",),
    pattern=("moe",),
    sub_quadratic=False,
)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="moonshot-v1-16b-a3b-smoke", family="moe", n_layers=3,
        d_model=128, n_heads=4, n_kv=4, d_ff=256, d_expert=64, vocab=512,
        n_experts=8, top_k=2, n_shared=0, prelude=("dense",),
        pattern=("moe",), sub_quadratic=False,
    )
