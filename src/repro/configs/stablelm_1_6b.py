"""stablelm-1.6b [dense] (hf:stabilityai/stablelm-2-1_6b).
24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352."""

from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=5632,
    vocab=100352,
    pattern=("attn",),
    sub_quadratic=False,
)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="stablelm-1.6b-smoke", family="dense", n_layers=2,
        d_model=128, n_heads=8, n_kv=8, d_ff=256, vocab=512,
        pattern=("attn",), sub_quadratic=False,
    )
