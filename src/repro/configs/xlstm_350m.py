"""xlstm-350m [ssm] (arXiv:2405.04517): alternating mLSTM (parallel matrix
memory) and sLSTM (sequential scalar memory) blocks at ratio 3:1.
24L d_model=1024 4H vocab=50304, no separate FFN (d_ff=0; blocks carry
their own projections).  Sub-quadratic: long_500k runs (O(1) decode
state)."""

from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50304,
    pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    sub_quadratic=True,
)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="xlstm-350m-smoke", family="ssm", n_layers=4,
        d_model=64, n_heads=2, n_kv=2, d_ff=0, vocab=512,
        pattern=("mlstm", "mlstm", "mlstm", "slstm"), sub_quadratic=True,
    )
