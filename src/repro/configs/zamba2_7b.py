"""zamba2-7b [hybrid]: 81 Mamba2 blocks + a *shared* GQA attention block
invoked every 6th layer (13 call sites, one parameter set), per
arXiv:2411.15242.  81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64.  Sub-quadratic: long_500k runs (decode state is O(1) for the
mamba layers; the shared-attn ring caches are linear reads)."""

from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    prelude=("mamba", "mamba", "mamba"),
    pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "mamba_shared"),
    sub_quadratic=True,
)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b-smoke", family="hybrid", n_layers=10,
        d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
        ssm_state=16, mamba_head_dim=32, ssd_chunk=16,
        prelude=("mamba",),
        pattern=("mamba", "mamba", "mamba_shared"),
        sub_quadratic=True,
    )
