"""Iterative reconstruction algorithms (TIGRE's catalogue, paper SS2/SS3).

All algorithms are written against :class:`repro.core.operator.CTOperator`
only, so they run unchanged on the plain, streaming (out-of-core) and
distributed backends -- the paper's modularity argument.
"""

from .fdk import fdk, filter_projections
from .sart import sart, sirt, ossart
from .cgls import cgls
from .fista import fista_tv
from .asd_pocs import asd_pocs

__all__ = ["fdk", "filter_projections", "sart", "sirt", "ossart", "cgls",
           "fista_tv", "asd_pocs"]
