"""Iterative reconstruction algorithms (TIGRE's catalogue, paper SS2/SS3).

All algorithms are written against :class:`repro.core.operator.CTOperator`
only, so they run unchanged on the plain, streaming (out-of-core) and
distributed backends -- the paper's modularity argument.

Each algorithm exists in two equivalent forms:

* the monolithic entry point (``cgls(proj, geo, angles, n_iter=...)``),
* a resumable step-wise iterator (``cgls_init`` / ``cgls_step`` /
  ``cgls_finalize``) registered in :mod:`.stepwise`, which the serving
  scheduler (:mod:`repro.serve`) uses to interleave, preempt and
  checkpoint concurrent jobs.

The monolithic form is a thin loop over the step-wise form, so both
produce bit-identical results.
"""

from .fdk import fdk, filter_projections
from .sart import (OSSARTState, ossart, ossart_finalize, ossart_init,
                   ossart_step, sart, sirt)
from .cgls import CGLSState, cgls, cgls_finalize, cgls_init, cgls_step
from .fista import (FISTAState, fista_tv, fista_tv_finalize, fista_tv_init,
                    fista_tv_step)
from .asd_pocs import (ASDPOCSState, asd_pocs, asd_pocs_finalize,
                       asd_pocs_init, asd_pocs_step)
from .stepwise import (REGISTRY, StepwiseAlgorithm, checkpoint_state,
                       get_algorithm, restore_state)

__all__ = ["fdk", "filter_projections", "sart", "sirt", "ossart", "cgls",
           "fista_tv", "asd_pocs",
           "OSSARTState", "ossart_init", "ossart_step", "ossart_finalize",
           "CGLSState", "cgls_init", "cgls_step", "cgls_finalize",
           "FISTAState", "fista_tv_init", "fista_tv_step",
           "fista_tv_finalize",
           "ASDPOCSState", "asd_pocs_init", "asd_pocs_step",
           "asd_pocs_finalize",
           "StepwiseAlgorithm", "REGISTRY", "get_algorithm",
           "checkpoint_state", "restore_state"]
