"""ASD-POCS (Sidky & Pan): alternate data-consistency (OS-SART steps) with
TV steepest-descent minimisation (paper SS2.3's first regulariser), with the
adaptive step-size bookkeeping of the original algorithm (simplified as in
TIGRE's defaults).

Step-wise form (``asd_pocs_init`` / ``asd_pocs_step``): the adaptive
scalars (dtvg, dp_first, decaying lmbda) ride along in
:class:`ASDPOCSState` so a preempted job resumes with the exact same
step-size schedule; :func:`asd_pocs` wraps the same steps.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..operator import CTOperator
from ..regularization import minimize_tv
from .sart import OSSARTState, ossart_init, ossart_step


@dataclasses.dataclass
class ASDPOCSState:
    """Resumable ASD-POCS state (iterate + adaptive step-size scalars)."""
    op: CTOperator
    proj: jnp.ndarray
    angles: np.ndarray
    subset_size: int
    lmbda: float
    lmbda_red: float
    tv_iters: int
    alpha: float
    alpha_red: float
    r_max: float
    x: jnp.ndarray
    dtvg: Optional[float] = None
    dp_first: Optional[float] = None
    it: int = 0
    # cached OS-SART state: the normalisation factors are deterministic, so
    # computing them once (lazily, also after a checkpoint restore) is
    # bit-identical to the historical re-init every outer iteration
    data_state: Optional[OSSARTState] = None


def asd_pocs_init(proj, geo, angles, subset_size: int = 20,
                  lmbda: float = 1.0, lmbda_red: float = 0.99,
                  tv_iters: int = 20, alpha: float = 0.002,
                  alpha_red: float = 0.95, r_max: float = 0.95,
                  op: Optional[CTOperator] = None, **_ignored) -> ASDPOCSState:
    angles = np.asarray(angles, np.float32)
    if op is None:
        op = CTOperator(geo, angles, mode="plain")
    return ASDPOCSState(op=op, proj=jnp.asarray(proj), angles=angles,
                        subset_size=subset_size, lmbda=lmbda,
                        lmbda_red=lmbda_red, tv_iters=tv_iters, alpha=alpha,
                        alpha_red=alpha_red, r_max=r_max,
                        x=jnp.zeros(geo.n_voxel, jnp.float32))


def asd_pocs_step(st: ASDPOCSState) -> ASDPOCSState:
    """One ASD-POCS iteration: OS-SART data sweep + adaptive TV descent."""
    x_prev = st.x
    if st.data_state is None:
        st.data_state = ossart_init(st.proj, st.op.geo, st.angles,
                                    subset_size=st.subset_size,
                                    lmbda=st.lmbda, op=st.op, x0=st.x)
    else:
        st.data_state.x = st.x
        st.data_state.lmbda = st.lmbda
    st.data_state = ossart_step(st.data_state)
    x = st.data_state.x
    st.lmbda *= st.lmbda_red

    dp_vec = x - x_prev
    dp = float(jnp.linalg.norm(dp_vec.ravel()))
    if st.dp_first is None:
        st.dp_first = dp
    if st.dtvg is None:
        st.dtvg = st.alpha * dp  # initial TV step from first data update

    x_before_tv = x
    x = minimize_tv(x, hyper=st.dtvg, n_iters=st.tv_iters)
    dg = float(jnp.linalg.norm((x - x_before_tv).ravel()))

    # adaptive step (Sidky & Pan): if TV moved more than the data step,
    # shrink the TV step size
    if dg > st.r_max * dp and dp > 0.01 * st.dp_first:
        st.dtvg *= st.alpha_red
    st.x = x
    st.it += 1
    return st


def asd_pocs_finalize(st: ASDPOCSState):
    return st.x


def asd_pocs(proj, geo, angles, n_iter: int = 10, subset_size: int = 20,
             lmbda: float = 1.0, lmbda_red: float = 0.99,
             tv_iters: int = 20, alpha: float = 0.002,
             alpha_red: float = 0.95, r_max: float = 0.95,
             op: Optional[CTOperator] = None,
             callback: Optional[Callable] = None):
    st = asd_pocs_init(proj, geo, angles, subset_size=subset_size,
                       lmbda=lmbda, lmbda_red=lmbda_red, tv_iters=tv_iters,
                       alpha=alpha, alpha_red=alpha_red, r_max=r_max, op=op)
    for it in range(n_iter):
        st = asd_pocs_step(st)
        if callback is not None:
            callback(it, st.x)
    return asd_pocs_finalize(st)
