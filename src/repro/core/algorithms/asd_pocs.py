"""ASD-POCS (Sidky & Pan): alternate data-consistency (OS-SART steps) with
TV steepest-descent minimisation (paper SS2.3's first regulariser), with the
adaptive step-size bookkeeping of the original algorithm (simplified as in
TIGRE's defaults).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..operator import CTOperator
from ..regularization import minimize_tv
from .sart import ossart


def asd_pocs(proj, geo, angles, n_iter: int = 10, subset_size: int = 20,
             lmbda: float = 1.0, lmbda_red: float = 0.99,
             tv_iters: int = 20, alpha: float = 0.002,
             alpha_red: float = 0.95, r_max: float = 0.95,
             op: Optional[CTOperator] = None,
             callback: Optional[Callable] = None):
    angles = np.asarray(angles, np.float32)
    if op is None:
        op = CTOperator(geo, angles, mode="plain")
    proj = jnp.asarray(proj)

    x = jnp.zeros(geo.n_voxel, jnp.float32)
    dtvg = None
    dp_first = None

    for it in range(n_iter):
        x_prev = x
        x = ossart(proj, geo, angles, n_iter=1, subset_size=subset_size,
                   lmbda=lmbda, op=op, x0=x)
        lmbda *= lmbda_red

        dp_vec = x - x_prev
        dp = float(jnp.linalg.norm(dp_vec.ravel()))
        if dp_first is None:
            dp_first = dp
        if dtvg is None:
            dtvg = alpha * dp  # initial TV step from first data update

        x_before_tv = x
        x = minimize_tv(x, hyper=dtvg, n_iters=tv_iters)
        dg = float(jnp.linalg.norm((x - x_before_tv).ravel()))

        # adaptive step (Sidky & Pan): if TV moved more than the data step,
        # shrink the TV step size
        if dg > r_max * dp and dp > 0.01 * dp_first:
            dtvg *= alpha_red
        if callback is not None:
            callback(it, x)
    return x
