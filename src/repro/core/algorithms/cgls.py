"""CGLS -- conjugate gradient on the normal equations (paper SS3.2, coffee
bean reconstruction).  Requires the *matched* adjoint (exact vjp transpose);
with an unmatched backprojector CG loses its convergence guarantees, which
is why TIGRE ships "pseudo-matched" weights and we ship the exact adjoint.

Step-wise form (``cgls_init`` / ``cgls_step``): the Krylov recurrence is
carried in a :class:`CGLSState` so the serving scheduler can advance one
CG iteration at a time and checkpoint/preempt between iterations.  The
monolithic :func:`cgls` wrapper runs the identical recurrence.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..operator import CTOperator


@dataclasses.dataclass
class CGLSState:
    """Resumable CGLS Krylov state (x, residual, search direction)."""
    op: CTOperator
    b: jnp.ndarray
    x: jnp.ndarray
    r: jnp.ndarray
    p: jnp.ndarray
    gamma: jnp.ndarray
    it: int = 0


def cgls_init(proj, geo, angles, op: Optional[CTOperator] = None,
              x0=None, **_ignored) -> CGLSState:
    angles = np.asarray(angles, np.float32)
    if op is None:
        op = CTOperator(geo, angles, mode="plain", bp_weight="matched")
    b = jnp.asarray(proj)
    x = jnp.zeros(geo.n_voxel, jnp.float32) if x0 is None else jnp.asarray(x0)
    r = b - op.A(x)
    p = op.At(r, weight="matched")
    s = p
    gamma = jnp.vdot(s.ravel(), s.ravel())
    return CGLSState(op=op, b=b, x=x, r=r, p=p, gamma=gamma)


def cgls_step(st: CGLSState) -> CGLSState:
    """One CG iteration on the normal equations."""
    q = st.op.A(st.p)
    alpha = st.gamma / (jnp.vdot(q.ravel(), q.ravel()) + 1e-30)
    st.x = st.x + alpha * st.p
    st.r = st.r - alpha * q
    s = st.op.At(st.r, weight="matched")
    gamma_new = jnp.vdot(s.ravel(), s.ravel())
    beta = gamma_new / (st.gamma + 1e-30)
    st.gamma = gamma_new
    st.p = s + beta * st.p
    st.it += 1
    return st


def cgls_finalize(st: CGLSState):
    return st.x


def cgls(proj, geo, angles, n_iter: int = 15,
         op: Optional[CTOperator] = None, x0=None,
         callback: Optional[Callable] = None):
    st = cgls_init(proj, geo, angles, op=op, x0=x0)
    for it in range(n_iter):
        st = cgls_step(st)
        if callback is not None:
            callback(it, st.x, float(jnp.linalg.norm(st.r.ravel())))
    return cgls_finalize(st)
