"""CGLS -- conjugate gradient on the normal equations (paper SS3.2, coffee
bean reconstruction).  Requires the *matched* adjoint (exact vjp transpose);
with an unmatched backprojector CG loses its convergence guarantees, which
is why TIGRE ships "pseudo-matched" weights and we ship the exact adjoint.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..operator import CTOperator


def cgls(proj, geo, angles, n_iter: int = 15,
         op: Optional[CTOperator] = None, x0=None,
         callback: Optional[Callable] = None):
    angles = np.asarray(angles, np.float32)
    if op is None:
        op = CTOperator(geo, angles, mode="plain", bp_weight="matched")
    b = jnp.asarray(proj)
    x = jnp.zeros(geo.n_voxel, jnp.float32) if x0 is None else jnp.asarray(x0)

    r = b - op.A(x)
    p = op.At(r, weight="matched")
    s = p
    gamma = jnp.vdot(s.ravel(), s.ravel())

    for it in range(n_iter):
        q = op.A(p)
        alpha = gamma / (jnp.vdot(q.ravel(), q.ravel()) + 1e-30)
        x = x + alpha * p
        r = r - alpha * q
        s = op.At(r, weight="matched")
        gamma_new = jnp.vdot(s.ravel(), s.ravel())
        beta = gamma_new / (gamma + 1e-30)
        gamma = gamma_new
        p = s + beta * p
        if callback is not None:
            callback(it, x, float(jnp.linalg.norm(r.ravel())))
    return x
