"""Feldkamp-Davis-Kress filtered backprojection.

Cosine weighting + Ram-Lak (ramp) filtering along the detector u axis +
depth-weighted voxel backprojection.  The u axis is rescaled to the virtual
detector through the rotation axis (factor DSO/DSD), as in TIGRE.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..geometry import ConeGeometry


def _ramp_freq_response(pad: int, d: float) -> np.ndarray:
    """|freq| response of the discrete Ram-Lak kernel with spacing ``d``.

    Built from the exact band-limited spatial kernel (Kak & Slaney eq. 61):
    h[0] = 1/(4 d^2), h[k odd] = -1/(pi k d)^2, h[k even] = 0, laid out
    circularly, then transformed.
    """
    k = np.fft.fftfreq(pad) * pad  # 0, 1, ..., -1 circular indices
    h = np.zeros(pad, np.float64)
    h[0] = 1.0 / (4.0 * d * d)
    ki = k.astype(np.int64)
    odd = np.abs(ki) % 2 == 1
    h[odd] = -1.0 / (np.pi * ki[odd] * d) ** 2
    return np.maximum(np.real(np.fft.fft(h)), 0.0)


def filter_projections(proj: jnp.ndarray, geo: ConeGeometry,
                       angles: np.ndarray) -> jnp.ndarray:
    """Cosine-weight and ramp-filter projections (per angle, along u)."""
    nv, nu = geo.n_detector
    dv, du = geo.d_detector
    offv, offu = geo.off_detector
    us = (jnp.arange(nu) - (nu - 1) / 2.0) * du + offu
    vs = (jnp.arange(nv) - (nv - 1) / 2.0) * dv + offv
    # cosine weights on the *real* detector
    cosw = geo.DSD / jnp.sqrt(geo.DSD ** 2 + us[None, :] ** 2
                              + vs[:, None] ** 2)
    # ramp on the virtual detector through the origin
    du_virt = du * geo.DSO / geo.DSD
    pad = 1 << int(np.ceil(np.log2(2 * nu)))
    H = jnp.asarray(_ramp_freq_response(pad, du_virt), jnp.float32)

    def one(p2d):
        pw = p2d * cosw
        P = jnp.fft.rfft(pw, n=pad, axis=1)
        Pf = P * H[: pad // 2 + 1][None, :]
        out = jnp.fft.irfft(Pf, n=pad, axis=1)[:, :nu]
        return out.astype(jnp.float32) * du_virt

    return jax.vmap(one)(proj)


def fdk(proj: jnp.ndarray, geo: ConeGeometry, angles: np.ndarray,
        op=None) -> jnp.ndarray:
    """FDK reconstruction.  ``op`` optionally supplies the backprojection
    backend (streaming / distributed); defaults to the plain operator.

    Scale: f = (d_theta / 2) * sum_theta (DSO/(DSO-p))^2 * g_filtered, the
    discrete Feldkamp integral; validated against the analytic sphere
    phantom in tests/test_algorithms.py.
    """
    from ..operator import CTOperator
    angles = np.asarray(angles, np.float32)
    if op is None:
        op = CTOperator(geo, angles, mode="plain")
    fp = filter_projections(jnp.asarray(proj), geo, angles)
    d_theta = 2.0 * np.pi / len(angles)
    vol = op.At(fp, weight="fdk")
    return vol * (d_theta / 2.0)
