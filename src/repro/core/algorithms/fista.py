"""FISTA with TV proximal step (Beck & Teboulle), TIGRE's FISTA analogue.

    y_{k}   : extrapolated point
    x_{k+1} = prox_{TV/L}( y_k - (1/L) A^T (A y_k - b) )
    t_{k+1} = (1 + sqrt(1 + 4 t_k^2)) / 2
    y_{k+1} = x_{k+1} + (t_k - 1)/t_{k+1} (x_{k+1} - x_k)

The proximal operator is the ROF denoiser (paper SS2.3's second
regulariser); L is estimated by power iteration on A^T A.

Step-wise form (``fista_tv_init`` / ``fista_tv_step``): the momentum
variables (x, y, t) live in a :class:`FISTAState` so the serving scheduler
can interleave iterations across jobs; :func:`fista_tv` wraps the same
steps.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..operator import CTOperator
from ..regularization import rof_denoise


@dataclasses.dataclass
class FISTAState:
    """Resumable FISTA state (iterate, extrapolated point, momentum)."""
    op: CTOperator
    b: jnp.ndarray
    L: float
    tv_lambda: float
    tv_iters: int
    x: jnp.ndarray
    y: jnp.ndarray
    t: float = 1.0
    it: int = 0


def fista_tv_init(proj, geo, angles, tv_lambda: float = 20.0,
                  tv_iters: int = 20, L: Optional[float] = None,
                  op: Optional[CTOperator] = None, **_ignored) -> FISTAState:
    angles = np.asarray(angles, np.float32)
    if op is None:
        op = CTOperator(geo, angles, mode="plain", bp_weight="matched")
    if L is None:
        L = op.norm_squared_est(n_iter=6) * 1.05
    b = jnp.asarray(proj)
    x = jnp.zeros(geo.n_voxel, jnp.float32)
    return FISTAState(op=op, b=b, L=L, tv_lambda=tv_lambda,
                      tv_iters=tv_iters, x=x, y=x)


def fista_tv_step(st: FISTAState) -> FISTAState:
    """One FISTA iteration: gradient step + TV prox + momentum update."""
    grad = st.op.At(st.op.A(st.y) - st.b, weight="matched")
    z = st.y - grad / st.L
    x_new = rof_denoise(z, lam=st.tv_lambda * st.L, n_iters=st.tv_iters)
    t_new = (1.0 + float(np.sqrt(1.0 + 4.0 * st.t * st.t))) / 2.0
    st.y = x_new + ((st.t - 1.0) / t_new) * (x_new - st.x)
    st.x, st.t = x_new, t_new
    st.it += 1
    return st


def fista_tv_finalize(st: FISTAState):
    return st.x


def fista_tv(proj, geo, angles, n_iter: int = 20, tv_lambda: float = 20.0,
             tv_iters: int = 20, L: Optional[float] = None,
             op: Optional[CTOperator] = None,
             callback: Optional[Callable] = None):
    st = fista_tv_init(proj, geo, angles, tv_lambda=tv_lambda,
                       tv_iters=tv_iters, L=L, op=op)
    for it in range(n_iter):
        st = fista_tv_step(st)
        if callback is not None:
            callback(it, st.x)
    return fista_tv_finalize(st)
