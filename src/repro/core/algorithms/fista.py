"""FISTA with TV proximal step (Beck & Teboulle), TIGRE's FISTA analogue.

    y_{k}   : extrapolated point
    x_{k+1} = prox_{TV/L}( y_k - (1/L) A^T (A y_k - b) )
    t_{k+1} = (1 + sqrt(1 + 4 t_k^2)) / 2
    y_{k+1} = x_{k+1} + (t_k - 1)/t_{k+1} (x_{k+1} - x_k)

The proximal operator is the ROF denoiser (paper SS2.3's second
regulariser); L is estimated by power iteration on A^T A.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..operator import CTOperator
from ..regularization import rof_denoise


def fista_tv(proj, geo, angles, n_iter: int = 20, tv_lambda: float = 20.0,
             tv_iters: int = 20, L: Optional[float] = None,
             op: Optional[CTOperator] = None,
             callback: Optional[Callable] = None):
    angles = np.asarray(angles, np.float32)
    if op is None:
        op = CTOperator(geo, angles, mode="plain", bp_weight="matched")
    if L is None:
        L = op.norm_squared_est(n_iter=6) * 1.05
    b = jnp.asarray(proj)

    x = jnp.zeros(geo.n_voxel, jnp.float32)
    y = x
    t = 1.0
    for it in range(n_iter):
        grad = op.At(op.A(y) - b, weight="matched")
        z = y - grad / L
        x_new = rof_denoise(z, lam=tv_lambda * L, n_iters=tv_iters)
        t_new = (1.0 + float(np.sqrt(1.0 + 4.0 * t * t))) / 2.0
        y = x_new + ((t - 1.0) / t_new) * (x_new - x)
        x, t = x_new, t_new
        if callback is not None:
            callback(it, x)
    return x
