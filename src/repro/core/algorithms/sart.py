"""SART family: SIRT, SART, OS-SART (the paper's SS3.2 workhorse).

Update rule (relaxation ``lmbda``):

    x <- x + lmbda * V_s . A_s^T ( W_s . (b_s - A_s x) )

with W = 1 / A 1 (ray normalisation) and V = 1 / A^T 1 (voxel
normalisation), computed per angle subset ``s``:

* SIRT     : one subset = all angles.
* SART     : one subset per angle.
* OS-SART  : blocks of ``subset_size`` angles (paper used 200).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..operator import CTOperator

_EPS = 1e-6


def _norm_factors(op: CTOperator, idx: np.ndarray):
    angles = jnp.asarray(op.angles_np[idx])
    ones_vol = jnp.ones(op.geo.n_voxel, jnp.float32)
    W = op.A(ones_vol, angles)
    W = jnp.where(W > _EPS, 1.0 / jnp.maximum(W, _EPS), 0.0)
    nv, nu = op.geo.n_detector
    ones_proj = jnp.ones((len(idx), nv, nu), jnp.float32)
    V = op.At(ones_proj, angles, weight="pmatched")
    V = jnp.where(V > _EPS, 1.0 / jnp.maximum(V, _EPS), 0.0)
    return W, V


def ossart(proj, geo, angles, n_iter: int = 20, subset_size: int = 20,
           lmbda: float = 1.0, op: Optional[CTOperator] = None,
           x0=None, callback: Optional[Callable] = None,
           bp_weight: str = "pmatched"):
    """OS-SART.  ``subset_size=len(angles)`` gives SIRT; ``1`` gives SART."""
    angles = np.asarray(angles, np.float32)
    if op is None:
        op = CTOperator(geo, angles, mode="plain")
    subsets = op.subset_indices(subset_size)
    factors = [_norm_factors(op, idx) for idx in subsets]
    x = jnp.zeros(geo.n_voxel, jnp.float32) if x0 is None else jnp.asarray(x0)
    proj = jnp.asarray(proj)

    for it in range(n_iter):
        for idx, (W, V) in zip(subsets, factors):
            a_sub = jnp.asarray(angles[idx])
            b_sub = proj[jnp.asarray(idx)]
            resid = W * (b_sub - op.A(x, a_sub))
            upd = op.At(resid, a_sub, weight=bp_weight)
            x = x + lmbda * V * upd
        if callback is not None:
            callback(it, x)
    return x


def sirt(proj, geo, angles, n_iter: int = 20, lmbda: float = 1.0, **kw):
    return ossart(proj, geo, angles, n_iter=n_iter,
                  subset_size=len(np.asarray(angles)), lmbda=lmbda, **kw)


def sart(proj, geo, angles, n_iter: int = 20, lmbda: float = 1.0, **kw):
    return ossart(proj, geo, angles, n_iter=n_iter, subset_size=1,
                  lmbda=lmbda, **kw)
