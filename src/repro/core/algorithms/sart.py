"""SART family: SIRT, SART, OS-SART (the paper's SS3.2 workhorse).

Update rule (relaxation ``lmbda``):

    x <- x + lmbda * V_s . A_s^T ( W_s . (b_s - A_s x) )

with W = 1 / A 1 (ray normalisation) and V = 1 / A^T 1 (voxel
normalisation), computed per angle subset ``s``:

* SIRT     : one subset = all angles.
* SART     : one subset per angle.
* OS-SART  : blocks of ``subset_size`` angles (paper used 200).

The algorithm is expressed as a resumable step-wise iterator
(``ossart_init`` / ``ossart_step``) so that the serving scheduler
(:mod:`repro.serve`) can interleave iterations of competing jobs; the
monolithic entry points below are thin wrappers over the same steps and
produce bit-identical results.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..operator import CTOperator

_EPS = 1e-6


def _norm_factors(op: CTOperator, idx: np.ndarray):
    angles = jnp.asarray(op.angles_np[idx])
    ones_vol = jnp.ones(op.geo.n_voxel, jnp.float32)
    W = op.A(ones_vol, angles)
    W = jnp.where(W > _EPS, 1.0 / jnp.maximum(W, _EPS), 0.0)
    nv, nu = op.geo.n_detector
    ones_proj = jnp.ones((len(idx), nv, nu), jnp.float32)
    V = op.At(ones_proj, angles, weight="pmatched")
    V = jnp.where(V > _EPS, 1.0 / jnp.maximum(V, _EPS), 0.0)
    return W, V


@dataclasses.dataclass
class OSSARTState:
    """Resumable OS-SART iteration state (one entry per outer iteration)."""
    op: CTOperator
    proj: jnp.ndarray
    angles: np.ndarray
    subsets: List[np.ndarray]
    factors: list
    lmbda: float
    bp_weight: str
    x: jnp.ndarray
    it: int = 0


def ossart_init(proj, geo, angles, subset_size: int = 20, lmbda: float = 1.0,
                op: Optional[CTOperator] = None, x0=None,
                bp_weight: str = "pmatched", **_ignored) -> OSSARTState:
    """Build the OS-SART state: normalisation factors + initial image."""
    angles = np.asarray(angles, np.float32)
    if op is None:
        op = CTOperator(geo, angles, mode="plain")
    subsets = op.subset_indices(subset_size)
    factors = [_norm_factors(op, idx) for idx in subsets]
    x = jnp.zeros(geo.n_voxel, jnp.float32) if x0 is None else jnp.asarray(x0)
    return OSSARTState(op=op, proj=jnp.asarray(proj), angles=angles,
                       subsets=subsets, factors=factors, lmbda=lmbda,
                       bp_weight=bp_weight, x=x)


def ossart_step(st: OSSARTState) -> OSSARTState:
    """One outer OS-SART iteration (a full sweep over all subsets)."""
    x = st.x
    for idx, (W, V) in zip(st.subsets, st.factors):
        a_sub = jnp.asarray(st.angles[idx])
        b_sub = st.proj[jnp.asarray(idx)]
        resid = W * (b_sub - st.op.A(x, a_sub))
        upd = st.op.At(resid, a_sub, weight=st.bp_weight)
        x = x + st.lmbda * V * upd
    st.x = x
    st.it += 1
    return st


def ossart_finalize(st: OSSARTState):
    return st.x


def ossart(proj, geo, angles, n_iter: int = 20, subset_size: int = 20,
           lmbda: float = 1.0, op: Optional[CTOperator] = None,
           x0=None, callback: Optional[Callable] = None,
           bp_weight: str = "pmatched"):
    """OS-SART.  ``subset_size=len(angles)`` gives SIRT; ``1`` gives SART."""
    st = ossart_init(proj, geo, angles, subset_size=subset_size, lmbda=lmbda,
                     op=op, x0=x0, bp_weight=bp_weight)
    for it in range(n_iter):
        st = ossart_step(st)
        if callback is not None:
            callback(it, st.x)
    return ossart_finalize(st)


def sirt(proj, geo, angles, n_iter: int = 20, lmbda: float = 1.0, **kw):
    return ossart(proj, geo, angles, n_iter=n_iter,
                  subset_size=len(np.asarray(angles)), lmbda=lmbda, **kw)


def sart(proj, geo, angles, n_iter: int = 20, lmbda: float = 1.0, **kw):
    return ossart(proj, geo, angles, n_iter=n_iter, subset_size=1,
                  lmbda=lmbda, **kw)
