"""Step-wise algorithm registry: the serving layer's view of the catalogue.

Every reconstruction algorithm is exposed as a resumable iterator

    state = alg.init(proj, geo, angles, op=op, **params)
    state = alg.step(state)          # one outer iteration
    image = alg.finalize(state)

so that a scheduler (:mod:`repro.serve`) can interleave iterations of
competing jobs, preempt low-priority work between steps, and checkpoint /
restore long jobs.  The monolithic entry points (``cgls``, ``ossart`` ...)
are wrappers over the very same step functions, so step-wise execution is
bit-identical to the one-shot path.

``ckpt_fields`` names the fields of the state dataclass that constitute
the resumable part (iterate + recurrence scalars); everything else is
rebuilt deterministically by ``init`` on restore.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .asd_pocs import (ASDPOCSState, asd_pocs_finalize, asd_pocs_init,
                       asd_pocs_step)
from .cgls import CGLSState, cgls_finalize, cgls_init, cgls_step
from .fdk import fdk
from .fista import (FISTAState, fista_tv_finalize, fista_tv_init,
                    fista_tv_step)
from .sart import (OSSARTState, ossart_finalize, ossart_init, ossart_step)


@dataclasses.dataclass(frozen=True)
class StepwiseAlgorithm:
    """A reconstruction algorithm as a resumable (init, step, finalize)."""
    name: str
    init: Callable[..., Any]
    step: Callable[[Any], Any]
    finalize: Callable[[Any], Any]
    ckpt_fields: Tuple[str, ...]
    iterative: bool = True
    # operator weighting the algorithm assumes (mirrors launch.recon):
    # Krylov/gradient methods need the exact vjp adjoint.
    default_bp_weight: str = "pmatched"
    # checkpointed scalars that are also valid ``init`` kwargs: feeding
    # them back on restore skips recomputing them (e.g. FISTA's L comes
    # from a 6-round power iteration -- the dominant admission cost)
    resume_params: Tuple[str, ...] = ()


# ---- direct (single-step) algorithms ---------------------------------------

@dataclasses.dataclass
class FDKState:
    """One-shot FDK wrapped in the step-wise protocol (a single step)."""
    op: Any
    proj: Any
    geo: Any
    angles: np.ndarray
    x: Optional[jnp.ndarray] = None
    it: int = 0


def fdk_init(proj, geo, angles, op=None, **_ignored) -> FDKState:
    return FDKState(op=op, proj=proj, geo=geo,
                    angles=np.asarray(angles, np.float32))


def fdk_step(st: FDKState) -> FDKState:
    st.x = fdk(st.proj, st.geo, st.angles, op=st.op)
    st.it += 1
    return st


def fdk_finalize(st: FDKState):
    return st.x


# ---- aliases (SIRT / SART are OS-SART with fixed subset sizes) -------------

def _sirt_init(proj, geo, angles, **params):
    params["subset_size"] = len(np.asarray(angles))
    return ossart_init(proj, geo, angles, **params)


def _sart_init(proj, geo, angles, **params):
    params["subset_size"] = 1
    return ossart_init(proj, geo, angles, **params)


REGISTRY: Dict[str, StepwiseAlgorithm] = {
    "ossart": StepwiseAlgorithm(
        "ossart", ossart_init, ossart_step, ossart_finalize,
        ckpt_fields=("x", "lmbda", "it"), resume_params=("lmbda",)),
    "sirt": StepwiseAlgorithm(
        "sirt", _sirt_init, ossart_step, ossart_finalize,
        ckpt_fields=("x", "lmbda", "it"), resume_params=("lmbda",)),
    "sart": StepwiseAlgorithm(
        "sart", _sart_init, ossart_step, ossart_finalize,
        ckpt_fields=("x", "lmbda", "it"), resume_params=("lmbda",)),
    "cgls": StepwiseAlgorithm(
        "cgls", cgls_init, cgls_step, cgls_finalize,
        ckpt_fields=("x", "r", "p", "gamma", "it"),
        default_bp_weight="matched"),
    "fista": StepwiseAlgorithm(
        "fista", fista_tv_init, fista_tv_step, fista_tv_finalize,
        ckpt_fields=("x", "y", "t", "L", "it"),
        default_bp_weight="matched", resume_params=("L",)),
    "asd_pocs": StepwiseAlgorithm(
        "asd_pocs", asd_pocs_init, asd_pocs_step, asd_pocs_finalize,
        ckpt_fields=("x", "lmbda", "dtvg", "dp_first", "it"),
        resume_params=("lmbda",)),
    "fdk": StepwiseAlgorithm(
        "fdk", fdk_init, fdk_step, fdk_finalize,
        ckpt_fields=("x", "it"), iterative=False),
}
REGISTRY["fista_tv"] = REGISTRY["fista"]


def get_algorithm(name: str) -> StepwiseAlgorithm:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown algorithm {name!r}; "
                         f"known: {sorted(REGISTRY)}") from None


# ---- checkpoint / restore ---------------------------------------------------

def checkpoint_state(alg: StepwiseAlgorithm, state) -> Dict[str, Any]:
    """Snapshot the resumable fields as host (numpy) values."""
    out: Dict[str, Any] = {}
    for f in alg.ckpt_fields:
        v = getattr(state, f)
        if isinstance(v, (jnp.ndarray, np.ndarray)):
            v = np.asarray(v)
        out[f] = v
    return out


def restore_state(alg: StepwiseAlgorithm, state, ckpt: Dict[str, Any]):
    """Overwrite a freshly-init'ed state with checkpointed fields."""
    for f, v in ckpt.items():
        if isinstance(v, np.ndarray) and v.dtype != object:
            v = jnp.asarray(v)
        setattr(state, f, v)
    return state


__all__ = ["StepwiseAlgorithm", "REGISTRY", "get_algorithm",
           "checkpoint_state", "restore_state",
           "FDKState", "fdk_init", "fdk_step", "fdk_finalize"]
