"""Kernel-backend registry: named projector implementations, one dispatch.

The paper's modularity claim cuts both ways: the splitting plans
(:mod:`repro.core.plan`) are independent of the algorithms *and* of the
kernels that execute them.  This module is the kernel half of that
contract — a registry of named backends, each providing the same small
slab-operator surface:

* ``"ref"``    — the pure-JAX projectors in :mod:`repro.core.projector`
  (obviously correct, runs everywhere; the parity oracle).
* ``"pallas"`` — the Pallas TPU kernels in :mod:`repro.kernels`
  (``fp_ray``, ``bp_voxel``): Mosaic-compiled on real TPU backends,
  interpret mode elsewhere.
* ``"auto"``   — resolves per JAX backend: ``"pallas"`` on TPU hosts,
  ``"ref"`` otherwise.

Every executor (``CTOperator`` plain mode, the out-of-core streaming
loops, the shard_map distributed operators) obtains its kernels from
here, so selecting ``backend="pallas"`` routes the *same* execution plan
onto the optimized kernels — tomoCAM's observation that the plan/kernel
split is what makes drop-in kernel swaps possible.

Cached-jit dispatch
-------------------
Backends hand out **jit-compiled callables from a process-wide dispatch
table keyed by (backend, kind, geometry, static plan args)**.  The
returned callables take only traced arguments (arrays, angles, the slab
origin ``z0``), so repeated calls — every slab of every iteration of
every job — reuse one compiled executable instead of retracing
(:func:`dispatch_cache_info` exposes the hit counters the regression
tests assert on).  Exact-adjoint ("matched") operators follow the
selected backend too: ``pallas_call`` defines no transpose rule, so the
pallas backend pairs the ray-driven FP with a dedicated transpose-shaped
scatter kernel (:mod:`repro.kernels.bp_matched`) via ``jax.custom_vjp``
— the pair replays identical fp32 ray weights, keeping
``<Ax, y> == <x, At y>`` to float tolerance for CGLS/FISTA — while the
ref backend keeps its ``jax.vjp`` construction.

Block sizes come from :mod:`repro.kernels.autotune`: the measured
per-(kind, platform, geometry-shape) table when ``REPRO_AUTOTUNE`` is
on, the divisor-or-pad heuristic otherwise.  The chosen blocks are part
of every dispatch key, so differently-tuned configs never share a
compiled entry.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from . import projector as proj_mod
from .geometry import ConeGeometry


# --------------------------------------------------------------------------
# cached-jit dispatch table
# --------------------------------------------------------------------------

class _DispatchTable:
    """Process-wide (key -> compiled callable) map with hit/miss stats.

    Builders run outside the lock (they only trace lazily anyway); a
    racing double-build keeps the first entry, so callers always share
    one callable (and its jit cache) per key.
    """

    def __init__(self):
        self._fns: Dict[tuple, Callable] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: tuple, build: Callable[[], Callable]) -> Callable:
        with self._lock:
            fn = self._fns.get(key)
            if fn is not None:
                self.hits += 1
                obs.incr("dispatch_hits")
                return fn
            self.misses += 1
        obs.incr("dispatch_misses")
        # The "compile" span times the builder.  XLA compilation proper is
        # lazy (first invocation), so it lands in whichever compute/init
        # span makes that first call -- documented in docs/observability.md.
        with obs.span("compile", "compile", key=str(key[:2])):
            fn = build()
        with self._lock:
            return self._fns.setdefault(key, fn)

    def info(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "currsize": len(self._fns)}

    def keys(self) -> tuple:
        with self._lock:
            return tuple(self._fns)

    def clear(self) -> None:
        with self._lock:
            self._fns.clear()
            self.hits = self.misses = 0


_TABLE = _DispatchTable()


def dispatch_cache_info() -> Dict[str, int]:
    """Hit/miss/size counters of the shared dispatch table."""
    return _TABLE.info()


def dispatch_cache_keys() -> tuple:
    """The dispatch table's current ``(backend, kind, geometry, ...)``
    keys.  Regression tests assert on *which* kernels materialised —
    e.g. that the dominance-split dist FP never builds the unused
    dominance variant on a single-dominance workload."""
    return _TABLE.keys()


def clear_dispatch_cache() -> None:
    """Drop every cached callable (frees their compiled executables)."""
    _TABLE.clear()


def _divisor_at_most(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>= 1).

    Kept as the angle-axis fallback; the tiled volume axes now go through
    :func:`repro.kernels.autotune.get_blocks` (divisor-or-pad heuristic,
    measured table when tuning is enabled)."""
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


# --------------------------------------------------------------------------
# backend interface + implementations
# --------------------------------------------------------------------------

class KernelBackend:
    """One named kernel implementation.

    The contract is three slab operators (all returned callables are
    jit-compiled, shared through the dispatch table, and close over the
    static plan args only):

    * ``fp(geo, xdom=...)``              -> ``f(slab, angles, z0) -> proj``
      partial forward projection of the z planes ``[z0, z0+len(slab))``
      for a single-dominance angle set;
    * ``bp(geo, planes=..., weight=...)``-> ``f(proj, angles, z0) -> slab``
      voxel-driven backprojection into an axial slab (weights
      ``fdk`` / ``pmatched`` / ``none``);
    * ``bp_matched(geo, planes=..., xdom=...)`` — the *exact* adjoint of
      the slab forward projection (``jax.vjp`` here; the pallas backend
      overrides it with its native transpose kernel).

    plus two full-volume conveniences for mixed-dominance angle sets
    (``fp_mixed`` / ``at_matched_mixed``), built on the slab operators.
    """

    name = "?"

    def kernel_config(self, geo: ConeGeometry, *,
                      planes: Optional[int] = None) -> Dict[str, int]:
        """Block-size configuration this backend would run ``geo`` with.

        Empty for backends without tunable blocks; the pallas backend
        reports the (possibly autotuned) slab/z/angle blocks — surfaced
        in serve calibration attrs and the operator benchmarks."""
        return {}

    # -- slab operators ------------------------------------------------------

    def fp(self, geo: ConeGeometry, *, xdom: bool) -> Callable:
        raise NotImplementedError

    def bp(self, geo: ConeGeometry, *, planes: int,
           weight: str) -> Callable:
        raise NotImplementedError

    def bp_matched(self, geo: ConeGeometry, *, planes: int,
                   xdom: bool) -> Callable:
        """Exact slab adjoint: vjp of the ref slab FP, keeping
        <Ax, y> == <x, At y> to float precision for CGLS/FISTA.  The
        pallas backend overrides this with the transpose-shaped scatter
        kernel (:mod:`repro.kernels.bp_matched`)."""
        def build():
            @jax.jit
            def f(proj_chunk, angles, z0):
                def fwd(slab):
                    return proj_mod.forward_project_joseph(
                        slab, geo, angles, xdom=xdom, z0=z0)
                zeros = jnp.zeros((planes,) + tuple(geo.n_voxel[1:]),
                                  jnp.float32)
                _, vjp = jax.vjp(fwd, zeros)
                return vjp(proj_chunk)[0]
            return f
        return _TABLE.get(("ref", "bp_matched", geo, planes, xdom), build)

    # -- full-volume mixed-dominance conveniences ----------------------------

    def fp_mixed(self, geo: ConeGeometry, mask: np.ndarray) -> Callable:
        """Full forward projection ``f(vol, angles) -> proj`` for a static
        dominance ``mask`` (x-dominant entries True): the angle set is
        split per dominance, each subset runs the specialised slab FP,
        and the results scatter back — TIGRE's independent per-GPU angle
        queues, expressed as one compiled callable per mask."""
        mask = np.asarray(mask, bool)
        key = (self.name, "fp_mixed", geo, mask.tobytes())

        def build():
            idx_x = np.nonzero(mask)[0]
            idx_y = np.nonzero(~mask)[0]
            fpx = self.fp(geo, xdom=True) if idx_x.size else None
            fpy = self.fp(geo, xdom=False) if idx_y.size else None
            nv, nu = geo.n_detector

            @jax.jit
            def f(vol, angles):
                out = jnp.zeros((len(mask), nv, nu), jnp.float32)
                if fpx is not None:
                    out = out.at[idx_x].set(fpx(vol, angles[idx_x], 0))
                if fpy is not None:
                    out = out.at[idx_y].set(fpy(vol, angles[idx_y], 0))
                return out
            return f
        return _TABLE.get(key, build)

    def at_matched_mixed(self, geo: ConeGeometry,
                         mask: np.ndarray) -> Callable:
        """Exact adjoint ``f(proj, angles) -> vol`` of the mixed-dominance
        full FP (ref-built vjp here; the pallas backend overrides it with
        per-dominance matched scatter kernels)."""
        mask = np.asarray(mask, bool)
        key = ("ref", "at_matched_mixed", geo, mask.tobytes())

        def build():
            ref_fp = get_backend("ref").fp_mixed(geo, mask)

            @jax.jit
            def f(proj, angles):
                zeros = jnp.zeros(geo.n_voxel, jnp.float32)
                _, vjp = jax.vjp(lambda v: ref_fp(v, angles), zeros)
                return vjp(proj)[0]
            return f
        return _TABLE.get(key, build)


class RefBackend(KernelBackend):
    """Pure-JAX projectors (:mod:`repro.core.projector`)."""

    name = "ref"

    def fp(self, geo: ConeGeometry, *, xdom: bool) -> Callable:
        def build():
            @jax.jit
            def f(slab, angles, z0):
                return proj_mod.forward_project_joseph(
                    slab, geo, angles, xdom=xdom, z0=z0)
            return f
        return _TABLE.get(("ref", "fp", geo, xdom), build)

    def bp(self, geo: ConeGeometry, *, planes: int,
           weight: str) -> Callable:
        def build():
            @jax.jit
            def f(proj, angles, z0):
                return proj_mod.backproject_voxel(
                    proj, geo, angles, weight=weight, z_start=z0,
                    z_planes=planes)
            return f
        return _TABLE.get(("ref", "bp", geo, planes, weight), build)


class PallasBackend(KernelBackend):
    """Pallas TPU kernels (:mod:`repro.kernels.fp_ray` /
    :mod:`repro.kernels.bp_voxel`).

    ``interpret`` defaults to auto-detection: Mosaic compiles the kernels
    on real TPU backends, interpret mode validates them everywhere else.
    Block sizes come from :mod:`repro.kernels.autotune` (measured table
    when enabled, divisor-or-pad heuristic otherwise); the kernels pad
    and mask non-divisor tails, so odd volume shapes stay runnable.

    Matched weighting is native here: ``fp`` pairs the ray kernel with
    the transpose-shaped scatter kernel through ``jax.custom_vjp``, and
    ``bp_matched`` / ``at_matched_mixed`` hand out that scatter kernel
    directly — no ref fallback anywhere on the matched path.
    """

    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None,
                 slab_planes: int = 16, z_block: int = 16,
                 angle_chunk: int = 8):
        self._interpret = interpret
        self.slab_planes = slab_planes
        self.z_block = z_block
        self.angle_chunk = angle_chunk

    @property
    def interpret(self) -> bool:
        if self._interpret is not None:
            return self._interpret
        return jax.default_backend() != "tpu"

    def _blocks(self, kind: str, geo: ConeGeometry,
                planes: Optional[int] = None) -> Dict[str, int]:
        from repro.kernels import autotune
        pref = self.z_block if kind == "bp" else self.slab_planes
        return autotune.get_blocks(kind, geo, planes=planes, preferred=pref,
                                   angle_pref=self.angle_chunk,
                                   interpret=self.interpret)

    def kernel_config(self, geo: ConeGeometry, *,
                      planes: Optional[int] = None) -> Dict[str, int]:
        from repro.kernels import autotune
        fp = self._blocks("fp", geo)
        bm = self._blocks("bp_matched", geo)
        bp = self._blocks("bp", geo, planes=planes)
        return {"fp.slab_planes": fp["slab_planes"],
                "bp_matched.slab_planes": bm["slab_planes"],
                "bp.z_block": bp["z_block"],
                "bp.angle_chunk": bp["angle_chunk"],
                "autotuned": bool(autotune.enabled())}

    @staticmethod
    def _check_rotation_trick(geo: ConeGeometry) -> None:
        # same transpose trick (and the same preconditions) as the ref
        # Joseph projector: rotate the scene -90 deg so the y-dominant
        # set becomes x-dominant
        nz, ny, nx = geo.n_voxel
        if nx != ny or abs(geo.d_voxel[1] - geo.d_voxel[2]) > 1e-12:
            raise ValueError(
                "y-dominant transpose trick needs square xy grid")
        if any(abs(o) > 0 for o in geo.off_origin[1:]):
            raise ValueError(
                "xy origin offsets unsupported with rotation trick")

    def fp(self, geo: ConeGeometry, *, xdom: bool) -> Callable:
        from repro.kernels.bp_matched import bp_matched_pallas
        from repro.kernels.fp_ray import fp_ray_pallas
        interpret = self.interpret
        sp = self._blocks("fp", geo)["slab_planes"]
        spb = self._blocks("bp_matched", geo)["slab_planes"]
        key = ("pallas", "fp", geo, xdom, sp, spb, interpret)

        def build():
            if not xdom:
                self._check_rotation_trick(geo)

            def make_core(planes):
                # one custom_vjp pair per slab height: forward runs the
                # ray kernel, backward the matched scatter kernel — the
                # two replay identical fp32 ray weights, so anything that
                # differentiates through this FP (norm estimation, CGLS's
                # A^T) gets the exact adjoint without leaving Pallas
                @jax.custom_vjp
                def core(s, ang, z0f):
                    return fp_ray_pallas(s, geo, ang, slab_planes=sp,
                                         interpret=interpret, z0=z0f)

                def fwd(s, ang, z0f):
                    return core(s, ang, z0f), (ang, z0f)

                def bwd(res, ct):
                    ang, z0f = res
                    sbar = bp_matched_pallas(
                        ct, geo, ang, slab_planes=spb, interpret=interpret,
                        z0=z0f, z_planes=planes)
                    return sbar, jnp.zeros_like(ang), jnp.zeros_like(z0f)
                core.defvjp(fwd, bwd)
                return core

            cores: Dict[int, Callable] = {}

            @jax.jit
            def f(slab, angles, z0):
                planes = slab.shape[0]
                if planes not in cores:
                    cores[planes] = make_core(planes)
                z0f = jnp.asarray(z0, jnp.float32)
                if not xdom:
                    # rotation stays outside the custom_vjp core: autodiff
                    # transposes the flip/transpose pair natively
                    slab = proj_mod._rotate_vol_90(slab)
                    angles = angles - jnp.pi / 2.0
                return cores[planes](slab, angles, z0f)
            return f
        return _TABLE.get(key, build)

    def bp(self, geo: ConeGeometry, *, planes: int,
           weight: str) -> Callable:
        from repro.kernels.bp_voxel import bp_voxel_pallas
        interpret = self.interpret
        cfg = self._blocks("bp", geo, planes=planes)
        zb, ca = cfg["z_block"], cfg["angle_chunk"]
        key = ("pallas", "bp", geo, planes, weight, zb, ca, interpret)

        def build():
            @jax.jit
            def f(proj, angles, z0):
                # bp_voxel clamps + pads non-divisor chunks itself
                return bp_voxel_pallas(proj, geo, angles, z_block=zb,
                                       angle_chunk=ca, weight=weight,
                                       interpret=interpret, z_start=z0,
                                       z_planes=planes)
            return f
        return _TABLE.get(key, build)

    def bp_matched(self, geo: ConeGeometry, *, planes: int,
                   xdom: bool) -> Callable:
        """Native exact slab adjoint: the transpose-shaped scatter kernel
        replaying the ray kernel's fp32 weights (no ref vjp involved)."""
        from repro.kernels.bp_matched import bp_matched_pallas
        interpret = self.interpret
        spb = self._blocks("bp_matched", geo)["slab_planes"]
        key = ("pallas", "bp_matched", geo, planes, xdom, spb, interpret)

        def build():
            if not xdom:
                self._check_rotation_trick(geo)

            @jax.jit
            def f(proj_chunk, angles, z0):
                ang = angles if xdom else angles - jnp.pi / 2.0
                slab = bp_matched_pallas(
                    proj_chunk, geo, ang, slab_planes=spb,
                    interpret=interpret, z0=z0, z_planes=planes)
                if not xdom:
                    # adjoint (= inverse) of the -90 deg scene rotation
                    # the forward pass applies before the ray kernel
                    slab = jnp.transpose(jnp.flip(slab, axis=1), (0, 2, 1))
                return slab
            return f
        return _TABLE.get(key, build)

    def at_matched_mixed(self, geo: ConeGeometry,
                         mask: np.ndarray) -> Callable:
        """Exact adjoint of the mixed-dominance FP from the per-dominance
        matched scatter kernels: the dominance groups partition the angle
        rows, so summing each group's slab adjoint is the full A^T."""
        mask = np.asarray(mask, bool)
        interpret = self.interpret
        nz = geo.n_voxel[0]
        spb = self._blocks("bp_matched", geo)["slab_planes"]
        key = ("pallas", "at_matched_mixed", geo, mask.tobytes(), spb,
               interpret)

        def build():
            idx_x = np.nonzero(mask)[0]
            idx_y = np.nonzero(~mask)[0]
            bmx = (self.bp_matched(geo, planes=nz, xdom=True)
                   if idx_x.size else None)
            bmy = (self.bp_matched(geo, planes=nz, xdom=False)
                   if idx_y.size else None)

            @jax.jit
            def f(proj, angles):
                out = jnp.zeros(geo.n_voxel, jnp.float32)
                if bmx is not None:
                    out = out + bmx(proj[idx_x], angles[idx_x], 0)
                if bmy is not None:
                    out = out + bmy(proj[idx_y], angles[idx_y], 0)
                return out
            return f
        return _TABLE.get(key, build)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, KernelBackend] = {}


def register_backend(backend: KernelBackend) -> KernelBackend:
    """Add a named backend (replacing any previous holder of the name)."""
    _REGISTRY[backend.name] = backend
    return backend


register_backend(RefBackend())
register_backend(PallasBackend())


def available_backends() -> tuple:
    """Registered backend names plus the ``"auto"`` alias."""
    return tuple(sorted(_REGISTRY)) + ("auto",)


def resolve(name: Optional[str]) -> str:
    """Canonical backend name: ``None`` / ``"auto"`` pick per JAX backend
    (pallas on TPU hosts, ref elsewhere); unknown names raise."""
    name = name or "auto"
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel backend {name!r} "
                         f"(have {available_backends()})")
    return name


def get_backend(name: Optional[str] = None) -> KernelBackend:
    """Backend instance for ``name`` (default: auto-resolve)."""
    return _REGISTRY[resolve(name)]
