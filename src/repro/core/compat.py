"""Version compatibility shims for the JAX API surface we use.

The repo targets the modern API (``jax.shard_map``, ``check_vma``,
``jax.make_mesh(..., axis_types=...)``); older runtimes (<= 0.4.x) ship
the same functionality as ``jax.experimental.shard_map`` (``check_rep``)
and ``jax.make_mesh`` without ``axis_types``.  Everything in the repo goes
through these two helpers so a single module owns the divergence.
"""

from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(shape: Sequence[int], names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(tuple(shape), tuple(names),
                             axis_types=(AxisType.Auto,) * len(names))
    except (ImportError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(names))


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` with a psum(1) fallback for older runtimes."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` falling back to the experimental module.

    ``check_vma`` (new name) maps onto ``check_rep`` (old name).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
