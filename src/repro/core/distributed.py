"""Multi-device (pod-scale) projection operators via ``shard_map``.

This is the paper's multi-GPU layer generalised to TPU meshes (DESIGN.md SS5):

* forward projection: angles sharded over the ``data`` axis (paper SS2.1
  "each GPU will compute a set of independent projections"), the volume
  z-slab sharded over the ``model`` axis; per-device partial projections are
  reduced over ``model``.
* backprojection: projections sharded over ``data``, image slabs over
  ``model``; partial slab updates are reduced over ``data``.

The reductions are exact because the operators are additive over disjoint
z slabs / angle sets (tests/test_splitting.py, tests/test_distributed.py).

The communication decisions are no longer hard-coded at the call sites:
the plan IR's :class:`~repro.core.plan.CommSchedule` selects the
cross-shard reduction schedule (``"psum"`` baseline, ``"ppermute"``
ring, or a hierarchical two-level tree — intra-group ring then
cross-group hops, chosen from the mesh shape by
:func:`~repro.core.plan.choose_reduction`) and whether the FP angle set
is split by dominant axis on the host, so that non-ref backends run one
single-dominance kernel per shard instead of evaluating both variants
(the historical 2x local FP).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import obs
from .compat import axis_size as compat_axis_size, shard_map
from .geometry import ConeGeometry, dominant_axis_mask
from .plan import choose_reduction, hier_group_size
from .projector import (_joseph_xdom_one_angle, _rotate_vol_90,
                        backproject_voxel)


def _traced_dist(fn, op: str, mesh: Mesh, data_axis: str, model_axis: str,
                 **extra):
    """Wrap a jitted sharded op with a host-side compute span.

    Spans cannot be opened *inside* shard_map (the body is traced code),
    so each call gets one span carrying the shard layout; with tracing
    enabled the wrapper blocks on the result so the span is honest
    compute time (when disabled the raw async-dispatch fn runs —
    zero overhead, unchanged overlap behaviour)."""
    n_data = mesh.shape[data_axis]
    n_model = mesh.shape[model_axis]

    def traced(*args):
        if not obs.enabled():
            return fn(*args)
        with obs.span(op, "compute", op=op, data_shards=n_data,
                      model_shards=n_model, **extra):
            out = fn(*args)
            for leaf in jax.tree_util.tree_leaves(out):
                block = getattr(leaf, "block_until_ready", None)
                if block is not None:
                    block()
        return out
    return traced


def _reduce_partial(part, schedule: str, axis_name: str, n: int):
    """Cross-shard all-reduce of a partial result, per the plan's
    :func:`~repro.core.plan.choose_reduction` schedule.

    ``"psum"`` is the one-shot baseline; ``"ring"`` runs ``n - 1``
    ppermute hops each overlappable with compute; ``"hier"`` reduces
    within contiguous groups first (ring), then accumulates the group
    sums with group-stride hops — Petascale XCT's intra-node-before-
    inter-node tree mapped onto one mesh axis.  All three produce the
    full sum on every shard (summation order differs, so only ``psum``
    is bit-identical to the historical default)."""
    if schedule == "psum" or n == 1:
        return jax.lax.psum(part, axis_name)
    if schedule == "ring":
        perm = [(j, (j + 1) % n) for j in range(n)]

        def hop(_, acc_part):
            acc, p = acc_part
            p = jax.lax.ppermute(p, axis_name, perm)
            return acc + p, p
        acc, _ = jax.lax.fori_loop(0, n - 1, hop, (part, part))
        return acc
    if schedule == "hier":
        g = hier_group_size(n)
        intra = [(j, (j // g) * g + ((j % g) + 1) % g) for j in range(n)]
        inter = [(j, (j + g) % n) for j in range(n)]

        def hop1(_, acc_part):
            acc, p = acc_part
            p = jax.lax.ppermute(p, axis_name, intra)
            return acc + p, p
        group_sum, _ = jax.lax.fori_loop(0, g - 1, hop1, (part, part))

        def hop2(_, tot_rot):
            tot, rot = tot_rot
            rot = jax.lax.ppermute(rot, axis_name, inter)
            return tot + rot, rot
        total, _ = jax.lax.fori_loop(0, n // g - 1, hop2,
                                     (group_sum, group_sum))
        return total
    raise ValueError(f"unknown reduction schedule {schedule!r} "
                     f"(have psum | ring | hier)")


def _joseph_any_angle(vol, vol_rot, geo: ConeGeometry, theta, z0):
    """Joseph integral at one angle with a *traced* dominant-axis decision.

    Needed inside shard_map where an angle shard may mix x- and y-dominant
    angles.  ``lax.cond`` under ``lax.map`` stays a true branch (sequential
    scan), so only one projector runs per angle.
    """
    nz, ny, nx = geo.n_voxel
    x_centers = jnp.asarray(
        (np.arange(nx) - (nx - 1) / 2.0) * geo.d_voxel[2] + geo.off_origin[2],
        dtype=jnp.float32)
    xdom = jnp.abs(jnp.cos(theta)) >= jnp.abs(jnp.sin(theta))
    return jax.lax.cond(
        xdom,
        lambda: _joseph_xdom_one_angle(vol, geo, theta, x_centers, z0=z0),
        lambda: _joseph_xdom_one_angle(vol_rot, geo, theta - jnp.pi / 2,
                                       x_centers, z0=z0),
    )


def _fp_local(vol_slab, angles_local, geo: ConeGeometry, z0):
    """Partial FP of a z slab for a local angle set (any dominance mix)."""
    vol_rot = _rotate_vol_90(vol_slab)

    def one(theta):
        return _joseph_any_angle(vol_slab, vol_rot, geo, theta, z0)

    return jax.lax.map(one, angles_local)


def _fp_local_fn(geo: ConeGeometry, backend: Optional[str]):
    """Local slab-FP for an arbitrary-dominance angle shard, on the
    selected kernel backend.

    The dominant axis is a *static* host decision in the plain/stream
    paths, but a shard_map angle shard may mix dominances, and the
    Pallas FP kernel is single-dominance.  The ref backend keeps the
    per-angle ``lax.cond`` (one projector runs per angle); other
    backends evaluate both dominance variants for the shard and select
    per angle — 2x local FP compute.  This is only the *fallback* for
    ``dominance_split=False``: the default dist FP path regroups the
    angles by dominance on the host so every shard runs exactly one
    single-dominance kernel (see :func:`dist_forward_project`).
    """
    from .backend import get_backend, resolve
    if resolve(backend) == "ref":
        return lambda vol_slab, angles_local, z0: _fp_local(
            vol_slab, angles_local, geo, z0)
    bk = get_backend(backend)
    fpx = bk.fp(geo, xdom=True)
    fpy = bk.fp(geo, xdom=False)

    def f(vol_slab, angles_local, z0):
        px = fpx(vol_slab, angles_local, z0)
        py = fpy(vol_slab, angles_local, z0)
        xdom = jnp.abs(jnp.cos(angles_local)) >= jnp.abs(jnp.sin(angles_local))
        return jnp.where(xdom[:, None, None], px, py)
    return f


def dist_forward_project(mesh: Mesh, geo: ConeGeometry,
                         data_axis: str = "data", model_axis: str = "model",
                         reduce: Optional[str] = None,
                         backend: Optional[str] = None,
                         dominance_split: Optional[bool] = None,
                         comm=None):
    """Build a sharded FP: ``f(vol, angles) -> proj``.

    ``vol`` sharded ``P(model, None, None)`` (z slabs); ``angles`` sharded
    ``P(data)``; output sharded ``P(data, None, None)``.

    Both communication decisions come off the plan IR: ``reduce`` selects
    the cross-slab reduction schedule (``"psum"`` | ``"ring"`` |
    ``"hier"``; default ``None`` reads ``comm.reduction`` or derives it
    from the model-axis size via
    :func:`~repro.core.plan.choose_reduction`), and ``dominance_split``
    (default from ``comm``, else on) regroups the angle set by dominant
    axis on the host so each group runs one *single-dominance* sharded
    call — on non-ref backends this kills the 2x local FP of evaluating
    both kernel variants per shard (:func:`_fp_local_fn`; ref needs no
    split, its per-angle ``lax.cond`` already runs one projector).  Each
    group is padded to the data-axis size with
    :func:`pad_angles`-style duplicate angles and the rows scatter back
    to input order afterwards, so the wrapper is call-compatible with
    the plain sharded fn.
    """
    n_model = mesh.shape[model_axis]
    n_data = mesh.shape[data_axis]
    nz = geo.n_voxel[0]
    if nz % n_model:
        raise ValueError(f"Nz={nz} not divisible by model axis {n_model}")
    planes = nz // n_model
    if comm is not None:
        if reduce is None:
            reduce = comm.reduction
        if dominance_split is None:
            dominance_split = comm.dominance_split
    if reduce is None:
        reduce = choose_reduction(n_model)
    if dominance_split is None:
        dominance_split = True
    from .backend import get_backend, resolve
    split = dominance_split and resolve(backend) != "ref"

    def sharded(fp_local):
        def body(vol_slab, angles_local):
            z0 = jax.lax.axis_index(model_axis) * planes
            part = fp_local(vol_slab, angles_local, z0)
            return _reduce_partial(part, reduce, model_axis, n_model)
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(model_axis, None, None), P(data_axis)),
            out_specs=P(data_axis, None, None), check_vma=False)
        return jax.jit(fn)

    if not split:
        return _traced_dist(sharded(_fp_local_fn(geo, backend)), "dist_fp",
                            mesh, data_axis, model_axis, reduce=reduce)

    # Host-level dominance split: one single-dominance sharded call per
    # non-empty dominance group.  Built lazily so an all-one-dominance
    # workload never even fetches the other kernel variant from the
    # dispatch table (asserted via dispatch_cache_keys in the tests).
    bk = get_backend(backend)
    fns = {}

    def fn_for(xdom: bool):
        if xdom not in fns:
            fp1 = bk.fp(geo, xdom=xdom)
            fns[xdom] = _traced_dist(
                sharded(lambda vs, al, z0, _fp=fp1: _fp(vs, al, z0)),
                "dist_fp", mesh, data_axis, model_axis, reduce=reduce,
                xdom=xdom)
        return fns[xdom]

    nv, nu = geo.n_detector

    def call(vol, angles):
        angles_np = np.asarray(angles, np.float32)
        xm = dominant_axis_mask(angles_np)
        groups = [(True, np.nonzero(xm)[0]), (False, np.nonzero(~xm)[0])]
        groups = [(x, i) for x, i in groups if i.size]
        parts = []
        for xdom, idx in groups:
            padded, valid = pad_angles(angles_np[idx], n_data)
            outp = fn_for(xdom)(vol, jnp.asarray(padded))
            parts.append((idx, outp if valid.all() else outp[:idx.size]))
        if len(parts) == 1 and parts[0][0].size == len(angles_np):
            return parts[0][1]     # single dominance: rows already ordered
        out = jnp.zeros((len(angles_np), nv, nu), jnp.float32)
        with obs.span("reduce", "reduce", op="dist_fp", schedule=reduce,
                      groups=len(parts),
                      bytes=int(len(angles_np)) * nv * nu * 4):
            for idx, p in parts:
                out = out.at[jnp.asarray(idx)].set(p)
            if obs.enabled():
                out.block_until_ready()
        return out
    return call


def dist_backproject(mesh: Mesh, geo: ConeGeometry, weight: str = "fdk",
                     data_axis: str = "data", model_axis: str = "model",
                     backend: Optional[str] = None, reduce: str = "psum",
                     comm=None):
    """Build a jitted sharded BP: ``g(proj, angles) -> vol``.

    ``proj``/``angles`` sharded over ``data``; output volume z-sharded over
    ``model`` (each device updates its own slab from its angle subset, then
    the partial updates are reduced over ``data`` -- additive in angles).
    ``backend`` selects the slab kernel (the voxel-driven BP is
    dominance-free, so the Pallas kernel drops straight in; no dominance
    split applies here).  ``reduce`` selects the data-axis reduction
    schedule; unlike the FP it defaults to ``"psum"`` regardless of the
    plan (``comm`` is accepted for API symmetry) because the historical
    reduction order is part of the bit-exactness contract the serving
    layer's preemption/restore tests rely on.
    """
    from .backend import get_backend
    n_model = mesh.shape[model_axis]
    n_data = mesh.shape[data_axis]
    nz = geo.n_voxel[0]
    if nz % n_model:
        raise ValueError(f"Nz={nz} not divisible by model axis {n_model}")
    planes = nz // n_model
    bp = get_backend(backend).bp(geo, planes=planes, weight=weight)

    def body(proj_local, angles_local):
        z0 = jax.lax.axis_index(model_axis) * planes
        slab = bp(proj_local, angles_local, z0)
        return _reduce_partial(slab, reduce, data_axis, n_data)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(data_axis, None, None), P(data_axis)),
        out_specs=P(model_axis, None, None), check_vma=False)
    return _traced_dist(jax.jit(fn), "dist_bp", mesh, data_axis,
                        model_axis, weight=weight)


def dist_backproject_matched(mesh: Mesh, geo: ConeGeometry,
                             data_axis: str = "data",
                             model_axis: str = "model",
                             backend: Optional[str] = None):
    """Exact adjoint BP on the selected backend: ``f(proj, angles) -> vol``.

    Each device adjoints its angle shard's FP restricted to its z slab,
    then partial slab updates are summed over ``data`` — linearity over
    disjoint angle sets makes the stacked result the monolithic A^T
    exactly, so CGLS/FISTA keep their convergence guarantees on the
    distributed backend (same argument as the streaming matched adjoint).

    On the ref backend the per-shard adjoint is the historical
    ``jax.vjp`` of the mixed-dominance local FP.  Non-ref backends use
    the backend's native single-dominance ``bp_matched`` kernel and
    mirror :func:`dist_forward_project`'s host-level dominance split:
    one sharded call per non-empty dominance group (padded to the data
    axis with duplicate angles + zeroed projection rows — BP is linear,
    so they add nothing), group volumes summed.
    """
    from .backend import get_backend, resolve
    n_model = mesh.shape[model_axis]
    n_data = mesh.shape[data_axis]
    nz = geo.n_voxel[0]
    if nz % n_model:
        raise ValueError(f"Nz={nz} not divisible by model axis {n_model}")
    planes = nz // n_model

    if resolve(backend) == "ref":
        def body(proj_local, angles_local):
            z0 = jax.lax.axis_index(model_axis) * planes
            zeros = jnp.zeros((planes,) + tuple(geo.n_voxel[1:]),
                              jnp.float32)

            def fwd(slab):
                return _fp_local(slab, angles_local, geo, z0)

            _, vjp = jax.vjp(fwd, zeros)
            return jax.lax.psum(vjp(proj_local)[0], data_axis)

        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(data_axis, None, None), P(data_axis)),
            out_specs=P(model_axis, None, None), check_vma=False)
        return _traced_dist(jax.jit(fn), "dist_bp_matched", mesh,
                            data_axis, model_axis)

    # Non-ref: lazily build one single-dominance sharded matched BP per
    # dominance group present in the workload (mirrors the dist FP's
    # host split; asserted via dispatch_cache_keys in the tests).
    bk = get_backend(backend)
    fns = {}

    def sharded(bm):
        def body(proj_local, angles_local):
            z0 = jax.lax.axis_index(model_axis) * planes
            slab = bm(proj_local, angles_local, z0)
            return jax.lax.psum(slab, data_axis)
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(data_axis, None, None), P(data_axis)),
            out_specs=P(model_axis, None, None), check_vma=False)
        return jax.jit(fn)

    def fn_for(xdom: bool):
        if xdom not in fns:
            bm = bk.bp_matched(geo, planes=planes, xdom=xdom)
            fns[xdom] = _traced_dist(sharded(bm), "dist_bp_matched", mesh,
                                     data_axis, model_axis, xdom=xdom)
        return fns[xdom]

    nv, nu = geo.n_detector

    def call(proj, angles):
        angles_np = np.asarray(angles, np.float32)
        xm = dominant_axis_mask(angles_np)
        groups = [(True, np.nonzero(xm)[0]), (False, np.nonzero(~xm)[0])]
        groups = [(x, i) for x, i in groups if i.size]
        proj = jnp.asarray(proj, jnp.float32)
        out = None
        for xdom, idx in groups:
            padded, valid = pad_angles(angles_np[idx], n_data)
            pj = proj[jnp.asarray(idx)]
            if not valid.all():
                pj = jnp.concatenate(
                    [pj, jnp.zeros((len(padded) - idx.size, nv, nu),
                                   jnp.float32)], 0)
            part = fn_for(xdom)(pj, jnp.asarray(padded))
            out = part if out is None else out + part
        if out is None:
            out = jnp.zeros(geo.n_voxel, jnp.float32)
        return out
    return call


def pad_angles(angles: np.ndarray, multiple: int):
    """Pad the angle set to a multiple of the data-axis size.

    Padded entries repeat the last angle; callers must consume the returned
    ``valid`` mask — drop the padded rows of a padded forward projection,
    and zero the padded rows before a backprojection (BP is linear, so zero
    rows add nothing to the slab sums).  ``CTOperator`` (mode="dist") does
    both automatically for non-divisible angle counts.
    """
    n = len(angles)
    n_pad = (-n) % multiple
    if n_pad == 0:
        return np.asarray(angles, np.float32), np.ones(n, bool)
    padded = np.concatenate([angles, np.full(n_pad, angles[-1])]).astype(np.float32)
    valid = np.concatenate([np.ones(n, bool), np.zeros(n_pad, bool)])
    return padded, valid


def halo_exchange(x: jnp.ndarray, depth: int, axis_name: str):
    """Exchange ``depth`` boundary planes with axis neighbours (paper SS2.3).

    ``x`` is a local z slab ``(planes, ...)``; returns ``x`` padded to
    ``planes + 2*depth`` with the neighbours' boundary planes (zeros at the
    global ends).  One ``ppermute`` pair per call -- this is the *only*
    communication the split TV regulariser performs every ``N_in`` inner
    iterations.
    """
    n = compat_axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    top = x[-depth:]      # send up (to idx+1)
    bot = x[:depth]       # send down (to idx-1)
    up_perm = [(i, i + 1) for i in range(n - 1)]
    down_perm = [(i + 1, i) for i in range(n - 1)]
    from_below = jax.lax.ppermute(top, axis_name, up_perm)     # neighbour idx-1's top
    from_above = jax.lax.ppermute(bot, axis_name, down_perm)   # neighbour idx+1's bottom
    pad_shape = (depth,) + x.shape[1:]
    from_below = jnp.where(idx > 0, from_below, jnp.zeros(pad_shape, x.dtype))
    from_above = jnp.where(idx < n - 1, from_above, jnp.zeros(pad_shape, x.dtype))
    return jnp.concatenate([from_below, x, from_above], axis=0)
