"""Cone-beam CT geometry (TIGRE parameterisation).

Conventions
-----------
* The volume is a ``(Nz, Ny, Nx)`` array indexed ``vol[k, j, i]``; voxel
  ``(k, j, i)`` has world-space centre

      x = (i - (Nx-1)/2) * dx + off_x
      y = (j - (Ny-1)/2) * dy + off_y
      z = (k - (Nz-1)/2) * dz + off_z

* The source rotates in the xy-plane.  At gantry angle ``theta``:

      S(theta) = ( DSO * cos(theta),  DSO * sin(theta), 0 )

  The flat detector is perpendicular to the central ray at distance
  ``DSD - DSO`` behind the origin; pixel ``(iv, iu)`` has world position

      C(theta) + (iu - (Nu-1)/2 + off_u/du) * du * e_u + (iv - ...) * dv * e_v

  with ``e_u = (-sin, cos, 0)``, ``e_v = (0, 0, 1)`` and
  ``C = -(DSD - DSO) * (cos, sin, 0)``.

* Projections are ``(n_angles, Nv, Nu)`` arrays.

The class is a plain frozen dataclass of Python/numpy scalars so that it can
be closed over by jitted functions (static) while ``angles`` remains a JAX
array argument.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

Vec3 = Tuple[float, float, float]
Vec2 = Tuple[float, float]


@dataclasses.dataclass(frozen=True)
class ConeGeometry:
    """Circular cone-beam geometry.

    Distances are in mm (any consistent unit works).  The defaults model a
    standard micro-CT bench.
    """

    DSD: float = 1536.0          # source -> detector
    DSO: float = 1000.0          # source -> rotation axis
    n_voxel: Tuple[int, int, int] = (256, 256, 256)       # (Nz, Ny, Nx)
    s_voxel: Tuple[float, float, float] = (256.0, 256.0, 256.0)  # physical size
    n_detector: Tuple[int, int] = (256, 256)              # (Nv, Nu)
    s_detector: Tuple[float, float] = (409.6, 409.6)      # physical size
    off_origin: Vec3 = (0.0, 0.0, 0.0)                    # (z, y, x) offsets
    off_detector: Vec2 = (0.0, 0.0)                       # (v, u) offsets

    # ---- derived quantities ------------------------------------------------
    @property
    def d_voxel(self) -> Tuple[float, float, float]:
        return tuple(s / n for s, n in zip(self.s_voxel, self.n_voxel))

    @property
    def d_detector(self) -> Tuple[float, float]:
        return tuple(s / n for s, n in zip(self.s_detector, self.n_detector))

    @property
    def magnification(self) -> float:
        return self.DSD / self.DSO

    @property
    def fan_half_angle(self) -> float:
        """Maximum in-plane angle between a ray and the central ray (rad)."""
        half_u = 0.5 * self.s_detector[1] + abs(self.off_detector[1])
        return math.atan2(half_u, self.DSD)

    @property
    def cone_half_angle(self) -> float:
        half_v = 0.5 * self.s_detector[0] + abs(self.off_detector[0])
        return math.atan2(half_v, self.DSD)

    def __post_init__(self):
        if self.DSD <= self.DSO:
            raise ValueError("DSD must exceed DSO")
        # Joseph's method with a per-angle dominant axis requires every ray of
        # an angle to share that axis; cap the fan angle safely below 45 deg.
        if self.fan_half_angle > math.radians(40.0):
            raise ValueError(
                f"fan half-angle {math.degrees(self.fan_half_angle):.1f} deg "
                "too large for the per-angle dominant-axis Joseph projector "
                "(limit 40 deg); reduce detector width or increase DSD"
            )

    # ---- factory helpers ---------------------------------------------------
    @staticmethod
    def nice(n: int, n_detector: Tuple[int, int] | None = None) -> "ConeGeometry":
        """A well-conditioned N^3 volume / N^2 detector geometry (paper Fig 7)."""
        if n_detector is None:
            n_detector = (n, n)
        return ConeGeometry(
            DSD=1536.0,
            DSO=1000.0,
            n_voxel=(n, n, n),
            s_voxel=(256.0, 256.0, 256.0),
            n_detector=n_detector,
            s_detector=(409.6 * n_detector[0] / max(n_detector), 409.6),
        )

    def with_voxels(self, n_voxel: Tuple[int, int, int]) -> "ConeGeometry":
        return dataclasses.replace(self, n_voxel=n_voxel)

    # ---- world-space helpers (numpy; used to set up jit constants) ---------
    def voxel_centers_1d(self, axis: int) -> np.ndarray:
        """World coordinates of voxel centres along axis (0=z,1=y,2=x)."""
        n = self.n_voxel[axis]
        d = self.d_voxel[axis]
        off = self.off_origin[axis]
        return (np.arange(n) - (n - 1) / 2.0) * d + off

    def detector_coords_1d(self, axis: int) -> np.ndarray:
        """World (detector-plane) coordinates of pixel centres (0=v,1=u)."""
        n = self.n_detector[axis]
        d = self.d_detector[axis]
        off = self.off_detector[axis]
        return (np.arange(n) - (n - 1) / 2.0) * d + off


def circular_angles(n_angles: int, total: float = 2.0 * math.pi) -> np.ndarray:
    """Equally spaced gantry angles over ``total`` radians (endpoint excl.)."""
    return np.linspace(0.0, total, n_angles, endpoint=False).astype(np.float32)


def source_positions(geo: ConeGeometry, angles: np.ndarray) -> np.ndarray:
    """(n_angles, 3) source positions in world (x, y, z) order."""
    c, s = np.cos(angles), np.sin(angles)
    return np.stack([geo.DSO * c, geo.DSO * s, np.zeros_like(c)], axis=-1)


def dominant_axis_mask(angles: np.ndarray) -> np.ndarray:
    """True where the *central ray* of the angle is x-dominant.

    The central ray direction is -(cos, sin, 0); x-dominant iff
    |cos| >= |sin|.  Rays within the fan deviate by < fan_half_angle
    (asserted < 40 deg in the geometry), so with the 45 deg decision
    boundary every ray of an x-dominant angle has |d_x| within
    tan(5 deg) of dominance — Joseph quadrature remains well conditioned.
    """
    return np.abs(np.cos(angles)) >= np.abs(np.sin(angles))
