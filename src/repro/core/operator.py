"""Unified CT operator: one object, three execution modes, any kernel backend.

The paper's point is that the *same* algorithms run regardless of how the
operators are executed ("TIGRE's architecture is modular, thus all of the
GPU code is independent from the algorithm that uses it").  ``CTOperator``
exposes ``A`` (forward) and ``At`` (back) and hides the execution:

* ``mode="plain"``   -- monolithic jitted operators (volume fits on device).
* ``mode="stream"``  -- the paper's out-of-core double-buffered executor
                         (host-resident arrays, slab streaming).
* ``mode="dist"``    -- shard_map over a device mesh (angles x z-slabs).

All three are built from one memoized :class:`~repro.core.plan.ExecutionPlan`
(``self.plan``) and draw their kernels from the backend registry
(:mod:`repro.core.backend`): ``backend="ref"`` runs the pure-JAX
projectors, ``backend="pallas"`` the Pallas TPU kernels, ``"auto"``
(default) picks per JAX backend.  The plan fixes the slab/chunk/device
structure; the backend fixes the kernel that executes each piece — either
can change without touching the other (or the algorithms).

All modes and backends produce matching results (tests/test_splitting.py,
tests/test_distributed.py, tests/test_backend.py); algorithms in
``repro.core.algorithms`` are written against this interface only.
Exact-adjoint ("matched") weighting follows the backend too: the ref
backend builds it from ``jax.vjp``, the pallas backend from its native
transpose-shaped scatter kernel — see :mod:`repro.core.backend` and
tests/test_adjoint.py.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .backend import get_backend, resolve as resolve_backend
from .geometry import ConeGeometry, dominant_axis_mask
from .plan import ExecutionPlan, plan as plan_execution
from .splitting import MemoryModel


class CTOperator:
    """``A`` / ``At`` with selectable execution mode and kernel backend.

    Parameters
    ----------
    geo, angles : geometry and the (static, numpy) gantry angles.
    mode : "plain" | "stream" | "dist".
    bp_weight : default backprojection weighting ("matched" uses the exact
        vjp adjoint; "fdk"/"pmatched"/"none" use the voxel-driven kernel).
    mesh : required for mode="dist".
    memory : memory model for mode="stream" (defaults to an 11 GiB device).
    backend : kernel backend name ("ref" | "pallas" | "auto"/None).
    plan : pre-computed :class:`~repro.core.plan.ExecutionPlan`; derived
        (memoized) from the other arguments when omitted.
    """

    def __init__(self, geo: ConeGeometry, angles: np.ndarray,
                 mode: str = "plain", bp_weight: str = "matched",
                 mesh=None, memory: Optional[MemoryModel] = None,
                 devices: Optional[Sequence] = None,
                 backend: Optional[str] = None,
                 plan: Optional[ExecutionPlan] = None):
        self.geo = geo
        self.angles_np = np.asarray(angles, np.float32)
        self.angles = jnp.asarray(self.angles_np)
        self.mode = mode
        self.bp_weight = bp_weight
        self.mesh = mesh
        self.devices = devices
        self.memory = memory or MemoryModel()
        self.backend_name = resolve_backend(backend)
        self._backend = get_backend(self.backend_name)
        self._xdom = dominant_axis_mask(self.angles_np)

        if mode not in ("plain", "stream", "dist"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "dist" and mesh is None:
            raise ValueError("mode='dist' needs a mesh")

        # one plan drives every mode: the stream executors interpret its
        # CommSchedule step list verbatim, plain mode is its n_slabs == 1
        # fast path, and dist mode reads its reduction / dominance-split
        # decisions (n_devices = the mesh's model axis, so the schedule's
        # reduction tree reflects the actual shard count; the plan still
        # carries the footprint/pass model the serving layer prices with)
        if mode == "dist":
            n_dev = mesh.shape.get("model", 1)
        elif mode == "stream" and devices:
            n_dev = len(devices)
        else:
            n_dev = 1
        self.plan = plan if plan is not None else \
            plan_execution(geo, len(self.angles_np), n_dev, self.memory)

        if mode == "dist":
            from .distributed import (dist_backproject,
                                      dist_backproject_matched,
                                      dist_forward_project)
            comm = self.plan.comm
            self._a = dist_forward_project(mesh, geo,
                                           backend=self.backend_name,
                                           comm=comm)
            self._at_fdk = dist_backproject(mesh, geo, weight="fdk",
                                            backend=self.backend_name,
                                            comm=comm)
            self._at_none = dist_backproject(mesh, geo, weight="none",
                                             backend=self.backend_name,
                                             comm=comm)
            self._at_pm = dist_backproject(mesh, geo, weight="pmatched",
                                           backend=self.backend_name,
                                           comm=comm)
            self._at_matched = dist_backproject_matched(
                mesh, geo, backend=self.backend_name)
            self._data_axis_size = mesh.shape["data"]
        elif mode == "stream":
            # kept as attributes: the executors (and older callers) read
            # the per-operator schedules straight off the shared plan
            self.plan_f = self.plan.forward
            self.plan_b = self.plan.backward

    def warmup(self, weight: Optional[str] = None) -> None:
        """Materialise this operator's dispatch entries ahead of first use.

        Fetches every kernel callable the configured mode/weighting will
        ask the backend registry for (building + jit-wrapping them into
        the shared dispatch table; XLA compilation proper stays lazy).
        The serve layer's autoscaler pre-warm calls this during the
        predictive lead window so a freshly scaled-up pod admits its
        first job without the operator-build stall.  Dist mode builds
        its sharded fns in ``__init__`` — nothing lazy is left there.
        """
        weight = weight or self.bp_weight
        has = [(True, bool(self._xdom.any())),
               (False, bool((~self._xdom).any()))]
        if self.mode == "plain":
            self._plain_fp(self.angles_np)
            if weight == "matched":
                self._backend.at_matched_mixed(self.geo, self._xdom)
            else:
                self._backend.bp(self.geo, planes=self.geo.n_voxel[0],
                                 weight=weight)
            return
        if self.mode == "stream":
            for xd, present in has:
                if present:
                    self._backend.fp(self.geo, xdom=xd)
            for z0, z1 in self.plan.backward.slab_ranges:
                if weight == "matched":
                    for xd, present in has:
                        if present:
                            self._backend.bp_matched(self.geo,
                                                     planes=z1 - z0,
                                                     xdom=xd)
                else:
                    self._backend.bp(self.geo, planes=z1 - z0,
                                     weight=weight)

    def kernel_config(self) -> dict:
        """The backend's (possibly autotuned) block-size config for this
        operator's geometry — empty on backends without tunable blocks.
        Surfaced in serve init events and the operator benchmarks."""
        return self._backend.kernel_config(self.geo,
                                           planes=self.geo.n_voxel[0])

    def _plain_fp(self, angles_np: np.ndarray):
        """Compiled forward for a concrete angle subset: the backend's
        mixed-dominance dispatch, cached process-wide per (geo, mask)."""
        return self._backend.fp_mixed(self.geo, dominant_axis_mask(angles_np))

    # ---- forward ----------------------------------------------------------
    def A(self, vol, angles=None):
        if self.mode == "stream":
            a = self.angles_np if angles is None else np.asarray(angles)
            from .streaming import stream_forward
            return stream_forward(np.asarray(vol), self.geo, a, self.plan,
                                  devices=self.devices,
                                  backend=self.backend_name)
        if self.mode == "dist":
            from .distributed import pad_angles
            angles_np = self.angles_np if angles is None else \
                np.asarray(angles, np.float32)
            # shard_map needs the angle count divisible by the data axis;
            # pad with duplicates and drop the padded projections afterwards
            padded, valid = pad_angles(angles_np, self._data_axis_size)
            out = self._a(vol, jnp.asarray(padded))
            if valid.all():
                return out
            return out[:len(angles_np)]   # padding is always a suffix
        angles_np = self.angles_np if angles is None else np.asarray(angles)
        return self._plain_fp(angles_np)(vol, jnp.asarray(angles_np))

    # ---- backward ---------------------------------------------------------
    def At(self, proj, angles=None, weight: Optional[str] = None):
        angles = self.angles if angles is None else angles
        weight = weight or self.bp_weight
        if self.mode == "stream":
            from .streaming import stream_backward
            # "matched" streams the exact per-slab vjp adjoint (CGLS keeps
            # its convergence guarantees out-of-core)
            return stream_backward(np.asarray(proj), self.geo,
                                   np.asarray(angles), self.plan,
                                   weight=weight, devices=self.devices,
                                   backend=self.backend_name)
        if self.mode == "dist":
            from .distributed import pad_angles
            angles_np = np.asarray(angles, np.float32)
            padded, valid = pad_angles(angles_np, self._data_axis_size)
            if not valid.all():
                # zero the padded duplicate projections: BP is linear in the
                # projections, so zero rows contribute nothing to the sums
                n_pad = len(padded) - len(angles_np)
                proj = jnp.concatenate(
                    [jnp.asarray(proj),
                     jnp.zeros((n_pad,) + tuple(self.geo.n_detector),
                               jnp.float32)], axis=0)
            angles = jnp.asarray(padded)
            if weight == "fdk":
                return self._at_fdk(proj, angles)
            if weight == "none":
                return self._at_none(proj, angles)
            if weight == "matched":
                return self._at_matched(proj, angles)
            return self._at_pm(proj, angles)
        angles_np = np.asarray(angles)
        if weight == "matched":
            # exact adjoint of the compiled mixed-dominance forward (ref:
            # vjp; pallas: native matched scatter kernels per dominance)
            at = self._backend.at_matched_mixed(
                self.geo, dominant_axis_mask(angles_np))
            return at(proj, jnp.asarray(angles_np))
        bp = self._backend.bp(self.geo, planes=self.geo.n_voxel[0],
                              weight=weight)
        return bp(proj, jnp.asarray(angles_np), 0)

    # ---- spectral norm estimate (power iterations) -------------------------
    def norm_squared_est(self, n_iter: int = 8, seed: int = 0) -> float:
        """Estimate ||A||_2^2 with power iteration on A^T A (matched pair)."""
        x = jax.random.normal(jax.random.PRNGKey(seed), self.geo.n_voxel,
                              jnp.float32)
        x = x / jnp.linalg.norm(x.ravel())
        lam = 1.0
        for _ in range(n_iter):
            y = self.At(self.A(x), weight="matched")
            lam = float(jnp.linalg.norm(y.ravel()))
            x = y / (lam + 1e-30)
        return lam

    def subset_indices(self, subset_size: int):
        """Contiguous angle subsets for OS methods (paper SS3.2 OS-SART)."""
        n = len(self.angles_np)
        return [np.arange(s, min(s + subset_size, n))
                for s in range(0, n, subset_size)]
