"""Unified CT operator: one object, three execution backends.

The paper's point is that the *same* algorithms run regardless of how the
operators are executed ("TIGRE's architecture is modular, thus all of the
GPU code is independent from the algorithm that uses it").  ``CTOperator``
exposes ``A`` (forward) and ``At`` (back) and hides the backend:

* ``mode="plain"``   -- monolithic jitted operators (volume fits on device).
* ``mode="stream"``  -- the paper's out-of-core double-buffered executor
                         (host-resident arrays, slab streaming).
* ``mode="dist"``    -- shard_map over a device mesh (angles x z-slabs).

All three produce identical results (tests/test_splitting.py,
tests/test_distributed.py); algorithms in ``repro.core.algorithms`` are
written against this interface only.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .geometry import ConeGeometry, dominant_axis_mask
from . import projector as proj_mod
from .splitting import MemoryModel, plan_backward, plan_forward


class CTOperator:
    """``A`` / ``At`` with selectable execution backend.

    Parameters
    ----------
    geo, angles : geometry and the (static, numpy) gantry angles.
    mode : "plain" | "stream" | "dist".
    bp_weight : default backprojection weighting ("matched" uses the exact
        vjp adjoint; "fdk"/"pmatched"/"none" use the voxel-driven kernel).
    mesh : required for mode="dist".
    memory : memory model for mode="stream" (defaults to an 11 GiB device).
    """

    def __init__(self, geo: ConeGeometry, angles: np.ndarray,
                 mode: str = "plain", bp_weight: str = "matched",
                 mesh=None, memory: Optional[MemoryModel] = None,
                 devices: Optional[Sequence] = None):
        self.geo = geo
        self.angles_np = np.asarray(angles, np.float32)
        self.angles = jnp.asarray(self.angles_np)
        self.mode = mode
        self.bp_weight = bp_weight
        self.mesh = mesh
        self.devices = devices
        self.memory = memory or MemoryModel()
        self._xdom = dominant_axis_mask(self.angles_np)

        if mode == "plain":
            self._a_cache = {}
            self._at_voxel = jax.jit(partial(
                proj_mod.backproject_voxel, geo=geo), static_argnames=("weight",))
        elif mode == "dist":
            if mesh is None:
                raise ValueError("mode='dist' needs a mesh")
            from .distributed import (dist_backproject,
                                      dist_backproject_matched,
                                      dist_forward_project)
            self._a = dist_forward_project(mesh, geo)
            self._at_fdk = dist_backproject(mesh, geo, weight="fdk")
            self._at_none = dist_backproject(mesh, geo, weight="none")
            self._at_pm = dist_backproject(mesh, geo, weight="pmatched")
            self._at_matched = dist_backproject_matched(mesh, geo)
            self._data_axis_size = mesh.shape["data"]
        elif mode == "stream":
            n_dev = len(devices) if devices else 1
            self.plan_f = plan_forward(geo, len(self.angles_np), n_dev,
                                       self.memory)
            self.plan_b = plan_backward(geo, len(self.angles_np), n_dev,
                                        self.memory)
        else:
            raise ValueError(f"unknown mode {mode!r}")

    def _plain_fp(self, angles_np: np.ndarray):
        """jitted forward for a concrete angle subset (cached per mask)."""
        mask = dominant_axis_mask(angles_np)
        key = (len(angles_np), mask.tobytes())
        if key not in self._a_cache:
            self._a_cache[key] = jax.jit(
                lambda v, a, m=mask: proj_mod.forward_project(v, self.geo, a, m))
        return self._a_cache[key]

    # ---- forward ----------------------------------------------------------
    def A(self, vol, angles=None):
        if self.mode == "stream":
            a = self.angles_np if angles is None else np.asarray(angles)
            from .streaming import stream_forward
            return stream_forward(np.asarray(vol), self.geo, a, self.plan_f,
                                  devices=self.devices)
        if self.mode == "dist":
            from .distributed import pad_angles
            angles_np = self.angles_np if angles is None else \
                np.asarray(angles, np.float32)
            # shard_map needs the angle count divisible by the data axis;
            # pad with duplicates and drop the padded projections afterwards
            padded, valid = pad_angles(angles_np, self._data_axis_size)
            out = self._a(vol, jnp.asarray(padded))
            if valid.all():
                return out
            return out[:len(angles_np)]   # padding is always a suffix
        angles_np = self.angles_np if angles is None else np.asarray(angles)
        return self._plain_fp(angles_np)(vol, jnp.asarray(angles_np))

    # ---- backward ---------------------------------------------------------
    def At(self, proj, angles=None, weight: Optional[str] = None):
        angles = self.angles if angles is None else angles
        weight = weight or self.bp_weight
        if self.mode == "stream":
            from .streaming import stream_backward
            # "matched" streams the exact per-slab vjp adjoint (CGLS keeps
            # its convergence guarantees out-of-core)
            return stream_backward(np.asarray(proj), self.geo,
                                   np.asarray(angles), self.plan_b,
                                   weight=weight, devices=self.devices)
        if self.mode == "dist":
            from .distributed import pad_angles
            angles_np = np.asarray(angles, np.float32)
            padded, valid = pad_angles(angles_np, self._data_axis_size)
            if not valid.all():
                # zero the padded duplicate projections: BP is linear in the
                # projections, so zero rows contribute nothing to the sums
                n_pad = len(padded) - len(angles_np)
                proj = jnp.concatenate(
                    [jnp.asarray(proj),
                     jnp.zeros((n_pad,) + tuple(self.geo.n_detector),
                               jnp.float32)], axis=0)
            angles = jnp.asarray(padded)
            if weight == "fdk":
                return self._at_fdk(proj, angles)
            if weight == "none":
                return self._at_none(proj, angles)
            if weight == "matched":
                return self._at_matched(proj, angles)
            return self._at_pm(proj, angles)
        if weight == "matched":
            # exact adjoint via vjp of the jitted forward
            angles_np = np.asarray(angles)
            key = ("at", len(angles_np),
                   dominant_axis_mask(angles_np).tobytes())
            if key not in self._a_cache:
                fp = self._plain_fp(angles_np)

                def at_fn(p, a):
                    _, vjp = jax.vjp(
                        lambda v: fp(v, a),
                        jnp.zeros(self.geo.n_voxel, jnp.float32))
                    return vjp(p)[0]

                self._a_cache[key] = jax.jit(at_fn)
            return self._a_cache[key](proj, jnp.asarray(angles_np))
        return self._at_voxel(proj, angles=angles, weight=weight)

    # ---- spectral norm estimate (power iterations) -------------------------
    def norm_squared_est(self, n_iter: int = 8, seed: int = 0) -> float:
        """Estimate ||A||_2^2 with power iteration on A^T A (matched pair)."""
        x = jax.random.normal(jax.random.PRNGKey(seed), self.geo.n_voxel,
                              jnp.float32)
        x = x / jnp.linalg.norm(x.ravel())
        lam = 1.0
        for _ in range(n_iter):
            y = self.At(self.A(x), weight="matched")
            lam = float(jnp.linalg.norm(y.ravel()))
            x = y / (lam + 1e-30)
        return lam

    def subset_indices(self, subset_size: int):
        """Contiguous angle subsets for OS methods (paper SS3.2 OS-SART)."""
        n = len(self.angles_np)
        return [np.arange(s, min(s + subset_size, n))
                for s in range(0, n, subset_size)]
