"""Synthetic phantoms with analytic forward projections.

The sphere phantom has a closed-form cone-beam line integral (chord length
through a ball), giving a ground-truth oracle for the projectors that is
independent of any discretisation.  The Shepp-Logan-like ellipsoid phantom is
used for reconstruction-quality benchmarks (paper SS3.2 stand-in, since the
measured coffee-bean/ichthyosaur data is not redistributable).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from .geometry import ConeGeometry


# Each ellipsoid: (value, (cx, cy, cz), (ax, ay, az), phi_deg) -- rotation
# about the z axis only (enough structure, keeps the analytic FP simple).
Ellipsoid = Tuple[float, Tuple[float, float, float], Tuple[float, float, float], float]

# A compact Shepp-Logan-like set, coordinates in units of half-volume-extent.
SHEPP_LIKE: Sequence[Ellipsoid] = (
    (1.00, (0.0, 0.0, 0.0), (0.69, 0.92, 0.81), 0.0),
    (-0.80, (0.0, -0.0184, 0.0), (0.6624, 0.874, 0.78), 0.0),
    (-0.20, (0.22, 0.0, 0.0), (0.11, 0.31, 0.22), -18.0),
    (-0.20, (-0.22, 0.0, 0.0), (0.16, 0.41, 0.28), 18.0),
    (0.10, (0.0, 0.35, -0.15), (0.21, 0.25, 0.41), 0.0),
    (0.10, (0.0, 0.1, 0.25), (0.046, 0.046, 0.05), 0.0),
    (0.10, (-0.08, -0.605, 0.0), (0.046, 0.023, 0.02), 0.0),
    (0.10, (0.06, -0.605, -0.1), (0.023, 0.046, 0.02), 90.0),
)


def _world_grids(geo: ConeGeometry):
    z = geo.voxel_centers_1d(0)
    y = geo.voxel_centers_1d(1)
    x = geo.voxel_centers_1d(2)
    return np.meshgrid(z, y, x, indexing="ij")


def sphere(geo: ConeGeometry, center=(0.0, 0.0, 0.0), radius: float | None = None,
           value: float = 1.0) -> np.ndarray:
    """A uniform ball; ``center`` in world (x, y, z), radius in world units."""
    if radius is None:
        radius = 0.35 * min(geo.s_voxel)
    zz, yy, xx = _world_grids(geo)
    cx, cy, cz = center
    r2 = (xx - cx) ** 2 + (yy - cy) ** 2 + (zz - cz) ** 2
    return (value * (r2 <= radius * radius)).astype(np.float32)


def sphere_projection_analytic(geo: ConeGeometry, angles: np.ndarray,
                               center=(0.0, 0.0, 0.0), radius: float | None = None,
                               value: float = 1.0) -> np.ndarray:
    """Exact cone-beam line integrals of the ball: chord length * value.

    For a ray  p(t) = S + t d  (d unit) and ball (c, R):
        chord = 2 sqrt(R^2 - b^2),  b = || (S - c) - ((S - c).d) d ||.
    """
    if radius is None:
        radius = 0.35 * min(geo.s_voxel)
    angles = np.asarray(angles, dtype=np.float64)
    n_angles = angles.shape[0]
    nv, nu = geo.n_detector
    u = geo.detector_coords_1d(1)  # (Nu,)
    v = geo.detector_coords_1d(0)  # (Nv,)
    cx, cy, cz = center
    out = np.zeros((n_angles, nv, nu), dtype=np.float64)
    for a, th in enumerate(angles):
        cth, sth = np.cos(th), np.sin(th)
        S = np.array([geo.DSO * cth, geo.DSO * sth, 0.0])
        det_c = np.array([-(geo.DSD - geo.DSO) * cth, -(geo.DSD - geo.DSO) * sth, 0.0])
        e_u = np.array([-sth, cth, 0.0])
        e_v = np.array([0.0, 0.0, 1.0])
        P = (det_c[None, None, :]
             + u[None, :, None] * e_u[None, None, :]
             + v[:, None, None] * e_v[None, None, :])
        D = P - S[None, None, :]
        D = D / np.linalg.norm(D, axis=-1, keepdims=True)
        SC = S - np.array([cx, cy, cz])
        proj_len = D @ SC  # (Nv, Nu)
        b2 = (SC @ SC) - proj_len ** 2
        chord2 = radius * radius - b2
        out[a] = 2.0 * value * np.sqrt(np.maximum(chord2, 0.0))
    return out.astype(np.float32)


def shepp_logan(geo: ConeGeometry, ellipsoids: Sequence[Ellipsoid] = SHEPP_LIKE) -> np.ndarray:
    """Rasterise the ellipsoid set onto the voxel grid (additive values)."""
    zz, yy, xx = _world_grids(geo)
    half = np.array([geo.s_voxel[2], geo.s_voxel[1], geo.s_voxel[0]]) / 2.0
    vol = np.zeros(geo.n_voxel, dtype=np.float32)
    for value, (cx, cy, cz), (ax, ay, az), phi_deg in ellipsoids:
        phi = np.deg2rad(phi_deg)
        c, s = np.cos(phi), np.sin(phi)
        # normalised coords
        xn = xx / half[0] - cx
        yn = yy / half[1] - cy
        zn = zz / half[2] - cz
        xr = c * xn + s * yn
        yr = -s * xn + c * yn
        inside = (xr / ax) ** 2 + (yr / ay) ** 2 + (zn / az) ** 2 <= 1.0
        vol += value * inside.astype(np.float32)
    return vol


def shepp_logan_projection_analytic(geo: ConeGeometry, angles: np.ndarray,
                                    ellipsoids: Sequence[Ellipsoid] = SHEPP_LIKE
                                    ) -> np.ndarray:
    """Exact line integrals of the ellipsoid set (sum of per-ellipsoid chords).

    Each ellipsoid is mapped to the unit ball by an affine transform; the
    chord length in world space is the parametric interval length where the
    transformed ray intersects the unit sphere.
    """
    angles = np.asarray(angles, dtype=np.float64)
    nv, nu = geo.n_detector
    u = geo.detector_coords_1d(1)
    v = geo.detector_coords_1d(0)
    half = np.array([geo.s_voxel[2], geo.s_voxel[1], geo.s_voxel[0]]) / 2.0
    out = np.zeros((angles.shape[0], nv, nu), dtype=np.float64)
    for a, th in enumerate(angles):
        cth, sth = np.cos(th), np.sin(th)
        S = np.array([geo.DSO * cth, geo.DSO * sth, 0.0])
        det_c = np.array([-(geo.DSD - geo.DSO) * cth, -(geo.DSD - geo.DSO) * sth, 0.0])
        e_u = np.array([-sth, cth, 0.0])
        e_v = np.array([0.0, 0.0, 1.0])
        P = (det_c[None, None, :]
             + u[None, :, None] * e_u[None, None, :]
             + v[:, None, None] * e_v[None, None, :])
        D = P - S[None, None, :]
        Dn = D / np.linalg.norm(D, axis=-1, keepdims=True)
        for value, (cx, cy, cz), (ax, ay, az), phi_deg in ellipsoids:
            phi = np.deg2rad(phi_deg)
            c, s = np.cos(phi), np.sin(phi)
            R = np.array([[c, s, 0.0], [-s, c, 0.0], [0.0, 0.0, 1.0]])
            scale = 1.0 / (np.array([ax, ay, az]) * half)
            ctr = np.array([cx, cy, cz]) * half
            S_t = (R @ (S - ctr)) * scale
            D_t = np.einsum("ij,uvj->uvi", R, Dn) * scale[None, None, :]
            A = np.sum(D_t * D_t, axis=-1)
            B = 2.0 * np.sum(D_t * S_t[None, None, :], axis=-1)
            C = float(S_t @ S_t) - 1.0
            disc = B * B - 4.0 * A * C
            ok = disc > 0
            dt = np.where(ok, np.sqrt(np.maximum(disc, 0.0)) / A, 0.0)
            out[a] += value * dt  # world chord = |t1-t0| since Dn is unit
    return out.astype(np.float32)
