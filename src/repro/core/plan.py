"""Unified execution-plan IR: one planner output for every executor.

The paper's central claim is that the splitting decisions — how the image
is cut into axial slabs, how angles are chunked and assigned to devices —
are independent of both the algorithm and the kernels that execute them
(TIGRE: "all of the GPU code is independent from the algorithm that uses
it").  Historically this repo re-derived that structure in three places:
the executors interpreted :func:`~repro.core.splitting.plan_forward` /
:func:`~repro.core.splitting.plan_backward` ad hoc, and the serving layer
re-ran the planners to price jobs.  :class:`ExecutionPlan` makes the
partition/communication schedule a first-class object instead: a single
memoized :func:`plan` entry point produces one IR that

* the executors consume verbatim (``CTOperator`` plain / stream / dist
  iterate the plan's slab ranges and angle chunks),
* the kernel-backend registry (:mod:`repro.core.backend`) keys its
  cached-jit dispatch table on (the static plan args are exactly the jit
  static args), and
* the serving cost model reads — footprints, modeled pass counts and
  host<->device transfer bytes come off the plan, never from re-invoked
  planners (``serve/scheduler.py``, ``serve/pool.py`` routing and
  ``serve/steal.py``'s benefit checks all price through here).

The IR is pure Python/numpy (static): it feeds jit-compiled executors
without retracing, and because every field derives deterministically from
``(geo, n_angles, n_devices, memory)`` the memo table can be shared by
every scheduler, pod and benchmark in the process.
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import List, Optional, Tuple

from .geometry import ConeGeometry
from .splitting import (F32, BackwardPlan, ForwardPlan, MemoryModel,
                        plan_backward, plan_forward)


# --------------------------------------------------------------------------
# communication schedule (the paper's Fig 3 / Fig 5 timelines, reified)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CommStep:
    """One entry of a :class:`CommSchedule` step list.

    ``kind`` is ``"h2d"`` (stage host data onto a device), ``"compute"``
    (consume what is staged) or ``"d2h"`` (copy a finished result back).
    ``prefetch`` marks staging issued *ahead* of the step that consumes
    it — the overlap the paper's double buffers buy.  ``nbytes`` is the
    host<->device traffic of the step (0 for compute), so the schedule
    doubles as the transfer cost model.
    """

    kind: str              # "h2d" | "compute" | "d2h"
    op: str                # "fp" | "bp"
    device: int
    slab: int
    chunk: int = -1        # bp projection-chunk index; -1 for fp / d2h
    nbytes: int = 0
    prefetch: bool = False

    def __str__(self):
        tag = {"h2d": "h2d", "compute": "cmp", "d2h": "d2h"}[self.kind]
        if self.prefetch:
            tag += "*"
        loc = f"d{self.device} s{self.slab}"
        if self.chunk >= 0:
            loc += f" c{self.chunk}"
        return f"{tag}[{loc}]"


def _fp_comm_steps(fwd: ForwardPlan, geo: ConeGeometry, n_angles: int,
                   depth: int) -> Tuple[CommStep, ...]:
    """FP step list (paper Alg 1 / Fig 3): every device streams every
    slab; ``depth`` slabs are staged ahead of the one being computed
    (``depth=0`` is the serial single-buffer reference)."""
    _, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    steps: List[CommStep] = []
    staged = 0
    for k in range(fwd.n_slabs):
        hi = min(fwd.n_slabs, k + 1 + max(0, depth))
        for t in range(max(staged, k), hi):
            z0, z1 = fwd.slab_ranges[t]
            for d in range(fwd.n_devices):
                steps.append(CommStep("h2d", "fp", d, t,
                                      nbytes=(z1 - z0) * ny * nx * F32,
                                      prefetch=(t > k)))
        staged = max(staged, hi)
        for d in range(fwd.n_devices):
            steps.append(CommStep("compute", "fp", d, k))
    for d, (a0, a1) in enumerate(fwd.angle_ranges):
        steps.append(CommStep("d2h", "fp", d, -1,
                              nbytes=(a1 - a0) * nv * nu * F32))
    return tuple(steps)


def _bp_comm_steps(bwd: BackwardPlan, geo: ConeGeometry, n_angles: int,
                   depth: int) -> Tuple[CommStep, ...]:
    """BP step list (paper Alg 2 / Fig 5): each slab's owner consumes the
    projection chunks through ``1 + depth`` staging buffers.  When every
    chunk fits in the buffers at once, a device's later slabs *reuse* the
    chunks staged for its first slab (no h2d steps are emitted)."""
    _, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    chunks = [(c, min(c + bwd.angle_chunk, n_angles))
              for c in range(0, n_angles, bwd.angle_chunk)]
    reuse = len(chunks) <= 1 + max(0, depth)
    steps: List[CommStep] = []
    chunks_on: set = set()          # devices whose chunks stay resident
    for k, (z0, z1) in enumerate(bwd.slab_ranges):
        d = bwd.device_of_slab[k]
        stage = not (reuse and d in chunks_on)
        if reuse:
            chunks_on.add(d)
        staged = 0
        for ci, (c0, c1) in enumerate(chunks):
            if stage:
                hi = min(len(chunks), ci + 1 + max(0, depth))
                for t in range(max(staged, ci), hi):
                    t0, t1 = chunks[t]
                    steps.append(CommStep(
                        "h2d", "bp", d, k, chunk=t,
                        nbytes=(t1 - t0) * (nv * nu + 1) * F32,
                        prefetch=(t > ci)))
                staged = max(staged, hi)
            steps.append(CommStep("compute", "bp", d, k, chunk=ci))
        steps.append(CommStep("d2h", "bp", d, k,
                              nbytes=(z1 - z0) * ny * nx * F32))
    return tuple(steps)


def hier_group_size(n: int) -> int:
    """Largest divisor of ``n`` that is <= sqrt(n): the intra-group size
    of the hierarchical two-level reduction (1 for primes)."""
    g = 1
    for d in range(2, int(math.isqrt(n)) + 1):
        if n % d == 0:
            g = d
    return g


def choose_reduction(n_shards: int) -> str:
    """Cross-shard reduction schedule for ``n_shards`` model shards.

    ``"psum"`` for <= 2 shards (one hop; also the bit-exact baseline),
    ``"hier"`` (intra-group ring then cross-group hops — Petascale XCT's
    intra-node-before-inter-node shape) when the count factors into
    groups, ``"ring"`` otherwise (primes)."""
    if n_shards <= 2:
        return "psum"
    g = hier_group_size(n_shards)
    if g <= 1 or g >= n_shards:
        return "ring"
    return "hier"


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """When the bytes move: the explicit staging/compute/reduce schedule
    of one :class:`ExecutionPlan`.

    The streaming executors *interpret* ``fp_steps`` / ``bp_steps``
    verbatim (tests assert the interpreted result is bit-identical to the
    serial ``prefetch_depth=0`` reference), the dist operators read
    ``reduction`` / ``dominance_split``, and the serving layer prices
    transfers with :meth:`transfer_seconds` under a measured-bandwidth
    EMA.  Exactly one place decides when bytes move; everything else
    executes or prices it.
    """

    prefetch_depth: int          # slabs/chunks staged ahead of compute
    n_buffers: int               # staging buffers per device (1 + depth)
    reduction: str               # "psum" | "ring" | "hier" (dist FP)
    dominance_split: bool        # host-level single-dominance dist shards
    bp_chunk_reuse: bool         # later slabs reuse resident chunks
    fp_steps: Tuple[CommStep, ...]
    bp_steps: Tuple[CommStep, ...]

    def steps(self, op: str) -> Tuple[CommStep, ...]:
        return self.fp_steps if op == "fp" else self.bp_steps

    def bytes_moved(self, op: Optional[str] = None) -> int:
        """Total host<->device bytes the schedule moves (one ``A`` plus
        one ``At`` pass when ``op`` is None).  Reflects chunk reuse, so
        it can undercut the raw ``transfer_bytes_*`` upper bounds."""
        which = (self.fp_steps + self.bp_steps if op is None
                 else self.steps(op))
        return sum(s.nbytes for s in which)

    def transfer_seconds(self, bandwidth_bytes_per_s: float,
                         op: Optional[str] = None) -> float:
        """Schedule-derived transfer time of one pass at a measured
        effective bandwidth: the busiest device's staged bytes over the
        bandwidth (devices transfer concurrently; contention is already
        folded into the *measured* bandwidth the serving layer feeds
        in)."""
        if bandwidth_bytes_per_s <= 0:
            raise ValueError("bandwidth must be positive")
        which = (self.fp_steps + self.bp_steps if op is None
                 else self.steps(op))
        per_dev: dict = {}
        for s in which:
            per_dev[s.device] = per_dev.get(s.device, 0) + s.nbytes
        return max(per_dev.values(), default=0) / bandwidth_bytes_per_s

    def describe(self, max_steps: int = 8) -> str:
        """Step-list summary (docs / benchmarks): totals per op plus the
        first ``max_steps`` steps (``*`` marks prefetch)."""
        lines = [f"CommSchedule(depth={self.prefetch_depth}, "
                 f"buffers={self.n_buffers}, reduction={self.reduction}, "
                 f"dominance_split={self.dominance_split}, "
                 f"bp_chunk_reuse={self.bp_chunk_reuse})"]
        for op in ("fp", "bp"):
            steps = self.steps(op)
            shown = " ".join(str(s) for s in steps[:max_steps])
            if len(steps) > max_steps:
                shown += f" ... +{len(steps) - max_steps}"
            lines.append(f"  {op}: {len(steps)} steps, "
                         f"{self.bytes_moved(op)} B: {shown}")
        return "\n".join(lines)


def build_comm_schedule(geo: ConeGeometry, n_angles: int,
                        forward: ForwardPlan, backward: BackwardPlan,
                        prefetch_depth: int = 1) -> CommSchedule:
    """Derive the deterministic communication schedule of a plan."""
    depth = max(0, int(prefetch_depth))
    n_chunks = math.ceil(n_angles / backward.angle_chunk) if n_angles else 0
    return CommSchedule(
        prefetch_depth=depth,
        n_buffers=1 + depth,
        reduction=choose_reduction(max(forward.n_devices,
                                       backward.n_devices)),
        dominance_split=True,
        bp_chunk_reuse=n_chunks <= 1 + depth,
        fp_steps=_fp_comm_steps(forward, geo, n_angles, depth),
        bp_steps=_bp_comm_steps(backward, geo, n_angles, depth))


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The partition/communication schedule for one (geometry, workload).

    One plan covers *both* operators: ``forward`` holds the FP schedule
    (paper Alg 1 — angles across devices, z-slabs sized to the budget)
    and ``backward`` the BP schedule (paper Alg 2 — slab queues across
    devices, angle-chunk double buffers).  Everything below is derived,
    so consumers never re-run the planners.
    """

    geo: ConeGeometry
    n_angles: int
    n_devices: int
    memory: MemoryModel
    forward: ForwardPlan
    backward: BackwardPlan
    #: the communication schedule (derived in __post_init__ when omitted,
    #: so direct constructions stay valid)
    comm: Optional[CommSchedule] = None

    def __post_init__(self):
        if self.comm is None:
            object.__setattr__(self, "comm", build_comm_schedule(
                self.geo, self.n_angles, self.forward, self.backward))

    def with_prefetch(self, depth: int) -> "ExecutionPlan":
        """Same partition, different overlap: a copy whose schedule
        stages ``depth`` slabs/chunks ahead (``0`` = the serial
        no-prefetch reference the parity tests and the bench's
        overlap-off arm use)."""
        return dataclasses.replace(self, comm=build_comm_schedule(
            self.geo, self.n_angles, self.forward, self.backward,
            prefetch_depth=depth))

    # ---- structure (what the executors iterate) ----------------------------

    @property
    def streams(self) -> bool:
        """True when either operator must split the volume: the workload
        cannot be held resident and belongs on the out-of-core path."""
        return self.forward.n_slabs > 1 or self.backward.n_slabs > 1

    @property
    def slab_ranges(self) -> List[Tuple[int, int]]:
        """Union schedule: the finer of the two operators' slab splits
        (forward and backward agree on (0, nz) when nothing splits)."""
        if self.forward.n_slabs >= self.backward.n_slabs:
            return list(self.forward.slab_ranges)
        return list(self.backward.slab_ranges)

    @property
    def device_of_slab(self) -> List[int]:
        """Backward-pass slab ownership (forward slabs stream on every
        device; backward slabs are round-robin queued, paper SS2.2)."""
        return list(self.backward.device_of_slab)

    @property
    def angle_ranges(self) -> List[Tuple[int, int]]:
        """Forward-pass per-device angle assignment (paper SS2.1)."""
        return list(self.forward.angle_ranges)

    # ---- cost model (what the serving layer prices with) -------------------

    @property
    def step_passes(self) -> float:
        """Relative cost of one outer iteration in units of an in-core
        iteration (= 1.0).  A streamed iteration re-stages the volume once
        per forward slab and the projections once per backward slab, so it
        costs ``(fp slabs + bp slabs) / 2`` — the one cost model shared by
        deadline admission, multi-pod routing and the stealing benefit
        check."""
        if not self.streams:
            return 1.0
        return (self.forward.n_slabs + self.backward.n_slabs) / 2.0

    @property
    def stream_bytes_on_device(self) -> int:
        """Per-device working set of the out-of-core executors: the larger
        of the two operators' ``slab + projection buffers`` budgets."""
        return max(
            self.forward.bytes_image_slab + self.forward.bytes_proj_buffers,
            self.backward.bytes_image_slab + self.backward.bytes_proj_buffers)

    @property
    def vol_bytes(self) -> int:
        nz, ny, nx = self.geo.n_voxel
        return nz * ny * nx * F32

    @property
    def proj_bytes(self) -> int:
        nv, nu = self.geo.n_detector
        return self.n_angles * nv * nu * F32

    @property
    def transfer_bytes_forward(self) -> int:
        """Host<->device bytes one FP pass moves: every device streams the
        whole volume slab by slab (paper Fig 3), and each device's partial
        projections come back once."""
        return self.n_devices * self.vol_bytes + self.proj_bytes

    @property
    def transfer_bytes_backward(self) -> int:
        """Host<->device bytes one BP pass moves: every slab's owner
        consumes the entire projection set through its double buffer
        (paper Fig 5), and each finished slab comes back once."""
        return self.backward.n_slabs * self.proj_bytes + self.vol_bytes

    @property
    def transfer_bytes(self) -> int:
        """One ``A`` plus one ``At`` pass (a gradient-like iteration)."""
        return self.transfer_bytes_forward + self.transfer_bytes_backward

    def describe(self) -> str:
        """Human-readable one-plan summary (docs / benchmarks)."""
        f, b = self.forward, self.backward
        return (f"ExecutionPlan(vol={self.geo.n_voxel}, "
                f"angles={self.n_angles}, devices={self.n_devices}, "
                f"streams={self.streams}, "
                f"fp: {f.n_slabs} slab(s) x chunk {f.angle_chunk}, "
                f"bp: {b.n_slabs} slab(s) x chunk {b.angle_chunk}, "
                f"passes/iter={self.step_passes:g}, "
                f"device bytes={self.stream_bytes_on_device}, "
                f"comm: depth={self.comm.prefetch_depth} "
                f"reduce={self.comm.reduction})")


@lru_cache(maxsize=1024)
def _plan_cached(geo: ConeGeometry, n_angles: int, n_devices: int,
                 memory: MemoryModel, angle_chunk_fp: int,
                 angle_chunk_bp: int, prefetch_depth: int) -> ExecutionPlan:
    fwd = plan_forward(geo, n_angles, n_devices, memory,
                       angle_chunk=angle_chunk_fp)
    bwd = plan_backward(geo, n_angles, n_devices, memory,
                        angle_chunk=angle_chunk_bp)
    return ExecutionPlan(
        geo=geo, n_angles=n_angles, n_devices=n_devices, memory=memory,
        forward=fwd, backward=bwd,
        comm=build_comm_schedule(geo, n_angles, fwd, bwd,
                                 prefetch_depth=prefetch_depth))


def plan(geo: ConeGeometry, n_angles: int, n_devices: int = 1,
         memory: Optional[MemoryModel] = None, angle_chunk_fp: int = 16,
         angle_chunk_bp: int = 32, prefetch_depth: int = 1) -> ExecutionPlan:
    """The single planning entry point (subsumes ``plan_forward`` /
    ``plan_backward``).  Memoized: every consumer in the process —
    operators, streaming executors, schedulers, routing, stealing,
    benchmarks — shares one plan object per (geometry, workload, budget),
    so the pure-python planners never re-run on a hot path.

    Raises :class:`MemoryError` (not cached) when even one image plane
    plus the projection buffers exceed the budget."""
    from .. import obs
    if not obs.enabled():
        return _plan_cached(geo, int(n_angles), int(n_devices),
                            memory or MemoryModel(),
                            int(angle_chunk_fp), int(angle_chunk_bp),
                            int(prefetch_depth))
    # Span only the memo *misses*: hits are sub-microsecond dict lookups
    # and the serving layer's load polling would flood the ring with them.
    # An abandoned begin() handle costs nothing (miss check is advisory
    # under concurrent planners).
    misses0 = _plan_cached.cache_info().misses
    h = obs.begin("plan", "plan", n_angles=int(n_angles),
                  n_devices=int(n_devices))
    out = _plan_cached(geo, int(n_angles), int(n_devices),
                       memory or MemoryModel(),
                       int(angle_chunk_fp), int(angle_chunk_bp),
                       int(prefetch_depth))
    if _plan_cached.cache_info().misses != misses0:
        obs.end(h)
    return out


def plan_cache_info():
    """Memo-table statistics (hits/misses/currsize) — the regression tests
    assert the serving layer's load polling stays on the cache."""
    return _plan_cached.cache_info()


def plan_cache_clear() -> None:
    _plan_cached.cache_clear()
