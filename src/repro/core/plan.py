"""Unified execution-plan IR: one planner output for every executor.

The paper's central claim is that the splitting decisions — how the image
is cut into axial slabs, how angles are chunked and assigned to devices —
are independent of both the algorithm and the kernels that execute them
(TIGRE: "all of the GPU code is independent from the algorithm that uses
it").  Historically this repo re-derived that structure in three places:
the executors interpreted :func:`~repro.core.splitting.plan_forward` /
:func:`~repro.core.splitting.plan_backward` ad hoc, and the serving layer
re-ran the planners to price jobs.  :class:`ExecutionPlan` makes the
partition/communication schedule a first-class object instead: a single
memoized :func:`plan` entry point produces one IR that

* the executors consume verbatim (``CTOperator`` plain / stream / dist
  iterate the plan's slab ranges and angle chunks),
* the kernel-backend registry (:mod:`repro.core.backend`) keys its
  cached-jit dispatch table on (the static plan args are exactly the jit
  static args), and
* the serving cost model reads — footprints, modeled pass counts and
  host<->device transfer bytes come off the plan, never from re-invoked
  planners (``serve/scheduler.py``, ``serve/pool.py`` routing and
  ``serve/steal.py``'s benefit checks all price through here).

The IR is pure Python/numpy (static): it feeds jit-compiled executors
without retracing, and because every field derives deterministically from
``(geo, n_angles, n_devices, memory)`` the memo table can be shared by
every scheduler, pod and benchmark in the process.
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import List, Optional, Tuple

from .geometry import ConeGeometry
from .splitting import (F32, BackwardPlan, ForwardPlan, MemoryModel,
                        plan_backward, plan_forward)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The partition/communication schedule for one (geometry, workload).

    One plan covers *both* operators: ``forward`` holds the FP schedule
    (paper Alg 1 — angles across devices, z-slabs sized to the budget)
    and ``backward`` the BP schedule (paper Alg 2 — slab queues across
    devices, angle-chunk double buffers).  Everything below is derived,
    so consumers never re-run the planners.
    """

    geo: ConeGeometry
    n_angles: int
    n_devices: int
    memory: MemoryModel
    forward: ForwardPlan
    backward: BackwardPlan

    # ---- structure (what the executors iterate) ----------------------------

    @property
    def streams(self) -> bool:
        """True when either operator must split the volume: the workload
        cannot be held resident and belongs on the out-of-core path."""
        return self.forward.n_slabs > 1 or self.backward.n_slabs > 1

    @property
    def slab_ranges(self) -> List[Tuple[int, int]]:
        """Union schedule: the finer of the two operators' slab splits
        (forward and backward agree on (0, nz) when nothing splits)."""
        if self.forward.n_slabs >= self.backward.n_slabs:
            return list(self.forward.slab_ranges)
        return list(self.backward.slab_ranges)

    @property
    def device_of_slab(self) -> List[int]:
        """Backward-pass slab ownership (forward slabs stream on every
        device; backward slabs are round-robin queued, paper SS2.2)."""
        return list(self.backward.device_of_slab)

    @property
    def angle_ranges(self) -> List[Tuple[int, int]]:
        """Forward-pass per-device angle assignment (paper SS2.1)."""
        return list(self.forward.angle_ranges)

    # ---- cost model (what the serving layer prices with) -------------------

    @property
    def step_passes(self) -> float:
        """Relative cost of one outer iteration in units of an in-core
        iteration (= 1.0).  A streamed iteration re-stages the volume once
        per forward slab and the projections once per backward slab, so it
        costs ``(fp slabs + bp slabs) / 2`` — the one cost model shared by
        deadline admission, multi-pod routing and the stealing benefit
        check."""
        if not self.streams:
            return 1.0
        return (self.forward.n_slabs + self.backward.n_slabs) / 2.0

    @property
    def stream_bytes_on_device(self) -> int:
        """Per-device working set of the out-of-core executors: the larger
        of the two operators' ``slab + projection buffers`` budgets."""
        return max(
            self.forward.bytes_image_slab + self.forward.bytes_proj_buffers,
            self.backward.bytes_image_slab + self.backward.bytes_proj_buffers)

    @property
    def vol_bytes(self) -> int:
        nz, ny, nx = self.geo.n_voxel
        return nz * ny * nx * F32

    @property
    def proj_bytes(self) -> int:
        nv, nu = self.geo.n_detector
        return self.n_angles * nv * nu * F32

    @property
    def transfer_bytes_forward(self) -> int:
        """Host<->device bytes one FP pass moves: every device streams the
        whole volume slab by slab (paper Fig 3), and each device's partial
        projections come back once."""
        return self.n_devices * self.vol_bytes + self.proj_bytes

    @property
    def transfer_bytes_backward(self) -> int:
        """Host<->device bytes one BP pass moves: every slab's owner
        consumes the entire projection set through its double buffer
        (paper Fig 5), and each finished slab comes back once."""
        return self.backward.n_slabs * self.proj_bytes + self.vol_bytes

    @property
    def transfer_bytes(self) -> int:
        """One ``A`` plus one ``At`` pass (a gradient-like iteration)."""
        return self.transfer_bytes_forward + self.transfer_bytes_backward

    def describe(self) -> str:
        """Human-readable one-plan summary (docs / benchmarks)."""
        f, b = self.forward, self.backward
        return (f"ExecutionPlan(vol={self.geo.n_voxel}, "
                f"angles={self.n_angles}, devices={self.n_devices}, "
                f"streams={self.streams}, "
                f"fp: {f.n_slabs} slab(s) x chunk {f.angle_chunk}, "
                f"bp: {b.n_slabs} slab(s) x chunk {b.angle_chunk}, "
                f"passes/iter={self.step_passes:g}, "
                f"device bytes={self.stream_bytes_on_device})")


@lru_cache(maxsize=1024)
def _plan_cached(geo: ConeGeometry, n_angles: int, n_devices: int,
                 memory: MemoryModel, angle_chunk_fp: int,
                 angle_chunk_bp: int) -> ExecutionPlan:
    return ExecutionPlan(
        geo=geo, n_angles=n_angles, n_devices=n_devices, memory=memory,
        forward=plan_forward(geo, n_angles, n_devices, memory,
                             angle_chunk=angle_chunk_fp),
        backward=plan_backward(geo, n_angles, n_devices, memory,
                               angle_chunk=angle_chunk_bp))


def plan(geo: ConeGeometry, n_angles: int, n_devices: int = 1,
         memory: Optional[MemoryModel] = None, angle_chunk_fp: int = 16,
         angle_chunk_bp: int = 32) -> ExecutionPlan:
    """The single planning entry point (subsumes ``plan_forward`` /
    ``plan_backward``).  Memoized: every consumer in the process —
    operators, streaming executors, schedulers, routing, stealing,
    benchmarks — shares one plan object per (geometry, workload, budget),
    so the pure-python planners never re-run on a hot path.

    Raises :class:`MemoryError` (not cached) when even one image plane
    plus the projection buffers exceed the budget."""
    from .. import obs
    if not obs.enabled():
        return _plan_cached(geo, int(n_angles), int(n_devices),
                            memory or MemoryModel(),
                            int(angle_chunk_fp), int(angle_chunk_bp))
    # Span only the memo *misses*: hits are sub-microsecond dict lookups
    # and the serving layer's load polling would flood the ring with them.
    # An abandoned begin() handle costs nothing (miss check is advisory
    # under concurrent planners).
    misses0 = _plan_cached.cache_info().misses
    h = obs.begin("plan", "plan", n_angles=int(n_angles),
                  n_devices=int(n_devices))
    out = _plan_cached(geo, int(n_angles), int(n_devices),
                       memory or MemoryModel(),
                       int(angle_chunk_fp), int(angle_chunk_bp))
    if _plan_cached.cache_info().misses != misses0:
        obs.end(h)
    return out


def plan_cache_info():
    """Memo-table statistics (hits/misses/currsize) — the regression tests
    assert the serving layer's load polling stays on the cache."""
    return _plan_cached.cache_info()


def plan_cache_clear() -> None:
    _plan_cached.cache_clear()
