"""Pure-JAX cone-beam projection operators (the ``A`` and ``A^T`` of eq. 1).

Two forward projectors, mirroring TIGRE's pair (paper SS2.1):

* ``forward_project_interp`` -- uniform-step sampled line integral with
  trilinear interpolation ("interpolated projector").  Simple and obviously
  correct; used as the oracle in tests.
* ``forward_project_joseph`` -- Joseph's method with a per-angle dominant
  axis ("ray-driven" analogue).  This is the production path: its sample
  planes coincide with voxel planes of the marching axis, which (a) makes
  slab decomposition *exact* (paper's splitting claim) and (b) maps onto a
  Pallas grid pipeline with dense, regular per-plane bilinear reads -- the
  TPU adaptation of TIGRE's texture-cache layout (see DESIGN.md SS4).

Backprojectors (paper SS2.2):

* ``backproject_voxel`` -- voxel-driven with ``fdk`` or ``pmatched``
  weights (TIGRE's two weightings).
* ``backproject_matched`` -- the *exact* adjoint of ``forward_project_joseph``
  obtained with ``jax.vjp``; used by CGLS/FISTA where a true matched pair
  is required.

All functions are jit-friendly: geometry is static (closed over), ``angles``
is a traced array.  Volumes are ``(Nz, Ny, Nx)`` float32, projections
``(n_angles, Nv, Nu)`` float32.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .geometry import ConeGeometry


# --------------------------------------------------------------------------
# small interpolation helpers (zero outside the grid)
# --------------------------------------------------------------------------

def bilinear_gather(img: jnp.ndarray, fi: jnp.ndarray, fj: jnp.ndarray) -> jnp.ndarray:
    """Bilinear sample of ``img[(Ni, Nj)]`` at float indices; 0 outside."""
    ni, nj = img.shape
    i0 = jnp.floor(fi)
    j0 = jnp.floor(fj)
    wi = fi - i0
    wj = fj - j0
    i0 = i0.astype(jnp.int32)
    j0 = j0.astype(jnp.int32)

    def tap(ii, jj, w):
        valid = (ii >= 0) & (ii < ni) & (jj >= 0) & (jj < nj)
        v = img[jnp.clip(ii, 0, ni - 1), jnp.clip(jj, 0, nj - 1)]
        return jnp.where(valid, v * w, 0.0)

    return (tap(i0, j0, (1 - wi) * (1 - wj))
            + tap(i0, j0 + 1, (1 - wi) * wj)
            + tap(i0 + 1, j0, wi * (1 - wj))
            + tap(i0 + 1, j0 + 1, wi * wj))


def trilinear_gather(vol: jnp.ndarray, fk: jnp.ndarray, fj: jnp.ndarray,
                     fi: jnp.ndarray) -> jnp.ndarray:
    """Trilinear sample of ``vol[(Nz, Ny, Nx)]`` at float indices; 0 outside."""
    nk, nj, ni = vol.shape
    k0 = jnp.floor(fk); j0 = jnp.floor(fj); i0 = jnp.floor(fi)
    wk = fk - k0; wj = fj - j0; wi = fi - i0
    k0 = k0.astype(jnp.int32); j0 = j0.astype(jnp.int32); i0 = i0.astype(jnp.int32)

    def tap(kk, jj, ii, w):
        valid = ((kk >= 0) & (kk < nk) & (jj >= 0) & (jj < nj)
                 & (ii >= 0) & (ii < ni))
        v = vol[jnp.clip(kk, 0, nk - 1), jnp.clip(jj, 0, nj - 1),
                jnp.clip(ii, 0, ni - 1)]
        return jnp.where(valid, v * w, 0.0)

    out = 0.0
    for dk in (0, 1):
        for dj in (0, 1):
            for di in (0, 1):
                w = ((wk if dk else 1 - wk) * (wj if dj else 1 - wj)
                     * (wi if di else 1 - wi))
                out = out + tap(k0 + dk, j0 + dj, i0 + di, w)
    return out


# --------------------------------------------------------------------------
# detector / pixel geometry (traced, per angle)
# --------------------------------------------------------------------------

def _pixel_world_positions(geo: ConeGeometry, theta: jnp.ndarray):
    """Source position (3,) and pixel positions (Nv, Nu, 3) at one angle."""
    nv, nu = geo.n_detector
    dv, du = geo.d_detector
    offv, offu = geo.off_detector
    cth, sth = jnp.cos(theta), jnp.sin(theta)
    src = jnp.stack([geo.DSO * cth, geo.DSO * sth, jnp.zeros_like(cth)])
    det_c = jnp.stack([-(geo.DSD - geo.DSO) * cth, -(geo.DSD - geo.DSO) * sth,
                       jnp.zeros_like(cth)])
    e_u = jnp.stack([-sth, cth, jnp.zeros_like(cth)])
    e_v = jnp.stack([jnp.zeros_like(cth), jnp.zeros_like(cth), jnp.ones_like(cth)])
    uu = (jnp.arange(nu) - (nu - 1) / 2.0) * du + offu
    vv = (jnp.arange(nv) - (nv - 1) / 2.0) * dv + offv
    pix = (det_c[None, None, :]
           + uu[None, :, None] * e_u[None, None, :]
           + vv[:, None, None] * e_v[None, None, :])
    return src, pix


# --------------------------------------------------------------------------
# interpolated (uniform-step) forward projector -- the oracle
# --------------------------------------------------------------------------

def _aabb_entry_exit(geo: ConeGeometry, src, direction):
    """Entry/exit ray parameters against the volume AABB (slab method)."""
    half = jnp.asarray([geo.s_voxel[2], geo.s_voxel[1], geo.s_voxel[0]]) / 2.0
    off = jnp.asarray([geo.off_origin[2], geo.off_origin[1], geo.off_origin[0]])
    lo = off - half
    hi = off + half
    inv = 1.0 / jnp.where(jnp.abs(direction) < 1e-9,
                          jnp.where(direction >= 0, 1e-9, -1e-9), direction)
    t1 = (lo - src) * inv
    t2 = (hi - src) * inv
    tmin = jnp.max(jnp.minimum(t1, t2), axis=-1)
    tmax = jnp.min(jnp.maximum(t1, t2), axis=-1)
    return tmin, tmax


def forward_project_interp(vol: jnp.ndarray, geo: ConeGeometry,
                           angles: jnp.ndarray, n_samples: int | None = None
                           ) -> jnp.ndarray:
    """Uniform-step sampled cone-beam forward projection (oracle)."""
    if n_samples is None:
        n_samples = 2 * max(geo.n_voxel)
    dz, dy, dx = geo.d_voxel
    offz, offy, offx = geo.off_origin
    nz, ny, nx = geo.n_voxel

    def one_angle(theta):
        src, pix = _pixel_world_positions(geo, theta)
        d = pix - src[None, None, :]
        norm = jnp.linalg.norm(d, axis=-1)
        dn = d / norm[..., None]
        tmin, tmax = _aabb_entry_exit(geo, src, dn)
        hit = tmax > tmin
        length = jnp.where(hit, tmax - tmin, 0.0)
        dt = length / n_samples

        def body(s, acc):
            t = tmin + (s + 0.5) * dt
            p = src[None, None, :] + t[..., None] * dn
            fk = (p[..., 2] - offz) / dz + (nz - 1) / 2.0
            fj = (p[..., 1] - offy) / dy + (ny - 1) / 2.0
            fi = (p[..., 0] - offx) / dx + (nx - 1) / 2.0
            return acc + trilinear_gather(vol, fk, fj, fi)

        acc = jax.lax.fori_loop(0, n_samples, body,
                                jnp.zeros(geo.n_detector, jnp.float32))
        return acc * dt

    return jax.lax.map(one_angle, angles)


# --------------------------------------------------------------------------
# Joseph forward projector (production path)
# --------------------------------------------------------------------------

def _rotate_vol_90(vol: jnp.ndarray) -> jnp.ndarray:
    """Volume of the scene rotated by -90 deg about z.

    f'(x', y', z) = f(-y', x', z)  =>  vol' = flip(transpose(vol, (0,2,1)), 1)
    Requires Nx == Ny and dx == dy (asserted by the caller).
    """
    return jnp.flip(jnp.transpose(vol, (0, 2, 1)), axis=1)


def _joseph_xdom_one_angle(vol, geo: ConeGeometry, theta, x_centers,
                           z0: int = 0):
    """Joseph x-dominant line integral at one angle.

    Marches the x planes whose world coords are ``x_centers``, bilinearly
    interpolating each (z, y) slice.  ``vol`` may be:

    * a slab of x planes (``x_centers`` restricted accordingly), and/or
    * a slab of z planes ``[z0, z0 + vol.shape[0])`` of the full volume.

    Because interpolation taps outside the slab evaluate to zero, the sum
    of slab results over a disjoint plane partition equals the monolithic
    integral *exactly* (paper's splitting claim; see tests/test_splitting).
    """
    dz, dy, dx = geo.d_voxel
    offz, offy, offx = geo.off_origin
    nz_full = geo.n_voxel[0]
    ny = vol.shape[1]
    n_planes = vol.shape[2]

    src, pix = _pixel_world_positions(geo, theta)
    d = pix - src[None, None, :]                      # (Nv, Nu, 3)
    norm = jnp.linalg.norm(d, axis=-1)
    # arc length per unit x: |d| / |d_x|
    seg = norm / jnp.maximum(jnp.abs(d[..., 0]), 1e-9) * dx
    inv_dx_ray = 1.0 / jnp.where(jnp.abs(d[..., 0]) < 1e-9, 1e-9, d[..., 0])

    def body(p, acc):
        x = x_centers[p]
        s = (x - src[0]) * inv_dx_ray                 # (Nv, Nu)
        y = src[1] + s * d[..., 1]
        z = src[2] + s * d[..., 2]
        fj = (y - offy) / dy + (ny - 1) / 2.0
        fk = (z - offz) / dz + (nz_full - 1) / 2.0 - z0
        # forward ray only (sample between source and detector)
        w = ((s > 0.0) & (s <= 1.0)).astype(vol.dtype)
        return acc + bilinear_gather(vol[:, :, p], fk, fj) * w

    acc = jax.lax.fori_loop(0, n_planes, body,
                            jnp.zeros(geo.n_detector, jnp.float32))
    return acc * seg


def forward_project_joseph(vol: jnp.ndarray, geo: ConeGeometry,
                           angles: jnp.ndarray, xdom: bool = True,
                           z0: int = 0, x_planes: Tuple[int, int] | None = None
                           ) -> jnp.ndarray:
    """Joseph projector for angles that are all x-dominant (``xdom=True``)
    or all y-dominant (``xdom=False``; handled by rotating the scene -90 deg,
    which maps the angle to ``theta - pi/2`` and transposes the volume).

    ``z0`` / ``x_planes`` select a volumetric slab: ``vol`` then holds only
    z planes ``[z0, z0+vol.shape[0])`` and/or marching planes
    ``[x_planes[0], x_planes[1])``; the result is that slab's *partial*
    projection (sum over slabs == monolithic).
    """
    nz, ny, nx = geo.n_voxel
    if not xdom:
        if nx != ny or abs(geo.d_voxel[1] - geo.d_voxel[2]) > 1e-12:
            raise ValueError("y-dominant transpose trick needs square xy grid")
        if any(abs(o) > 0 for o in geo.off_origin[1:]):
            raise ValueError("xy origin offsets unsupported with rotation trick")
        vol = _rotate_vol_90(vol)
        angles = angles - jnp.pi / 2.0

    p0, p1 = (0, nx) if x_planes is None else x_planes
    x_centers = jnp.asarray(
        (np.arange(p0, p1) - (nx - 1) / 2.0) * geo.d_voxel[2]
        + geo.off_origin[2], dtype=jnp.float32)

    def one_angle(theta):
        return _joseph_xdom_one_angle(vol, geo, theta, x_centers, z0=z0)

    return jax.lax.map(one_angle, angles)


def forward_project(vol: jnp.ndarray, geo: ConeGeometry, angles: jnp.ndarray,
                    xdom_mask: np.ndarray | None = None) -> jnp.ndarray:
    """Full Joseph forward projection for an arbitrary mix of angles.

    The dominant axis is a *static* property of each angle (numpy decision),
    so we split the angle set into the x-dominant and y-dominant subsets,
    project each with the specialised path, and scatter the results back.
    This mirrors TIGRE queuing independent per-GPU angle sets (paper SS2.1).
    """
    from .geometry import dominant_axis_mask
    if xdom_mask is None:
        xdom_mask = dominant_axis_mask(np.asarray(angles))  # needs concrete
    xdom_mask = np.asarray(xdom_mask)
    idx_x = np.nonzero(xdom_mask)[0]
    idx_y = np.nonzero(~xdom_mask)[0]
    angles = jnp.asarray(angles)
    n_angles = xdom_mask.shape[0]
    nv, nu = geo.n_detector
    out = jnp.zeros((n_angles, nv, nu), jnp.float32)
    if idx_x.size:
        px = forward_project_joseph(vol, geo, angles[jnp.asarray(idx_x)],
                                    xdom=True)
        out = out.at[jnp.asarray(idx_x)].set(px)
    if idx_y.size:
        py = forward_project_joseph(vol, geo, angles[jnp.asarray(idx_y)],
                                    xdom=False)
        out = out.at[jnp.asarray(idx_y)].set(py)
    return out


# --------------------------------------------------------------------------
# backprojectors
# --------------------------------------------------------------------------

def backproject_voxel(proj: jnp.ndarray, geo: ConeGeometry, angles: jnp.ndarray,
                      weight: str = "fdk", z_start=0,
                      z_planes: int | None = None) -> jnp.ndarray:
    """Voxel-driven backprojection (paper SS2.2).

    ``weight``:
      * ``"fdk"``      -- (DSO / (DSO - p))^2 depth weights (FDK).
      * ``"pmatched"`` -- TIGRE's "pseudo-matched" weighting ~ DSD^2/(DSO-p)^2.
      * ``"none"``     -- plain smearing (used by SART-family with its own
                          normalisation).
    ``z_start`` (traced OK) + ``z_planes`` (static) select an axial slab
    (paper's per-device image pieces); the angle axis is additive, so
    streaming angle chunks and summing reproduces the monolithic result
    exactly.  Returns an un-normalised accumulation over angles;
    algorithm-level constants (d_theta etc.) are applied by the callers.
    """
    nz, ny, nx = geo.n_voxel
    dz, dy, dx = geo.d_voxel
    dv, du = geo.d_detector
    offz, offy, offx = geo.off_origin
    offv, offu = geo.off_detector
    nv, nu = geo.n_detector
    planes = nz if z_planes is None else z_planes

    xs = (jnp.arange(nx) - (nx - 1) / 2.0) * dx + offx
    ys = (jnp.arange(ny) - (ny - 1) / 2.0) * dy + offy
    zs = (jnp.arange(planes) + z_start - (nz - 1) / 2.0) * dz + offz
    nz = planes
    X = xs[None, None, :]
    Y = ys[None, :, None]
    Z = zs[:, None, None]

    def one_angle(carry, inputs):
        theta, p2d = inputs
        cth, sth = jnp.cos(theta), jnp.sin(theta)
        p = X * cth + Y * sth                  # depth along source axis
        q = -X * sth + Y * cth
        depth = geo.DSO - p
        mag = geo.DSD / depth
        fu = (q * mag - offu) / du + (nu - 1) / 2.0
        fv = (Z * mag - offv) / dv + (nv - 1) / 2.0
        # broadcast (Nz,1,1) x (1,Ny,Nx) index fields to the full voxel grid
        val = bilinear_gather(p2d, fv + 0.0 * fu, fu + 0.0 * fv)
        if weight == "fdk":
            w = (geo.DSO / depth) ** 2
        elif weight == "pmatched":
            w = (geo.DSD / depth) ** 2 * (geo.DSO / geo.DSD)
        elif weight == "none":
            w = jnp.ones_like(depth)
        else:
            raise ValueError(f"unknown weight {weight!r}")
        return carry + val * w, None

    init = jnp.zeros((nz, ny, nx), jnp.float32)
    out, _ = jax.lax.scan(one_angle, init, (angles, proj))
    return out


def backproject_matched(proj: jnp.ndarray, geo: ConeGeometry,
                        angles: jnp.ndarray) -> jnp.ndarray:
    """Exact adjoint of ``forward_project`` via ``jax.vjp``.

    Guarantees <Ax, y> == <x, A^T y> to float precision, which CGLS and
    FISTA rely on for convergence.
    """
    from .geometry import dominant_axis_mask
    xdom_mask = dominant_axis_mask(np.asarray(angles))
    zeros = jnp.zeros(geo.n_voxel, jnp.float32)
    _, vjp = jax.vjp(lambda v: forward_project(v, geo, angles, xdom_mask), zeros)
    (vol,) = vjp(proj)
    return vol


def backproject(proj: jnp.ndarray, geo: ConeGeometry, angles: jnp.ndarray,
                weight: str = "fdk") -> jnp.ndarray:
    """Dispatch: ``weight='matched'`` uses the exact adjoint, else voxel-driven."""
    if weight == "matched":
        return backproject_matched(proj, geo, angles)
    return backproject_voxel(proj, geo, angles, weight=weight)
