"""TV regularisation with the paper's halo-buffer splitting (SS2.3, Fig 6).

Two minimisers, as in TIGRE:

* ``minimize_tv`` -- steepest-descent minimisation of smoothed isotropic TV
  (used by ASD-POCS / OS-ASD-POCS).
* ``rof_denoise`` -- Chambolle dual projection for the ROF model (used by
  FISTA-TV style algorithms).

Both are single-voxel-neighbourhood coupled stencils (z radius 1 per
iteration), so a halo of depth ``N_in`` buys ``N_in`` *independent* inner
iterations between synchronisations -- the paper's key observation ("the
depth of the buffer is equal to the amount of independent iterations").

Distributed behaviour and exactness:

* ``dist_minimize_tv`` is *exact*: the TV objective is masked so that halo
  planes beyond the global volume boundary contribute nothing, which makes
  the owned-region gradient identical to the monolithic one at every inner
  iteration (tests/test_regularization.py asserts elementwise equality).
* ``dist_rof_denoise`` carries the dual field ``p`` across rounds
  (re-exchanging its halo), exact on interior planes; the global top/bottom
  boundary planes deviate at the few-ulp-to-1e-3 level because Chambolle's
  div/grad boundary convention cannot be expressed through a constant halo
  (documented; the paper itself accepts boundary-level approximation).
* The global gradient norm is either exact (``psum``) or the paper's
  no-communication approximation ``sqrt(n_shards) * ||g_local||``
  (SS2.3 "assuming uniform distribution along the image samples").

``halo_overhead`` quantifies the redundant halo compute for the ``N_in``
trade-off benchmark (paper found N_in=60 optimal on PCIe; on ICI the
optimum shifts -- see benchmarks/bench_tv_halo.py).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map
from .distributed import halo_exchange


# --------------------------------------------------------------------------
# TV value / gradient (forward differences, z-radius-1 stencil)
# --------------------------------------------------------------------------

def _tv_field(vol: jnp.ndarray, eps: float) -> jnp.ndarray:
    """|grad f| per voxel with edge-replicate (Neumann) forward differences."""
    dz = jnp.diff(vol, axis=0, append=vol[-1:])
    dy = jnp.diff(vol, axis=1, append=vol[:, -1:])
    dx = jnp.diff(vol, axis=2, append=vol[:, :, -1:])
    return jnp.sqrt(dz * dz + dy * dy + dx * dx + eps * eps)


def tv_value(vol: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    return jnp.sum(_tv_field(vol, eps))


def _tv_value_masked(vol: jnp.ndarray, plane_mask: jnp.ndarray,
                     dz_mask: jnp.ndarray, eps: float) -> jnp.ndarray:
    """TV objective of a halo-padded slab, restricted to the global volume.

    ``plane_mask`` zeroes |grad f| contributions of halo planes that lie
    *beyond the global volume*; ``dz_mask`` zeroes the z forward difference
    at the global last plane (reproducing the monolithic edge-replicate
    semantics, where ``append=vol[-1:]`` makes that difference vanish).
    Together these make the owned-region gradient match the monolithic
    gradient exactly.
    """
    dz = jnp.diff(vol, axis=0, append=vol[-1:]) * dz_mask[:, None, None]
    dy = jnp.diff(vol, axis=1, append=vol[:, -1:])
    dx = jnp.diff(vol, axis=2, append=vol[:, :, -1:])
    field = jnp.sqrt(dz * dz + dy * dy + dx * dx + eps * eps)
    return jnp.sum(field * plane_mask[:, None, None])


tv_gradient = jax.grad(tv_value)
_tv_gradient_masked = jax.grad(_tv_value_masked)


def minimize_tv(vol: jnp.ndarray, hyper: float, n_iters: int = 20,
                eps: float = 1e-6) -> jnp.ndarray:
    """TIGRE's ``minimizeTV``: steepest descent with norm-relative steps."""
    def body(_, v):
        g = tv_gradient(v, eps)
        gn = jnp.linalg.norm(g.ravel()) + 1e-12
        return v - hyper * g / gn
    return jax.lax.fori_loop(0, n_iters, body, vol)


# --------------------------------------------------------------------------
# ROF model via Chambolle's dual projection
# --------------------------------------------------------------------------

def _grad3(v):
    gz = jnp.concatenate([v[1:] - v[:-1], jnp.zeros_like(v[-1:])], 0)
    gy = jnp.concatenate([v[:, 1:] - v[:, :-1], jnp.zeros_like(v[:, -1:])], 1)
    gx = jnp.concatenate([v[:, :, 1:] - v[:, :, :-1],
                          jnp.zeros_like(v[:, :, -1:])], 2)
    return gz, gy, gx


def _div3(pz, py, px):
    """Adjoint of ``_grad3`` (Chambolle's boundary convention)."""
    dz = jnp.concatenate([pz[:1], pz[1:-1] - pz[:-2], -pz[-2:-1]], 0) \
        if pz.shape[0] > 1 else pz
    dy = jnp.concatenate([py[:, :1], py[:, 1:-1] - py[:, :-2], -py[:, -2:-1]], 1) \
        if py.shape[1] > 1 else py
    dx = jnp.concatenate([px[:, :, :1], px[:, :, 1:-1] - px[:, :, :-2],
                          -px[:, :, -2:-1]], 2) if px.shape[2] > 1 else px
    return dz + dy + dx


def _rof_step(p, f, tau):
    pz, py, px = p
    gz, gy, gx = _grad3(_div3(pz, py, px) - f)
    denom = 1.0 + tau * jnp.sqrt(gz * gz + gy * gy + gx * gx)
    return ((pz + tau * gz) / denom, (py + tau * gy) / denom,
            (px + tau * gx) / denom)


def rof_denoise(vol: jnp.ndarray, lam: float = 10.0, n_iters: int = 30,
                tau: float = 0.124) -> jnp.ndarray:
    """Chambolle (2004) dual projection for min ||u - vol||^2/2 + TV(u)/lam."""
    f = vol * lam
    p0 = tuple(jnp.zeros_like(vol) for _ in range(3))

    def body(_, p):
        return _rof_step(p, f, tau)

    pz, py, px = jax.lax.fori_loop(0, n_iters, body, p0)
    return vol - _div3(pz, py, px) / lam


# --------------------------------------------------------------------------
# distributed (halo-split) versions -- paper Fig 6
# --------------------------------------------------------------------------

def halo_overhead(planes_local: int, halo: int) -> float:
    """Fraction of redundant stencil work per shard for halo depth ``halo``."""
    return 2.0 * halo / max(planes_local, 1)


def _fake_plane_mask(planes_padded: int, depth: int, axis_name: str,
                     n_shards: int):
    """1.0 on planes that exist in the global volume, 0.0 on out-of-volume
    halo planes (only the first/last shard have those)."""
    idx = jax.lax.axis_index(axis_name)
    pos = jnp.arange(planes_padded)
    fake_low = (pos < depth) & (idx == 0)
    fake_high = (pos >= planes_padded - depth) & (idx == n_shards - 1)
    return jnp.where(fake_low | fake_high, 0.0, 1.0).astype(jnp.float32)


def _global_last_mask(planes_padded: int, depth: int, axis_name: str,
                      n_shards: int):
    """0.0 at the *global* last z plane (top shard only), 1.0 elsewhere."""
    idx = jax.lax.axis_index(axis_name)
    pos = jnp.arange(planes_padded)
    is_last = (pos == planes_padded - depth - 1) & (idx == n_shards - 1)
    return jnp.where(is_last, 0.0, 1.0).astype(jnp.float32)


def dist_minimize_tv(mesh: Mesh, hyper: float, n_iters: int, n_inner: int,
                     model_axis: str = "model", approx_norm: bool = True,
                     eps: float = 1e-6):
    """Halo-split steepest-descent TV minimiser (exact; see module docs).

    One halo exchange (a single ``ppermute`` pair) per ``n_inner`` inner
    iterations.  ``approx_norm`` selects the paper's no-sync norm estimate.
    """
    n_outer = -(-n_iters // n_inner)

    n_shards = mesh.shape[model_axis]

    def body(vol_slab):
        planes = vol_slab.shape[0]
        padded = planes + 2 * n_inner

        def outer(_, v):
            vp = halo_exchange(v, n_inner, model_axis)
            mask = _fake_plane_mask(padded, n_inner, model_axis, n_shards)
            dz_mask = _global_last_mask(padded, n_inner, model_axis, n_shards)

            def inner(_, vv):
                g = _tv_gradient_masked(vv, mask, dz_mask, eps)
                g_owned = g[n_inner:padded - n_inner]
                sq = jnp.sum(g_owned * g_owned)
                if approx_norm:
                    # paper SS2.3: no collective, assume uniform distribution
                    gn = jnp.sqrt(float(n_shards) * sq)
                else:
                    gn = jnp.sqrt(jax.lax.psum(sq, model_axis))
                return vv - hyper * g / (gn + 1e-12)

            vp = jax.lax.fori_loop(0, n_inner, inner, vp)
            return vp[n_inner:padded - n_inner]

        return jax.lax.fori_loop(0, n_outer, outer, vol_slab)

    fn = shard_map(body, mesh=mesh,
                   in_specs=P(model_axis, None, None),
                   out_specs=P(model_axis, None, None), check_vma=False)
    return jax.jit(fn)


def dist_rof_denoise(mesh: Mesh, lam: float, n_iters: int, n_inner: int,
                     model_axis: str = "model", tau: float = 0.124):
    """Halo-split Chambolle/ROF with a persistent dual field.

    The image ``f`` is exchanged once (it never changes); the three dual
    components exchange their depth-``n_inner`` halos every round.  Memory
    per shard: padded f + 3 padded duals + the slab itself -- matching the
    paper's note that the ROF minimiser needs ~5 image copies.
    """
    n_outer = -(-n_iters // n_inner)

    n_shards = mesh.shape[model_axis]
    # Chambolle's div/grad edge conventions corrupt *two* halo planes on the
    # first inner iteration (one from the first/last-row div special case on
    # top of the usual 1-plane wavefront), so the halo must be one plane
    # deeper than the inner iteration count for bit-exactness -- measured,
    # not assumed: see EXPERIMENTS.md "halo slack" note.
    depth = n_inner + 1

    def body(vol_slab):
        planes = vol_slab.shape[0]
        padded = planes + 2 * depth
        f_pad = halo_exchange(vol_slab, depth, model_axis) * lam
        mask = _fake_plane_mask(padded, depth, model_axis, n_shards)[:, None, None]
        gz_mask = _global_last_mask(padded, depth, model_axis,
                                    n_shards)[:, None, None]
        p = tuple(jnp.zeros_like(f_pad) for _ in range(3))

        def masked_step(p):
            """Chambolle step reproducing the monolithic boundary convention:
            gz vanishes at the global last plane and the dual field is pinned
            to zero on out-of-volume planes (so div reads zeros there, like
            the monolithic p_{-1} == 0)."""
            pz, py, px = p
            gz, gy, gx = _grad3(_div3(pz, py, px) - f_pad)
            gz = gz * gz_mask
            denom = 1.0 + tau * jnp.sqrt(gz * gz + gy * gy + gx * gx)
            p = ((pz + tau * gz) / denom, (py + tau * gy) / denom,
                 (px + tau * gx) / denom)
            return tuple(c * mask for c in p)

        def outer(r, p):
            # refresh dual halos from the owned region of the neighbours
            p = tuple(
                halo_exchange(c[depth:padded - depth], depth, model_axis)
                for c in p)
            return jax.lax.fori_loop(0, n_inner, lambda _, q: masked_step(q), p)

        p = jax.lax.fori_loop(0, n_outer, outer, p)
        # final depth-1 halo so div reads a valid neighbour plane
        p = tuple(halo_exchange(c[depth:padded - depth], 1, model_axis)
                  for c in p)
        u_pad = (f_pad[depth - 1:padded - depth + 1] / lam
                 - _div3(*p) / lam)
        return u_pad[1:1 + planes]

    fn = shard_map(body, mesh=mesh,
                   in_specs=P(model_axis, None, None),
                   out_specs=P(model_axis, None, None), check_vma=False)
    return jax.jit(fn)
