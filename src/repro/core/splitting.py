"""Partition planner: the paper's "Check GPU memory / split" logic (Alg 1-2).

Given a problem (geometry + angle count), a device count, and a per-device
memory budget, the planner decides

* how angles are partitioned across devices (forward projection,
  paper SS2.1: "each GPU will compute a set of independent projections"),
* how many volumetric axial slabs the image must be split into so that
  ``slab + projection double-buffers (+ accumulation buffer)`` fits in the
  budget (paper: "the image is partitioned into same size volumetric axial
  slices stacks, as big as possible"),
* the angle chunk size ``N_angles`` per kernel launch.

The plan is pure Python / numpy (static): it feeds jit-compiled executors
without retracing, and its invariants are property-tested with hypothesis
(tests/test_splitting.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import numpy as np

from .geometry import ConeGeometry

F32 = 4  # bytes


def even_splits(n: int, k: int) -> List[Tuple[int, int]]:
    """Split range(n) into k contiguous, maximally-even (start, stop) pieces."""
    if k <= 0:
        raise ValueError("k must be positive")
    base, extra = divmod(n, k)
    out, s = [], 0
    for i in range(k):
        e = s + base + (1 if i < extra else 0)
        out.append((s, e))
        s = e
    return out


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Per-device memory budget in bytes (11 GiB = paper's GTX 1080 Ti)."""
    device_bytes: int = 11 * (1 << 30)
    # fraction usable for our buffers (leave headroom for code/fragmentation)
    usable_fraction: float = 0.95

    @property
    def usable(self) -> int:
        return int(self.device_bytes * self.usable_fraction)


@dataclasses.dataclass(frozen=True)
class ForwardPlan:
    """Execution plan for the forward projection (paper Alg 1 / Fig 3)."""
    n_devices: int
    angle_ranges: List[Tuple[int, int]]     # per device
    angle_chunk: int                        # N_angles per kernel launch
    n_slabs: int                            # image splits N_sp
    slab_ranges: List[Tuple[int, int]]      # z-plane ranges
    bytes_image_slab: int
    bytes_proj_buffers: int

    @property
    def needs_accumulation(self) -> bool:
        return self.n_slabs > 1


@dataclasses.dataclass(frozen=True)
class BackwardPlan:
    """Execution plan for the backprojection (paper Alg 2 / Fig 5)."""
    n_devices: int
    slab_ranges: List[Tuple[int, int]]      # all slabs, round-robin over devices
    device_of_slab: List[int]
    angle_chunk: int
    bytes_image_slab: int
    bytes_proj_buffers: int

    @property
    def n_slabs(self) -> int:
        return len(self.slab_ranges)


def _proj_bytes(geo: ConeGeometry, n_angles: int) -> int:
    nv, nu = geo.n_detector
    return n_angles * nv * nu * F32


def _slab_bytes(geo: ConeGeometry, planes: int) -> int:
    _, ny, nx = geo.n_voxel
    return planes * ny * nx * F32


def plan_forward(geo: ConeGeometry, n_angles: int, n_devices: int = 1,
                 memory: MemoryModel = MemoryModel(),
                 angle_chunk: int = 16) -> ForwardPlan:
    """Plan FP: angles across devices; z-slabs sized to the memory budget.

    Budget per device (paper SS2.1): image slab + 2 x angle_chunk projection
    double-buffer + (if split) 1 x angle_chunk accumulation buffer.  The
    chunk auto-shrinks (halving) when the buffers alone exceed the budget
    -- tiny simulated devices stay runnable.
    """
    nz = geo.n_voxel[0]
    angle_ranges = even_splits(n_angles, n_devices)
    max_chunk = max(1, math.ceil(n_angles / n_devices))
    angle_chunk = min(angle_chunk, max_chunk)

    # First try: whole volume resident (fast path, no accumulation buffer).
    buf2 = 2 * _proj_bytes(geo, angle_chunk)
    if _slab_bytes(geo, nz) + buf2 <= memory.usable:
        return ForwardPlan(n_devices, angle_ranges, angle_chunk, 1,
                           [(0, nz)], _slab_bytes(geo, nz), buf2)

    # Split: need a third (accumulation) buffer; maximise slab planes.
    while angle_chunk > 1 and \
            3 * _proj_bytes(geo, angle_chunk) >= memory.usable:
        angle_chunk //= 2
    buf3 = 3 * _proj_bytes(geo, angle_chunk)
    avail = memory.usable - buf3
    if avail < _slab_bytes(geo, 1):
        raise MemoryError(
            f"cannot fit projection buffers ({buf3/2**30:.3f} GiB) plus one "
            f"image plane in the device budget")
    planes = max(1, avail // _slab_bytes(geo, 1))
    n_slabs = math.ceil(nz / planes)
    slab_ranges = even_splits(nz, n_slabs)  # paper: same-size slabs
    return ForwardPlan(n_devices, angle_ranges, angle_chunk, n_slabs,
                       slab_ranges, _slab_bytes(geo, slab_ranges[0][1]
                                                - slab_ranges[0][0]), buf3)


def plan_backward(geo: ConeGeometry, n_angles: int, n_devices: int = 1,
                  memory: MemoryModel = MemoryModel(),
                  angle_chunk: int = 32) -> BackwardPlan:
    """Plan BP: image slabs across (and, if needed, queued within) devices.

    Paper SS2.2: the image is split into equal slabs allocated among GPUs; if
    ``total image + buffers`` exceeds the pooled GPU RAM, each device owns a
    queue of more than one slab.  Every device consumes the entire projection
    set through a 2 x angle_chunk double buffer.
    """
    nz = geo.n_voxel[0]
    angle_chunk = min(angle_chunk, n_angles)
    while angle_chunk > 1 and \
            2 * _proj_bytes(geo, angle_chunk) >= memory.usable:
        angle_chunk //= 2
    buf2 = 2 * _proj_bytes(geo, angle_chunk)
    avail = memory.usable - buf2
    if avail < _slab_bytes(geo, 1):
        raise MemoryError(
            f"cannot fit projection buffers ({buf2/2**30:.3f} GiB) plus one "
            f"image plane in the device budget")
    max_planes_per_device = max(1, avail // _slab_bytes(geo, 1))

    # Fewest equal slabs such that each device's largest slab fits.
    n_slabs = n_devices * max(1, math.ceil(
        math.ceil(nz / n_devices) / max_planes_per_device))
    n_slabs = min(n_slabs, nz)
    slab_ranges = even_splits(nz, n_slabs)
    device_of_slab = [i % n_devices for i in range(n_slabs)]
    return BackwardPlan(n_devices, slab_ranges, device_of_slab, angle_chunk,
                        _slab_bytes(geo, slab_ranges[0][1] - slab_ranges[0][0]),
                        buf2)


def paper_size_limits(memory: MemoryModel = MemoryModel(),
                      angle_chunk_fp: int = 16, angle_chunk_bp: int = 32,
                      min_slab_planes: int = 1) -> dict:
    """Reproduce the paper's SS4 napkin numbers: the largest N (N^3 volume,
    N^2 detector, N angles) each operator can handle under the budget."""
    out = {}
    for name, chunk, nbuf in (("forward", angle_chunk_fp, 3),
                              ("backward", angle_chunk_bp, 2)):
        n = 1024
        while True:
            proj = nbuf * chunk * n * n * F32
            slab = min_slab_planes * n * n * F32
            if proj + slab > memory.usable:
                break
            n += 1024
        out[name] = n - 1024
    return out
