"""Out-of-core double-buffered streaming executors (paper Fig 3 / Fig 5).

These executors realise the paper's timelines: the volume lives in *host*
memory (numpy); each device only ever holds one image slab plus two
``angle_chunk``-sized projection buffers.  Overlap of transfer and compute
comes from JAX's asynchronous dispatch: we *prefetch* the next slab
(``device_put`` is queued) before blocking on the current slab's compute,
which is exactly the paper's two-buffer scheme expressed in the JAX
execution model (no CUDA streams needed -- the runtime owns the queues).

On hosts with several devices, each device processes its own angle range
(forward) or slab queue (backward) concurrently, matching the paper's
"each of these instructions is executed for all available GPUs
simultaneously".

The kernels executing each slab come from the backend registry
(:mod:`repro.core.backend`): ``backend="pallas"`` streams the same plan
through the Pallas TPU kernels, ``"ref"`` (resolved default on CPU)
through the pure-JAX projectors.  Either way the compiled slab operators
are shared process-wide through the registry's cached-jit dispatch table
(equal-size slabs guarantee at most two shapes per plan).

A :class:`Timeline` instruments the three bins of the paper's Fig 9
(compute / host-device staging / other memory ops) for the breakdown
benchmark.
"""

from __future__ import annotations

import time
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from .backend import get_backend
from .geometry import ConeGeometry, dominant_axis_mask
from .plan import ExecutionPlan
from .splitting import BackwardPlan, ForwardPlan


class Timeline:
    """Wall-clock bins mirroring paper Fig 9 (compute / staging / other)."""

    def __init__(self):
        self.bins: Dict[str, float] = defaultdict(float)
        self.events: List[tuple] = []

    def add(self, bin_name: str, seconds: float):
        self.bins[bin_name] += seconds
        self.events.append((bin_name, seconds))

    def fractions(self) -> Dict[str, float]:
        total = sum(self.bins.values()) or 1.0
        return {k: v / total for k, v in self.bins.items()}

    def __repr__(self):
        return f"Timeline({dict(self.bins)})"


# Timeline bin -> obs span category (paper Fig 9 bins -> ISSUE 6 phases).
_BIN_CAT = {"staging": "h2d", "compute": "compute", "other_memory": "d2h"}


class _Timed:
    """Times one block into a Timeline bin *and* an obs span.

    The obs span (category from ``_BIN_CAT``, attrs like slab/device/op)
    is only materialised when the process tracer is enabled, so the
    streaming hot loop keeps its zero-overhead default path."""
    __slots__ = ("tl", "name", "sp", "t0")

    def __init__(self, tl, name, attrs, emit_span=True):
        self.tl, self.name = tl, name
        self.sp = (obs.span(name, _BIN_CAT.get(name, name), **attrs)
                   if emit_span else obs.trace._NULL)

    def __enter__(self):
        self.sp.__enter__()
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        if self.tl is not None:
            self.tl.add(self.name, time.monotonic() - self.t0)
        self.sp.__exit__(*a)
        return False


def _timed(tl: Optional[Timeline], name: str, _span: bool = True, **attrs):
    return _Timed(tl, name, attrs, emit_span=_span)


# --------------------------------------------------------------------------
# forward projection streaming (paper Alg 1)
# --------------------------------------------------------------------------


def stream_forward(vol: np.ndarray, geo: ConeGeometry, angles: np.ndarray,
                   plan: Union[ExecutionPlan, ForwardPlan],
                   devices: Optional[Sequence] = None,
                   timeline: Optional[Timeline] = None,
                   backend: Optional[str] = None) -> np.ndarray:
    """Out-of-core forward projection.

    ``vol`` is a host (numpy) array that may exceed device memory; only
    slab-sized pieces are staged.  Angles are partitioned over ``devices``
    (paper SS2.1); each device streams all slabs and accumulates its partial
    projections on-device.  ``plan`` is the unified
    :class:`~repro.core.plan.ExecutionPlan` (its forward schedule is
    iterated verbatim) or a bare ``ForwardPlan``; ``backend`` selects the
    slab kernels ("ref" | "pallas" | "auto"/None).
    """
    if isinstance(plan, ExecutionPlan):
        plan = plan.forward
    bk = get_backend(backend)
    if devices is None:
        devices = jax.local_devices()[: plan.n_devices]
    if len(devices) < plan.n_devices:
        raise ValueError(f"plan wants {plan.n_devices} devices, "
                         f"got {len(devices)}")
    angles = np.asarray(angles, np.float32)
    xmask = dominant_axis_mask(angles)
    nv, nu = geo.n_detector
    out = np.zeros((len(angles), nv, nu), np.float32)

    # Per-device accumulation buffers (device-resident across slabs --
    # paper's "extra projection buffer ... accumulated on the GPU").
    dev_acc: Dict[int, Dict[str, object]] = {}
    for d, (a0, a1) in enumerate(plan.angle_ranges):
        dev_acc[d] = {}
        for key, idx in (("x", np.nonzero(xmask[a0:a1])[0] + a0),
                         ("y", np.nonzero(~xmask[a0:a1])[0] + a0)):
            if idx.size:
                dev_acc[d][key] = {
                    "idx": idx,
                    "angles": jax.device_put(jnp.asarray(angles[idx]),
                                             devices[d]),
                    "acc": jax.device_put(
                        jnp.zeros((idx.size, nv, nu), jnp.float32),
                        devices[d]),
                }

    # Pre-stage slab 0 on every device, then stream: prefetch k+1, compute k.
    def put_slab(k: int, dev):
        z0, z1 = plan.slab_ranges[k]
        return jax.device_put(jnp.asarray(vol[z0:z1]), dev)

    current = {}
    for d in dev_acc:
        with _timed(timeline, "staging", op="fp", slab=0, device=d):
            current[d] = put_slab(0, devices[d])

    for k in range(plan.n_slabs):
        z0, z1 = plan.slab_ranges[k]
        nxt = None
        if k + 1 < plan.n_slabs:
            nxt = {}
            for d in dev_acc:
                with _timed(timeline, "staging", op="fp", slab=k + 1,
                            device=d):
                    nxt[d] = put_slab(k + 1, devices[d])
        # Per-device compute spans use begin/end: the work for every
        # device is *queued* first (async dispatch = the paper's overlap),
        # then each device's span closes when its accumulator is ready.
        # The Timeline bin wraps the whole block; the obs spans are the
        # per-device ones (``_span=False`` avoids double-counted compute).
        with _timed(timeline, "compute", _span=False):
            handles = {}
            for d, groups in dev_acc.items():
                handles[d] = obs.begin("fp_slab", "compute", op="fp",
                                       slab=k, device=d)
                for key, g in groups.items():
                    fp = bk.fp(geo, xdom=(key == "x"))
                    slab = current[d]
                    g["acc"] = g["acc"] + fp(slab, g["angles"], z0)
            for d, groups in dev_acc.items():
                for g in groups.values():
                    g["acc"].block_until_ready()
                obs.end(handles[d])
        current = nxt if nxt is not None else current

    for d, groups in dev_acc.items():
        with _timed(timeline, "other_memory", op="fp", device=d):
            for g in groups.values():
                out[g["idx"]] = np.asarray(g["acc"])
    return out


# --------------------------------------------------------------------------
# backprojection streaming (paper Alg 2)
# --------------------------------------------------------------------------

def stream_backward(proj: np.ndarray, geo: ConeGeometry, angles: np.ndarray,
                    plan: Union[ExecutionPlan, BackwardPlan],
                    weight: str = "fdk",
                    devices: Optional[Sequence] = None,
                    timeline: Optional[Timeline] = None,
                    backend: Optional[str] = None) -> np.ndarray:
    """Out-of-core backprojection: every device consumes the entire
    projection set in ``angle_chunk`` double-buffered pieces while updating
    its resident image slab (paper Fig 5).  ``plan`` is the unified
    :class:`~repro.core.plan.ExecutionPlan` (its backward schedule is
    iterated verbatim) or a bare ``BackwardPlan``; ``backend`` selects the
    slab kernels.  ``weight="matched"`` streams the exact per-slab vjp
    adjoint — always ref-built (see :mod:`repro.core.backend`) so CGLS
    keeps its convergence guarantees out-of-core on every backend."""
    if isinstance(plan, ExecutionPlan):
        plan = plan.backward
    bk = get_backend(backend)
    if devices is None:
        devices = jax.local_devices()[: plan.n_devices]
    if len(devices) < plan.n_devices:
        raise ValueError(f"plan wants {plan.n_devices} devices, "
                         f"got {len(devices)}")
    angles = np.asarray(angles, np.float32)
    n_angles = len(angles)
    vol_out = np.zeros(geo.n_voxel, np.float32)
    chunks = [(c, min(c + plan.angle_chunk, n_angles))
              for c in range(0, n_angles, plan.angle_chunk)]

    xmask = dominant_axis_mask(angles)

    # Slab queue per device (paper: "a queue of image pieces is added").
    for k, (z0, z1) in enumerate(plan.slab_ranges):
        d = plan.device_of_slab[k]
        dev = devices[d]
        bp = None if weight == "matched" else bk.bp(geo, planes=z1 - z0,
                                                    weight=weight)
        acc = jax.device_put(jnp.zeros((z1 - z0,) + tuple(geo.n_voxel[1:]),
                                       jnp.float32), dev)
        # prefetch chunk 0; then stream with one-chunk lookahead
        with _timed(timeline, "staging", op="bp", slab=k, chunk=0, device=d):
            cur = (jax.device_put(jnp.asarray(proj[chunks[0][0]:chunks[0][1]]), dev),
                   jax.device_put(jnp.asarray(angles[chunks[0][0]:chunks[0][1]]), dev),
                   chunks[0])
        for ci, (c0, c1) in enumerate(chunks):
            nxt = None
            if ci + 1 < len(chunks):
                n0, n1 = chunks[ci + 1]
                with _timed(timeline, "staging", op="bp", slab=k,
                            chunk=ci + 1, device=d):
                    nxt = (jax.device_put(jnp.asarray(proj[n0:n1]), dev),
                           jax.device_put(jnp.asarray(angles[n0:n1]), dev),
                           chunks[ci + 1])
            with _timed(timeline, "compute", op="bp", slab=k, chunk=ci,
                        device=d):
                if weight == "matched":
                    # exact adjoint: per-dominance vjp of the slab FP
                    m = xmask[c0:c1]
                    for key, idx in (("x", np.nonzero(m)[0]),
                                     ("y", np.nonzero(~m)[0])):
                        if idx.size == 0:
                            continue
                        fn = bk.bp_matched(geo, planes=z1 - z0,
                                           xdom=(key == "x"))
                        acc = acc + fn(cur[0][jnp.asarray(idx)],
                                       cur[1][jnp.asarray(idx)], z0)
                else:
                    acc = acc + bp(cur[0], cur[1], z0)
                acc.block_until_ready()
            if nxt is not None:
                cur = nxt
        with _timed(timeline, "other_memory", op="bp", slab=k, device=d):
            vol_out[z0:z1] = np.asarray(acc)
    return vol_out
