"""Out-of-core double-buffered streaming executors (paper Fig 3 / Fig 5).

These executors realise the paper's timelines: the volume lives in *host*
memory (numpy); each device only ever holds one image slab plus two
``angle_chunk``-sized projection buffers.  Overlap of transfer and compute
comes from JAX's asynchronous dispatch: we *prefetch* the next slab
(``device_put`` is queued) before blocking on the current slab's compute,
which is exactly the paper's two-buffer scheme expressed in the JAX
execution model (no CUDA streams needed -- the runtime owns the queues).

On hosts with several devices, each device processes its own angle range
(forward) or slab queue (backward) concurrently, matching the paper's
"each of these instructions is executed for all available GPUs
simultaneously".

The kernels executing each slab come from the backend registry
(:mod:`repro.core.backend`): ``backend="pallas"`` streams the same plan
through the Pallas TPU kernels, ``"ref"`` (resolved default on CPU)
through the pure-JAX projectors.  Either way the compiled slab operators
are shared process-wide through the registry's cached-jit dispatch table
(equal-size slabs guarantee at most two shapes per plan).

A :class:`Timeline` instruments the three bins of the paper's Fig 9
(compute / host-device staging / other memory ops) for the breakdown
benchmark.
"""

from __future__ import annotations

import time
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from .backend import get_backend
from .geometry import ConeGeometry, dominant_axis_mask
from .plan import (CommSchedule, ExecutionPlan, _bp_comm_steps,
                   _fp_comm_steps)
from .splitting import BackwardPlan, ForwardPlan


class Timeline:
    """Wall-clock bins mirroring paper Fig 9 (compute / staging / other)."""

    def __init__(self):
        self.bins: Dict[str, float] = defaultdict(float)
        self.events: List[tuple] = []

    def add(self, bin_name: str, seconds: float):
        self.bins[bin_name] += seconds
        self.events.append((bin_name, seconds))

    def fractions(self) -> Dict[str, float]:
        total = sum(self.bins.values()) or 1.0
        return {k: v / total for k, v in self.bins.items()}

    def __repr__(self):
        return f"Timeline({dict(self.bins)})"


# Timeline bin -> obs span category (paper Fig 9 bins -> ISSUE 6 phases).
_BIN_CAT = {"staging": "h2d", "compute": "compute", "other_memory": "d2h"}


class _Timed:
    """Times one block into a Timeline bin *and* an obs span.

    The obs span (category from ``_BIN_CAT`` unless overridden — the
    schedule's lookahead staging reports category ``"prefetch"`` while
    keeping the ``"staging"`` Timeline bin; attrs like slab/device/op/
    bytes) is only materialised when the process tracer is enabled, so
    the streaming hot loop keeps its zero-overhead default path."""
    __slots__ = ("tl", "name", "sp", "t0")

    def __init__(self, tl, name, attrs, emit_span=True, cat=None):
        self.tl, self.name = tl, name
        self.sp = (obs.span(name, cat or _BIN_CAT.get(name, name), **attrs)
                   if emit_span else obs.trace._NULL)

    def __enter__(self):
        self.sp.__enter__()
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        if self.tl is not None:
            self.tl.add(self.name, time.monotonic() - self.t0)
        self.sp.__exit__(*a)
        return False


def _timed(tl: Optional[Timeline], name: str, _span: bool = True,
           _cat: Optional[str] = None, **attrs):
    return _Timed(tl, name, attrs, emit_span=_span, cat=_cat)


def _stage_cat(step) -> str:
    return "prefetch" if step.prefetch else "h2d"


# --------------------------------------------------------------------------
# forward projection streaming (paper Alg 1)
# --------------------------------------------------------------------------


def stream_forward(vol: np.ndarray, geo: ConeGeometry, angles: np.ndarray,
                   plan: Union[ExecutionPlan, ForwardPlan],
                   devices: Optional[Sequence] = None,
                   timeline: Optional[Timeline] = None,
                   backend: Optional[str] = None,
                   comm: Optional[CommSchedule] = None) -> np.ndarray:
    """Out-of-core forward projection: an interpreter over the plan's
    :class:`~repro.core.plan.CommSchedule` FP step list.

    ``vol`` is a host (numpy) array that may exceed device memory; only
    slab-sized pieces are staged, when the schedule says so (prefetch
    ``device_put`` is queued before the current slab's compute blocks —
    the paper's two-buffer overlap).  Angles are partitioned over
    ``devices`` (paper SS2.1); each device streams all slabs and
    accumulates its partial projections on-device, in slab order, so the
    result is bit-identical for every ``prefetch_depth``.  ``plan`` is
    the unified :class:`~repro.core.plan.ExecutionPlan` (its schedule is
    executed verbatim; override with ``comm``, e.g.
    ``plan.with_prefetch(0).comm`` for the serial reference) or a bare
    ``ForwardPlan``; ``backend`` selects the slab kernels
    ("ref" | "pallas" | "auto"/None).
    """
    if isinstance(plan, ExecutionPlan):
        if comm is None:
            comm = plan.comm
        plan = plan.forward
    bk = get_backend(backend)
    if devices is None:
        devices = jax.local_devices()[: plan.n_devices]
    if len(devices) < plan.n_devices:
        raise ValueError(f"plan wants {plan.n_devices} devices, "
                         f"got {len(devices)}")
    angles = np.asarray(angles, np.float32)
    xmask = dominant_axis_mask(angles)
    nv, nu = geo.n_detector
    out = np.zeros((len(angles), nv, nu), np.float32)
    steps = (comm.fp_steps if comm is not None
             else _fp_comm_steps(plan, geo, len(angles), 1))

    # Per-device accumulation buffers (device-resident across slabs --
    # paper's "extra projection buffer ... accumulated on the GPU").
    dev_acc: Dict[int, Dict[str, object]] = {}
    for d, (a0, a1) in enumerate(plan.angle_ranges):
        dev_acc[d] = {}
        for key, idx in (("x", np.nonzero(xmask[a0:a1])[0] + a0),
                         ("y", np.nonzero(~xmask[a0:a1])[0] + a0)):
            if idx.size:
                dev_acc[d][key] = {
                    "idx": idx,
                    "angles": jax.device_put(jnp.asarray(angles[idx]),
                                             devices[d]),
                    "acc": jax.device_put(
                        jnp.zeros((idx.size, nv, nu), jnp.float32),
                        devices[d]),
                }

    # Interpret the step list.  h2d stages a slab (a numpy view goes to
    # device_put directly -- no intermediate host jnp copy); a run of
    # consecutive compute steps is *queued* across all its devices first
    # (async dispatch = the paper's overlap), then each device blocks;
    # d2h copies a device's accumulated projections back.
    staged: Dict[tuple, object] = {}       # (device, slab) -> slab array
    i, n = 0, len(steps)
    while i < n:
        st = steps[i]
        if st.kind == "h2d":
            z0, z1 = plan.slab_ranges[st.slab]
            with _timed(timeline, "staging", _cat=_stage_cat(st), op="fp",
                        slab=st.slab, device=st.device, bytes=st.nbytes):
                staged[(st.device, st.slab)] = jax.device_put(
                    vol[z0:z1], devices[st.device])
            i += 1
        elif st.kind == "compute":
            j = i
            while j < n and steps[j].kind == "compute":
                j += 1
            run = steps[i:j]
            # The Timeline bin wraps the whole block; the obs spans are
            # the per-device ones (_span=False avoids double counting).
            with _timed(timeline, "compute", _span=False):
                handles = []
                for st2 in run:
                    z0, _ = plan.slab_ranges[st2.slab]
                    handles.append(obs.begin("fp_slab", "compute", op="fp",
                                             slab=st2.slab,
                                             device=st2.device))
                    for key, g in dev_acc[st2.device].items():
                        fp = bk.fp(geo, xdom=(key == "x"))
                        g["acc"] = g["acc"] + fp(
                            staged[(st2.device, st2.slab)], g["angles"], z0)
                for st2, h in zip(run, handles):
                    for g in dev_acc[st2.device].values():
                        g["acc"].block_until_ready()
                    obs.end(h)
                for st2 in run:     # slab consumed: free its buffer
                    staged.pop((st2.device, st2.slab), None)
            i = j
        else:  # d2h
            with _timed(timeline, "other_memory", op="fp",
                        device=st.device, bytes=st.nbytes):
                for g in dev_acc[st.device].values():
                    out[g["idx"]] = np.asarray(g["acc"])
            i += 1
    return out


# --------------------------------------------------------------------------
# backprojection streaming (paper Alg 2)
# --------------------------------------------------------------------------

def stream_backward(proj: np.ndarray, geo: ConeGeometry, angles: np.ndarray,
                    plan: Union[ExecutionPlan, BackwardPlan],
                    weight: str = "fdk",
                    devices: Optional[Sequence] = None,
                    timeline: Optional[Timeline] = None,
                    backend: Optional[str] = None,
                    comm: Optional[CommSchedule] = None) -> np.ndarray:
    """Out-of-core backprojection: an interpreter over the plan's
    :class:`~repro.core.plan.CommSchedule` BP step list.

    Every slab's owner consumes the projection set in ``angle_chunk``
    pieces through the schedule's staging buffers while updating its
    resident image slab (paper Fig 5); lookahead chunks are staged
    before the current chunk's compute blocks.  When the schedule says
    every chunk fits resident at once (``bp_chunk_reuse``), a device's
    later slabs reuse the chunks staged for its first slab — the step
    list simply carries no h2d steps for them.  Chunks are always
    accumulated in increasing order per slab, so the result is
    bit-identical for every ``prefetch_depth`` and reuse decision.
    ``plan`` is the unified :class:`~repro.core.plan.ExecutionPlan` (its
    schedule is executed verbatim; override with ``comm``) or a bare
    ``BackwardPlan``.  ``weight="matched"`` streams the backend's exact
    per-slab adjoint (ref: vjp of the slab FP; pallas: the native
    transpose-shaped scatter kernel — see :mod:`repro.core.backend`) so
    CGLS keeps its convergence guarantees out-of-core on every
    backend."""
    if isinstance(plan, ExecutionPlan):
        if comm is None:
            comm = plan.comm
        plan = plan.backward
    bk = get_backend(backend)
    if devices is None:
        devices = jax.local_devices()[: plan.n_devices]
    if len(devices) < plan.n_devices:
        raise ValueError(f"plan wants {plan.n_devices} devices, "
                         f"got {len(devices)}")
    angles = np.asarray(angles, np.float32)
    n_angles = len(angles)
    vol_out = np.zeros(geo.n_voxel, np.float32)
    chunks = [(c, min(c + plan.angle_chunk, n_angles))
              for c in range(0, n_angles, plan.angle_chunk)]
    xmask = dominant_axis_mask(angles)
    if comm is not None:
        steps = comm.bp_steps
        # The memoized schedule covers the plan's full angle set; callers
        # backprojecting a *subset* (OS-SART per-subset norm factors,
        # SART row sweeps) get a step list rebuilt for the angles
        # actually passed, at the same prefetch depth.
        sched_chunks = 1 + max((s.chunk for s in steps if s.chunk >= 0),
                               default=-1)
        if sched_chunks != len(chunks):
            steps = _bp_comm_steps(plan, geo, n_angles,
                                   comm.prefetch_depth)
    else:
        steps = _bp_comm_steps(plan, geo, n_angles, 1)

    # A staged chunk is dropped after its *last* compute use -- derived
    # from the step list itself, so the reuse decision needs no separate
    # flag here (without reuse each chunk has one use; with reuse the
    # last slab of the owning device holds it to the end).
    last_use: Dict[tuple, int] = {}
    for idx, st in enumerate(steps):
        if st.kind == "compute":
            last_use[(st.device, st.chunk)] = idx

    staged: Dict[tuple, tuple] = {}   # (device, chunk) -> (proj, angles)
    acc: Dict[int, object] = {}       # slab -> device accumulator
    for idx, st in enumerate(steps):
        d, dev = st.device, devices[st.device]
        if st.kind == "h2d":
            c0, c1 = chunks[st.chunk]
            with _timed(timeline, "staging", _cat=_stage_cat(st), op="bp",
                        slab=st.slab, chunk=st.chunk, device=d,
                        bytes=st.nbytes):
                # numpy views go to device_put directly: no per-slab
                # host-side jnp copies of the same projection rows
                staged[(d, st.chunk)] = (jax.device_put(proj[c0:c1], dev),
                                         jax.device_put(angles[c0:c1], dev))
        elif st.kind == "compute":
            k, ci = st.slab, st.chunk
            z0, z1 = plan.slab_ranges[k]
            if k not in acc:
                acc[k] = jax.device_put(
                    jnp.zeros((z1 - z0,) + tuple(geo.n_voxel[1:]),
                              jnp.float32), dev)
            cur_p, cur_a = staged[(d, ci)]
            c0, c1 = chunks[ci]
            with _timed(timeline, "compute", op="bp", slab=k, chunk=ci,
                        device=d):
                if weight == "matched":
                    # exact adjoint: the backend's per-dominance matched
                    # slab kernel (ref vjp / pallas scatter)
                    m = xmask[c0:c1]
                    for key, sub in (("x", np.nonzero(m)[0]),
                                     ("y", np.nonzero(~m)[0])):
                        if sub.size == 0:
                            continue
                        fn = bk.bp_matched(geo, planes=z1 - z0,
                                           xdom=(key == "x"))
                        acc[k] = acc[k] + fn(cur_p[jnp.asarray(sub)],
                                             cur_a[jnp.asarray(sub)], z0)
                else:
                    bp = bk.bp(geo, planes=z1 - z0, weight=weight)
                    acc[k] = acc[k] + bp(cur_p, cur_a, z0)
                acc[k].block_until_ready()
            if last_use.get((d, ci)) == idx:
                staged.pop((d, ci), None)
        else:  # d2h
            k = st.slab
            z0, z1 = plan.slab_ranges[k]
            with _timed(timeline, "other_memory", op="bp", slab=k,
                        device=d, bytes=st.nbytes):
                vol_out[z0:z1] = np.asarray(acc.pop(k))
    return vol_out
