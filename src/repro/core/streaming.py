"""Out-of-core double-buffered streaming executors (paper Fig 3 / Fig 5).

These executors realise the paper's timelines: the volume lives in *host*
memory (numpy); each device only ever holds one image slab plus two
``angle_chunk``-sized projection buffers.  Overlap of transfer and compute
comes from JAX's asynchronous dispatch: we *prefetch* the next slab
(``device_put`` is queued) before blocking on the current slab's compute,
which is exactly the paper's two-buffer scheme expressed in the JAX
execution model (no CUDA streams needed -- the runtime owns the queues).

On hosts with several devices, each device processes its own angle range
(forward) or slab queue (backward) concurrently, matching the paper's
"each of these instructions is executed for all available GPUs
simultaneously".

A :class:`Timeline` instruments the three bins of the paper's Fig 9
(compute / host-device staging / other memory ops) for the breakdown
benchmark.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .geometry import ConeGeometry, dominant_axis_mask
from .projector import backproject_voxel, forward_project_joseph
from .splitting import BackwardPlan, ForwardPlan


class Timeline:
    """Wall-clock bins mirroring paper Fig 9 (compute / staging / other)."""

    def __init__(self):
        self.bins: Dict[str, float] = defaultdict(float)
        self.events: List[tuple] = []

    def add(self, bin_name: str, seconds: float):
        self.bins[bin_name] += seconds
        self.events.append((bin_name, seconds))

    def fractions(self) -> Dict[str, float]:
        total = sum(self.bins.values()) or 1.0
        return {k: v / total for k, v in self.bins.items()}

    def __repr__(self):
        return f"Timeline({dict(self.bins)})"


def _timed(tl: Optional[Timeline], name: str):
    class _Ctx:
        def __enter__(self):
            self.t0 = time.monotonic()

        def __exit__(self, *a):
            if tl is not None:
                tl.add(name, time.monotonic() - self.t0)
    return _Ctx()


# --------------------------------------------------------------------------
# forward projection streaming (paper Alg 1)
# --------------------------------------------------------------------------

from functools import lru_cache


@lru_cache(maxsize=None)
def _fp_slab_fn(geo: ConeGeometry, xdom: bool):
    """jit-compiled partial FP of a z slab for a chunk of angles.

    ``z0`` is traced, so every same-shape slab reuses one executable
    (the paper's equal-size slabs guarantee at most two shapes).
    """
    @jax.jit
    def f(slab, angles, z0):
        return forward_project_joseph(slab, geo, angles, xdom=xdom, z0=z0)
    return f


def stream_forward(vol: np.ndarray, geo: ConeGeometry, angles: np.ndarray,
                   plan: ForwardPlan, devices: Optional[Sequence] = None,
                   timeline: Optional[Timeline] = None) -> np.ndarray:
    """Out-of-core forward projection.

    ``vol`` is a host (numpy) array that may exceed device memory; only
    slab-sized pieces are staged.  Angles are partitioned over ``devices``
    (paper SS2.1); each device streams all slabs and accumulates its partial
    projections on-device.
    """
    if devices is None:
        devices = jax.local_devices()[: plan.n_devices]
    if len(devices) < plan.n_devices:
        raise ValueError(f"plan wants {plan.n_devices} devices, "
                         f"got {len(devices)}")
    angles = np.asarray(angles, np.float32)
    xmask = dominant_axis_mask(angles)
    nv, nu = geo.n_detector
    out = np.zeros((len(angles), nv, nu), np.float32)

    # Per-device accumulation buffers (device-resident across slabs --
    # paper's "extra projection buffer ... accumulated on the GPU").
    dev_acc: Dict[int, Dict[str, object]] = {}
    for d, (a0, a1) in enumerate(plan.angle_ranges):
        dev_acc[d] = {}
        for key, idx in (("x", np.nonzero(xmask[a0:a1])[0] + a0),
                         ("y", np.nonzero(~xmask[a0:a1])[0] + a0)):
            if idx.size:
                dev_acc[d][key] = {
                    "idx": idx,
                    "angles": jax.device_put(jnp.asarray(angles[idx]),
                                             devices[d]),
                    "acc": jax.device_put(
                        jnp.zeros((idx.size, nv, nu), jnp.float32),
                        devices[d]),
                }

    # Pre-stage slab 0 on every device, then stream: prefetch k+1, compute k.
    def put_slab(k: int, dev):
        z0, z1 = plan.slab_ranges[k]
        return jax.device_put(jnp.asarray(vol[z0:z1]), dev)

    with _timed(timeline, "staging"):
        current = {d: put_slab(0, devices[d]) for d in dev_acc}

    for k in range(plan.n_slabs):
        z0, z1 = plan.slab_ranges[k]
        nxt = None
        if k + 1 < plan.n_slabs:
            with _timed(timeline, "staging"):
                nxt = {d: put_slab(k + 1, devices[d]) for d in dev_acc}
        with _timed(timeline, "compute"):
            for d, groups in dev_acc.items():
                for key, g in groups.items():
                    fp = _fp_slab_fn(geo, xdom=(key == "x"))
                    slab = current[d]
                    g["acc"] = g["acc"] + fp(slab, g["angles"], z0)
            for d, groups in dev_acc.items():
                for g in groups.values():
                    g["acc"].block_until_ready()
        current = nxt if nxt is not None else current

    with _timed(timeline, "other_memory"):
        for d, groups in dev_acc.items():
            for g in groups.values():
                out[g["idx"]] = np.asarray(g["acc"])
    return out


# --------------------------------------------------------------------------
# backprojection streaming (paper Alg 2)
# --------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _bp_slab_fn(geo: ConeGeometry, planes: int, weight: str):
    @jax.jit
    def f(proj_chunk, angles, z0):
        return backproject_voxel(proj_chunk, geo, angles, weight=weight,
                                 z_start=z0, z_planes=planes)
    return f


@lru_cache(maxsize=None)
def _bp_slab_matched_fn(geo: ConeGeometry, planes: int, xdom: bool):
    """Exact adjoint restricted to a z slab: the vjp of the slab's partial
    forward projection.  Linear => the adjoint restricted to disjoint
    slabs stacks to the monolithic A^T exactly, so CGLS keeps its
    convergence guarantees on the out-of-core backend."""
    @jax.jit
    def f(proj_chunk, angles, z0):
        def fwd(slab):
            return forward_project_joseph(slab, geo, angles, xdom=xdom,
                                          z0=z0)
        zeros = jnp.zeros((planes,) + tuple(geo.n_voxel[1:]), jnp.float32)
        _, vjp = jax.vjp(fwd, zeros)
        return vjp(proj_chunk)[0]
    return f


def stream_backward(proj: np.ndarray, geo: ConeGeometry, angles: np.ndarray,
                    plan: BackwardPlan, weight: str = "fdk",
                    devices: Optional[Sequence] = None,
                    timeline: Optional[Timeline] = None) -> np.ndarray:
    """Out-of-core backprojection: every device consumes the entire
    projection set in ``angle_chunk`` double-buffered pieces while updating
    its resident image slab (paper Fig 5)."""
    if devices is None:
        devices = jax.local_devices()[: plan.n_devices]
    if len(devices) < plan.n_devices:
        raise ValueError(f"plan wants {plan.n_devices} devices, "
                         f"got {len(devices)}")
    angles = np.asarray(angles, np.float32)
    n_angles = len(angles)
    vol_out = np.zeros(geo.n_voxel, np.float32)
    chunks = [(c, min(c + plan.angle_chunk, n_angles))
              for c in range(0, n_angles, plan.angle_chunk)]

    xmask = dominant_axis_mask(angles)

    # Slab queue per device (paper: "a queue of image pieces is added").
    for k, (z0, z1) in enumerate(plan.slab_ranges):
        dev = devices[plan.device_of_slab[k]]
        bp = None if weight == "matched" else _bp_slab_fn(geo, z1 - z0,
                                                          weight)
        acc = jax.device_put(jnp.zeros((z1 - z0,) + tuple(geo.n_voxel[1:]),
                                       jnp.float32), dev)
        # prefetch chunk 0; then stream with one-chunk lookahead
        with _timed(timeline, "staging"):
            cur = (jax.device_put(jnp.asarray(proj[chunks[0][0]:chunks[0][1]]), dev),
                   jax.device_put(jnp.asarray(angles[chunks[0][0]:chunks[0][1]]), dev),
                   chunks[0])
        for ci, (c0, c1) in enumerate(chunks):
            nxt = None
            if ci + 1 < len(chunks):
                n0, n1 = chunks[ci + 1]
                with _timed(timeline, "staging"):
                    nxt = (jax.device_put(jnp.asarray(proj[n0:n1]), dev),
                           jax.device_put(jnp.asarray(angles[n0:n1]), dev),
                           chunks[ci + 1])
            with _timed(timeline, "compute"):
                if weight == "matched":
                    # exact adjoint: per-dominance vjp of the slab FP
                    m = xmask[c0:c1]
                    for key, idx in (("x", np.nonzero(m)[0]),
                                     ("y", np.nonzero(~m)[0])):
                        if idx.size == 0:
                            continue
                        fn = _bp_slab_matched_fn(geo, z1 - z0, key == "x")
                        acc = acc + fn(cur[0][jnp.asarray(idx)],
                                       cur[1][jnp.asarray(idx)], z0)
                else:
                    acc = acc + bp(cur[0], cur[1], z0)
                acc.block_until_ready()
            if nxt is not None:
                cur = nxt
        with _timed(timeline, "other_memory"):
            vol_out[z0:z1] = np.asarray(acc)
    return vol_out
