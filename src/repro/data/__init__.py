"""Data substrate: deterministic synthetic pipelines for LM training and
projection-data generation for CT benchmarks."""

from .tokens import TokenPipeline, TokenPipelineConfig
from .ct import make_ct_dataset

__all__ = ["TokenPipeline", "TokenPipelineConfig", "make_ct_dataset"]
