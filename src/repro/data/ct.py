"""CT projection-data generation (benchmarks / examples).

Builds (volume, projections) pairs from the analytic phantoms so every
reconstruction benchmark has a ground truth without shipping measured data
(the paper's coffee-bean / ichthyosaur scans are not redistributable)."""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core import phantoms
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.projector import forward_project

import jax.numpy as jnp


def make_ct_dataset(geo: ConeGeometry, n_angles: int,
                    phantom: str = "shepp", noise_rel: float = 0.0,
                    seed: int = 0):
    """Returns (vol, angles, proj).  ``noise_rel`` adds Gaussian noise of
    that relative magnitude (models low-dose scans, paper SS3.2)."""
    angles = circular_angles(n_angles)
    if phantom == "shepp":
        vol = phantoms.shepp_logan(geo)
    elif phantom == "sphere":
        vol = phantoms.sphere(geo)
    else:
        raise ValueError(f"unknown phantom {phantom!r}")
    proj = np.asarray(forward_project(jnp.asarray(vol), geo, angles))
    if noise_rel > 0:
        rng = np.random.default_rng(seed)
        proj = proj + (noise_rel * proj.std()
                       * rng.standard_normal(proj.shape).astype(np.float32))
    return vol, angles, proj
