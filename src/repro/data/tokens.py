"""Deterministic synthetic token pipeline.

Every batch is a pure function of ``(seed, step)`` -- the property that
makes checkpoint/restart *exact*: resuming at step k regenerates the same
batch k that the failed run would have consumed (tests/test_data.py).

The synthetic distribution is a Zipfian unigram mixed with a repeated-
n-gram process so that a small LM actually has something learnable
(examples/train_lm.py drives a ~100M model to decreasing loss on it).
Per-host sharding: each data-parallel host draws only its slice, keyed by
``(seed, step, shard)`` -- no cross-host I/O.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram_repeat_p: float = 0.3     # P(copy an earlier window)
    n_shards: int = 1
    shard: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


class TokenPipeline:
    """``batch(step) -> (tokens, labels)`` -- stateless, deterministic."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        # Zipf unigram table (static, seed-independent shape)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._cdf = np.cumsum(probs / probs.sum())

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.cfg.shard]))

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        b, s = cfg.local_batch, cfg.seq_len
        u = rng.random((b, s + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.minimum(toks, cfg.vocab - 1)
        # repeated n-grams: with prob p, copy a window from earlier in-row
        n_rep = max(1, int(cfg.ngram_repeat_p * b))
        for i in rng.choice(b, size=n_rep, replace=False):
            w = int(rng.integers(8, 64))
            if s + 1 > 2 * w:
                src = int(rng.integers(0, s + 1 - 2 * w))
                dst = int(rng.integers(src + w, s + 1 - w))
                toks[i, dst:dst + w] = toks[i, src:src + w]
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def feature_batch(cfg: TokenPipelineConfig, step: int, d_model: int,
                  dtype=np.float32) -> Tuple[np.ndarray, np.ndarray]:
    """Stub modality frontend (hubert audio frames / vision patches):
    deterministic Gaussian frame embeddings + integer targets."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.shard, 7]))
    b, s = cfg.local_batch, cfg.seq_len
    feats = rng.standard_normal((b, s, d_model)).astype(dtype)
    labels = rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)
    return feats, labels
