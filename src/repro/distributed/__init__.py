"""Distributed runtime helpers: sharding-rule construction, straggler
watchdog, heartbeat-based failure detection."""

from .sharding import make_lm_rules, param_shardings, batch_sharding
from .watchdog import StepWatchdog, Heartbeat

__all__ = ["make_lm_rules", "param_shardings", "batch_sharding",
           "StepWatchdog", "Heartbeat"]
