"""Sharding rules for the LM zoo on the production mesh.

Logical-axis -> mesh-axis mapping (DESIGN.md SS5):

    batch        -> ("pod", "data")   data parallelism (pod-major)
    embed        -> None              activations replicated on d_model
    heads/kv     -> "model"           tensor parallelism over heads
    heads_x_dim  -> "model"           flat (H*hd) projection outputs
    mlp          -> "model"           FFN hidden
    vocab        -> "model"           vocab-parallel embedding / logits
    expert       -> "model"           expert parallelism (MoE)
    inner        -> "model"           mamba/xlstm inner channels
    heads_inner  -> "model"           mamba SSD head axis
    seq_q        -> "model"           xlstm query-sequence parallelism
    layers       -> None              stacked-scan leading axis

Divisibility is checked per-tensor by ``ShardingRules`` (non-divisible
axes fall back to replication, e.g. minicpm3's 73448 vocab rows on a
16-way model axis).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ShardingRules


def make_lm_rules(mesh: Optional[Mesh]) -> ShardingRules:
    if mesh is None:
        return ShardingRules()
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    rules = {
        "batch": batch,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "heads_x_dim": "model",
        "kv_x_dim": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "inner": "model",
        "heads_inner": "model",
        "seq_q": "model",
        "seq_kv": "model",
        "layers": None,
    }
    return ShardingRules(mesh=mesh, rules=rules)


def param_shardings(model, rules: ShardingRules, params_shape):
    """NamedSharding pytree for the param tree (divisibility-checked
    against the abstract shapes)."""
    axes = model.param_axes(params_shape)

    def one(ax, shape_struct):
        return rules.named_sharding(tuple(ax), shape_struct.shape)

    return jax.tree.map(one, axes, params_shape,
                        is_leaf=lambda v: isinstance(v, tuple))


def batch_sharding(rules: ShardingRules, spec_tree):
    """NamedSharding pytree for input batches: leading axis over
    ("pod","data"), rest replicated.  Scalars replicated."""

    def one(s):
        if len(s.shape) == 0:
            return NamedSharding(rules.mesh, P())
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        return rules.named_sharding(axes, s.shape)

    return jax.tree.map(one, spec_tree)
