"""Straggler / failure detection.

``StepWatchdog`` tracks per-step wall time with a robust (median + MAD)
model and flags stragglers -- on a real pod this feeds the controller's
decision to checkpoint-and-reschedule a slow host.  ``Heartbeat`` is the
cross-host liveness primitive: each host touches its heartbeat file every
step; the controller treats a host whose beat is older than ``timeout`` as
failed and triggers an elastic restart from the last committed checkpoint
(tests/test_fault_tolerance.py simulates both paths)."""

from __future__ import annotations

import os
import time
from collections import deque
from typing import Deque, List, Optional


class StepWatchdog:
    def __init__(self, window: int = 50, threshold: float = 3.0,
                 min_steps: int = 10):
        self.window: Deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.min_steps = min_steps
        self.stragglers: List[int] = []
        self._step = 0
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self) -> bool:
        """Record the step; True if it was a straggler."""
        dt = time.monotonic() - self._t0
        is_straggler = False
        if len(self.window) >= self.min_steps:
            med = sorted(self.window)[len(self.window) // 2]
            mad = sorted(abs(x - med) for x in self.window)[
                len(self.window) // 2]
            if dt > med + self.threshold * max(mad, 0.05 * med, 1e-4):
                is_straggler = True
                self.stragglers.append(self._step)
        # stragglers poison the baseline -- only admit normal steps
        if not is_straggler:
            self.window.append(dt)
        self._step += 1
        return is_straggler

    def observe(self, dt: float) -> bool:
        """Test hook: feed a duration directly."""
        self._t0 = time.monotonic() - dt
        return self.end_step()


class Heartbeat:
    """File-based liveness: ``beat()`` each step; ``dead_hosts()`` on the
    controller returns hosts whose last beat exceeds the timeout."""

    def __init__(self, root: str, host_id: int, timeout: float = 60.0):
        self.root = root
        self.host_id = host_id
        self.timeout = timeout
        os.makedirs(root, exist_ok=True)

    def _path(self, host: int) -> str:
        return os.path.join(self.root, f"host_{host:04d}.beat")

    def beat(self, step: int):
        with open(self._path(self.host_id), "w") as f:
            f.write(f"{step} {time.time()}")

    def dead_hosts(self, n_hosts: int, now: Optional[float] = None):
        now = time.time() if now is None else now
        dead = []
        for h in range(n_hosts):
            try:
                with open(self._path(h)) as f:
                    _, t = f.read().split()
                if now - float(t) > self.timeout:
                    dead.append(h)
            except FileNotFoundError:
                dead.append(h)
        return dead
