"""Measured block-size autotuner for the Pallas kernels.

The dispatch layer (:mod:`repro.core.backend`) historically chose kernel
block sizes with a static largest-divisor-<=-preferred heuristic.  That is
safe but blind: the best marching-slab width for ``fp_ray`` or z-block for
``bp_voxel`` depends on the geometry's shape and on the platform (interpret
mode on CPU amortises per-grid-step overhead very differently from Mosaic
on a real TPU).  This module times a small candidate grid per

    (kind, platform, geometry shape class)

on first use, memoises the winner into a process-wide table, and optionally
persists it as JSON so later processes skip the measurement:

* ``REPRO_AUTOTUNE=1`` (or :func:`enable`) turns tuning on; when off,
  :func:`get_blocks` returns the heuristic unchanged — zero behaviour
  change for existing callers.
* ``REPRO_AUTOTUNE_CACHE=/path/table.json`` loads the table on first use
  and rewrites it after every new measurement (``recon --autotune`` and
  ``tools/autotune.py`` pre-bake it).
* Candidates are floored at the heuristic block: the tuner only ever
  *grows* blocks (fewer grid steps, bigger VMEM windows), so a tuned
  config is always >= the heuristic one and the dispatch-table key —
  which includes the chosen blocks — stays distinct per config.

The heuristic itself carries the pad-to-divisor escape hatch: when the
largest divisor degrades below half the preferred block (prime axes used
to force block=1), it returns the preferred block and lets the kernels'
pad-and-mask path absorb the non-divisibility.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

_SCHEMA = 1
_KINDS = ("fp", "bp", "bp_matched")

_LOCK = threading.RLock()
_TABLE: Dict[Tuple, Dict[str, int]] = {}
_LOADED: set = set()          # cache paths already merged into _TABLE
_ENABLED: Optional[bool] = None   # None -> consult REPRO_AUTOTUNE
_FINGERPRINT = 0              # bumped on any table/state mutation


# --------------------------------------------------------------------------
# state

def enabled() -> bool:
    """True when measured tuning is active (env or :func:`enable`)."""
    if _ENABLED is not None:
        return _ENABLED
    return os.environ.get("REPRO_AUTOTUNE", "") not in ("", "0", "false")


def enable(on: Optional[bool]) -> None:
    """Force tuning on/off for this process (``None`` -> env-driven)."""
    global _ENABLED, _FINGERPRINT
    with _LOCK:
        _ENABLED = on
        _FINGERPRINT += 1


def cache_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE", "")


def fingerprint() -> int:
    """Monotone counter over table mutations.

    Folded into cache keys that must distinguish "same geometry, different
    tuned blocks" (e.g. the serve layer's operator cache).
    """
    return _FINGERPRINT


def clear() -> None:
    global _FINGERPRINT
    with _LOCK:
        _TABLE.clear()
        _LOADED.clear()
        _FINGERPRINT += 1


def table() -> Dict[str, Dict[str, int]]:
    """Copy of the current table, JSON-keyed (for inspection/tests)."""
    with _LOCK:
        return {_key_str(k): dict(v) for k, v in _TABLE.items()}


# --------------------------------------------------------------------------
# keys + persistence

def _platform() -> str:
    import jax
    return jax.default_backend()


def shape_class(kind: str, geo, planes: Optional[int]) -> Tuple:
    """The memo key: geometry *shape*, not its physical scale.

    Block sizes are about grid-step counts and VMEM windows, so only the
    integer shapes matter; two geometries with the same voxel/detector
    counts share a tuned entry.
    """
    return (kind, _platform(), tuple(geo.n_voxel), tuple(geo.n_detector),
            int(planes) if planes is not None else None)


def _key_str(key: Tuple) -> str:
    kind, plat, nvox, ndet, planes = key
    return "|".join([kind, plat,
                     ",".join(map(str, nvox)), ",".join(map(str, ndet)),
                     str(planes)])


def _key_parse(s: str) -> Optional[Tuple]:
    parts = s.split("|")
    if len(parts) != 5:
        return None
    kind, plat, nvox, ndet, planes = parts
    try:
        return (kind, plat, tuple(int(x) for x in nvox.split(",")),
                tuple(int(x) for x in ndet.split(",")),
                None if planes == "None" else int(planes))
    except ValueError:
        return None


def save(path: str) -> None:
    with _LOCK:
        doc = {"version": _SCHEMA, "entries": table()}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    os.replace(tmp, path)


def load(path: str) -> int:
    """Merge a persisted table; returns the number of entries taken."""
    global _FINGERPRINT
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return 0
    if not isinstance(doc, dict) or doc.get("version") != _SCHEMA:
        return 0
    n = 0
    with _LOCK:
        for ks, cfg in (doc.get("entries") or {}).items():
            key = _key_parse(ks)
            if key is None or not isinstance(cfg, dict):
                continue
            _TABLE[key] = {k: int(v) for k, v in cfg.items()}
            n += 1
        if n:
            _FINGERPRINT += 1
    return n


def _maybe_load() -> None:
    p = cache_path()
    if p and p not in _LOADED:
        _LOADED.add(p)
        if os.path.exists(p):
            load(p)


# --------------------------------------------------------------------------
# heuristic

def _divisor_at_most(n: int, cap: int) -> int:
    cap = max(1, min(cap, n))
    for c in range(cap, 0, -1):
        if n % c == 0:
            return c
    return 1


def pick_block(n: int, preferred: int) -> int:
    """Divisor-or-pad heuristic block for an axis of extent ``n``.

    Largest divisor <= ``preferred`` when that divisor is still at least
    half of ``preferred``; otherwise (prime/awkward axes) fall through to
    ``min(preferred, n)`` and rely on the kernels' pad-and-mask path.
    """
    d = _divisor_at_most(n, preferred)
    if d >= max(1, preferred // 2):
        return d
    return min(preferred, n)


def heuristic_blocks(kind: str, geo, *, planes: Optional[int] = None,
                     preferred: int = 16, angle_pref: int = 8
                     ) -> Dict[str, int]:
    nz, ny, nx = geo.n_voxel
    if kind in ("fp", "bp_matched"):
        return {"slab_planes": pick_block(nx, preferred)}
    if kind == "bp":
        p = nz if planes is None else int(planes)
        return {"z_block": pick_block(p, preferred),
                "angle_chunk": angle_pref}
    raise ValueError(f"unknown autotune kind: {kind!r}")


def _candidates(kind: str, geo, planes: Optional[int],
                heur: Dict[str, int]) -> list:
    """Small candidate grid, floored at the heuristic config."""
    nz, ny, nx = geo.n_voxel
    if kind in ("fp", "bp_matched"):
        h = heur["slab_planes"]
        sizes = sorted({min(nx, s) for s in (h, 2 * h, 4 * h, nx)
                        if min(nx, s) >= h})
        return [{"slab_planes": s} for s in sizes]
    p = nz if planes is None else int(planes)
    hz, hc = heur["z_block"], heur["angle_chunk"]
    zs = sorted({min(p, s) for s in (hz, 2 * hz, p) if min(p, s) >= hz})
    cas = sorted({hc, 2 * hc})
    return [{"z_block": z, "angle_chunk": c} for z in zs for c in cas][:8]


# --------------------------------------------------------------------------
# measurement

def _measure(kind: str, geo, planes: Optional[int], cfg: Dict[str, int],
             interpret: bool, repeats: int) -> float:
    """Median wall seconds for one kernel call under ``cfg``."""
    import jax.numpy as jnp
    from .bp_matched import bp_matched_pallas
    from .bp_voxel import bp_voxel_pallas
    from .fp_ray import fp_ray_pallas

    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    p = nz if planes is None else int(planes)
    n_ang = 16
    # x-dominant angles only: the rotation trick means the kernels only
    # ever see x-dominant work, so that's the representative workload
    angles = jnp.asarray(np.linspace(-0.3, 0.3, n_ang), jnp.float32)
    rng = np.random.default_rng(0)

    if kind == "fp":
        vol = jnp.asarray(rng.standard_normal((p, ny, nx)), jnp.float32)

        def call():
            return fp_ray_pallas(vol, geo, angles,
                                 slab_planes=cfg["slab_planes"],
                                 interpret=interpret, z0=0)
    elif kind == "bp_matched":
        proj = jnp.asarray(rng.standard_normal((n_ang, nv, nu)), jnp.float32)

        def call():
            return bp_matched_pallas(proj, geo, angles,
                                     slab_planes=cfg["slab_planes"],
                                     interpret=interpret, z0=0, z_planes=p)
    else:
        proj = jnp.asarray(rng.standard_normal((n_ang, nv, nu)), jnp.float32)

        def call():
            return bp_voxel_pallas(proj, geo, angles,
                                   z_block=cfg["z_block"],
                                   angle_chunk=cfg["angle_chunk"],
                                   weight="fdk", interpret=interpret,
                                   z_start=0, z_planes=p)

    call().block_until_ready()          # compile + warm
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        call().block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def tune(kind: str, geo, *, planes: Optional[int] = None,
         preferred: int = 16, angle_pref: int = 8, interpret: bool = True,
         repeats: int = 2) -> Dict[str, int]:
    """Measure the candidate grid and return (and memoise) the winner."""
    global _FINGERPRINT
    heur = heuristic_blocks(kind, geo, planes=planes, preferred=preferred,
                            angle_pref=angle_pref)
    best_cfg, best_t = dict(heur), None
    for cfg in _candidates(kind, geo, planes, heur):
        t = _measure(kind, geo, planes, cfg, interpret, repeats)
        if best_t is None or t < best_t:
            best_cfg, best_t = dict(cfg), t
    key = shape_class(kind, geo, planes)
    with _LOCK:
        _TABLE[key] = best_cfg
        _FINGERPRINT += 1
    p = cache_path()
    if p:
        try:
            save(p)
        except OSError:
            pass
    return dict(best_cfg)


def get_blocks(kind: str, geo, *, planes: Optional[int] = None,
               preferred: int = 16, angle_pref: int = 8,
               interpret: bool = True, repeats: int = 2) -> Dict[str, int]:
    """Block config for a kernel ``kind`` on ``geo``.

    Heuristic when tuning is disabled; otherwise the memoised measured
    winner, measuring on first miss.  Thread-safe; measurement happens
    outside the table lock (concurrent first-misses may both measure —
    idempotent, last writer wins).
    """
    heur = heuristic_blocks(kind, geo, planes=planes, preferred=preferred,
                            angle_pref=angle_pref)
    if not enabled():
        return heur
    with _LOCK:
        _maybe_load()
        hit = _TABLE.get(shape_class(kind, geo, planes))
    if hit is not None:
        # floor at the heuristic so a stale/foreign cache can never pick
        # a smaller block than the safe default
        return {k: max(int(v), heur.get(k, 1)) for k, v in hit.items()}
    return tune(kind, geo, planes=planes, preferred=preferred,
                angle_pref=angle_pref, interpret=interpret, repeats=repeats)


def warm(geo, *, planes: Optional[int] = None, kinds=_KINDS,
         preferred: int = 16, angle_pref: int = 8,
         interpret: bool = True, repeats: int = 2
         ) -> Dict[str, Dict[str, int]]:
    """Pre-bake tuned entries for every ``kind`` on ``geo``."""
    return {k: get_blocks(k, geo, planes=planes, preferred=preferred,
                          angle_pref=angle_pref, interpret=interpret,
                          repeats=repeats)
            for k in kinds}
