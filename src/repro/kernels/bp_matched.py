"""Pallas TPU kernel: exact transpose of the Joseph slab forward projector.

``fp_ray.py`` forward-projects by marching x planes and, per plane, doing a
two-tap y gather followed by a two-tap z gather.  A linear gather's transpose
is a scatter-add with the *same* indices and weights, so this kernel replays
the identical index/weight arithmetic as ``_fp_kernel`` — bit-for-bit the
same ``s_par`` / ``fj`` / ``fk`` / boundary masks / ``seg`` expressions — and
turns the two gathers into two scatter-adds:

* z gather ``take_along_axis(colz, k, axis=0)``  ->  ``.at[k, u].add(...)``
* y gather ``take(plane, j, axis=1)``            ->  ``.at[:, j].add(...)``

Because every weight is recomputed from the same fp32 expressions, the pair
satisfies ⟨Ax, y⟩ = ⟨x, Aᵀy⟩ to fp32 summation tolerance: exactly what CGLS
and FISTA need for their convergence guarantees (TIGRE paper SS2.2 — the
matched "Aᵀ" pair, as opposed to the filtered/voxel-driven BP).

Grid is ``(slab, angle)`` with the angle dimension innermost: each marching
slab of the output volume accumulates scattered contributions from every
angle while the Pallas pipeline double-buffers the next projection's
HBM->VMEM DMA — the mirror image of the FP kernel's (angle, slab) order.

Like ``fp_ray_pallas``, the wrapper pads the marching axis to a multiple of
``slab_planes`` (padded planes are computed then dropped: the exact
transpose of FP's pad-with-zero-planes), so any block size ``<= Nx`` is
legal — which is what lets the autotuner explore non-divisor candidates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.geometry import ConeGeometry

from .fp_ray import angle_constants


def _bp_matched_kernel(consts_ref, xc_ref, z0_ref, proj_ref, out_ref, *,
                       geo: ConeGeometry, px: int, nz_slab: int):
    """One (slab, angle) grid step: scatter one projection into Px planes.

    The index math below is a line-for-line copy of ``_fp_kernel``'s; only
    the data movement is transposed (gather -> scatter-add).  Keep the two
    in sync: any divergence breaks the adjoint identity.
    """
    a_idx = pl.program_id(1)
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    dz, dy, dx = geo.d_voxel
    dv, du = geo.d_detector
    offz, offy, offx = geo.off_origin
    offv, offu = geo.off_detector
    z0 = z0_ref[0, 0]

    c = consts_ref[0]
    sx, sy, sz = c[0], c[1], c[2]
    dcx, dcy = c[3], c[4]
    eux, euy = c[5], c[6]

    u = (jnp.arange(nu, dtype=jnp.float32) - (nu - 1) / 2.0) * du + offu
    v = (jnp.arange(nv, dtype=jnp.float32) - (nv - 1) / 2.0) * dv + offv
    d_x = dcx + u * eux - sx                       # (Nu,)
    d_y = dcy + u * euy - sy                       # (Nu,)
    d_z = v - sz                                   # (Nv,)
    norm = jnp.sqrt(d_x[None, :] ** 2 + d_y[None, :] ** 2
                    + d_z[:, None] ** 2)
    seg = norm / jnp.maximum(jnp.abs(d_x)[None, :], 1e-9) * dx
    inv_dx = 1.0 / jnp.where(jnp.abs(d_x) < 1e-9, 1e-9, d_x)

    # cotangent rays, pre-weighted by the FP's final ``acc * seg``
    g_seg = proj_ref[0] * seg                      # (Nv, Nu)
    uu = jnp.broadcast_to(jnp.arange(nu, dtype=jnp.int32)[None, :],
                          (nv, nu))

    def plane_body(p, out_acc):
        x = xc_ref[0, p]
        s_par = (x - sx) * inv_dx                  # (Nu,)
        yw = sy + s_par * d_y                      # (Nu,)
        fj = (yw - offy) / dy + (ny - 1) / 2.0     # (Nu,)
        fk = ((sz + s_par[None, :] * d_z[:, None] - offz) / dz
              + (nz - 1) / 2.0) - z0               # (Nv, Nu), slab-local

        j0 = jnp.floor(fj)
        wj = fj - j0
        j0i = j0.astype(jnp.int32)
        j0c = jnp.clip(j0i, 0, ny - 1)
        j1c = jnp.clip(j0i + 1, 0, ny - 1)
        wy0 = jnp.where((j0i >= 0) & (j0i < ny), 1.0 - wj, 0.0)     # (Nu,)
        wy1 = jnp.where((j0i + 1 >= 0) & (j0i + 1 < ny), wj, 0.0)

        k0 = jnp.floor(fk)
        wk = fk - k0
        k0i = k0.astype(jnp.int32)
        k0c = jnp.clip(k0i, 0, nz_slab - 1)
        k1c = jnp.clip(k0i + 1, 0, nz_slab - 1)
        wz0 = jnp.where((k0i >= 0) & (k0i < nz_slab), 1.0 - wk, 0.0)
        wz1 = jnp.where((k0i + 1 >= 0) & (k0i + 1 < nz_slab), wk, 0.0)

        w = ((s_par > 0.0) & (s_par <= 1.0)).astype(jnp.float32)[None, :]
        g = g_seg * w                              # (Nv, Nu)

        # transpose of the z gather: scatter the two taps into z columns
        colz_bar = jnp.zeros((nz_slab, nu), jnp.float32)
        colz_bar = colz_bar.at[k0c, uu].add(g * wz0)
        colz_bar = colz_bar.at[k1c, uu].add(g * wz1)       # (Nz, Nu)

        # transpose of the y gather: scatter u columns into y columns
        plane_bar = jnp.zeros((nz_slab, ny), jnp.float32)
        plane_bar = plane_bar.at[:, j0c].add(colz_bar * wy0[None, :])
        plane_bar = plane_bar.at[:, j1c].add(colz_bar * wy1[None, :])

        return out_acc.at[p].set(plane_bar)

    acc = jax.lax.fori_loop(
        0, px, plane_body, jnp.zeros((px, nz_slab, ny), jnp.float32))

    @pl.when(a_idx == 0)
    def _init():
        out_ref[0] = jnp.zeros_like(out_ref[0])

    out_ref[0] += acc


def bp_matched_pallas(proj: jnp.ndarray, geo: ConeGeometry, angles,
                      slab_planes: int = 16, interpret: bool = True,
                      z0=0, z_planes: int | None = None) -> jnp.ndarray:
    """Matched (exact-adjoint) backprojection of x-dominant ``angles``.

    Returns the slab ``(z_planes, Ny, Nx)`` such that for any volume slab
    ``x`` and projections ``y``::

        <fp_ray_pallas(x, geo, angles, z0=z0), y>
            == <x, bp_matched_pallas(y, geo, angles, z0=z0,
                                     z_planes=x.shape[0])>

    to fp32 tolerance.  ``z_planes`` defaults to the full ``Nz``; pass the
    slab height (with its ``z0``) to adjoint a streamed partial projection.
    ``angles`` and ``z0`` may be traced, mirroring ``fp_ray_pallas``.
    """
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    nz_slab = nz if z_planes is None else int(z_planes)
    slab_planes = min(int(slab_planes), nx)
    n_slabs = -(-nx // slab_planes)
    nx_pad = n_slabs * slab_planes
    n_angles = angles.shape[0] if hasattr(angles, "shape") else len(angles)

    consts = angle_constants(geo, angles)
    # marching-plane centres, continued past Nx for the padded tail
    xc = np.asarray(
        (np.arange(nx_pad) - (nx - 1) / 2.0) * geo.d_voxel[2]
        + geo.off_origin[2], np.float32).reshape(n_slabs, slab_planes)
    z0_arr = jnp.asarray(z0, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_bp_matched_kernel, geo=geo, px=slab_planes,
                               nz_slab=nz_slab)
    out = pl.pallas_call(
        kernel,
        grid=(n_slabs, n_angles),
        in_specs=[
            pl.BlockSpec((1, 8), lambda s_, a_: (a_, 0)),
            pl.BlockSpec((1, slab_planes), lambda s_, a_: (s_, 0)),
            pl.BlockSpec((1, 1), lambda s_, a_: (0, 0)),
            pl.BlockSpec((1, nv, nu), lambda s_, a_: (a_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, slab_planes, nz_slab, ny),
                               lambda s_, a_: (s_, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (n_slabs, slab_planes, nz_slab, ny), jnp.float32),
        interpret=interpret,
    )(consts, jnp.asarray(xc), z0_arr, jnp.asarray(proj, jnp.float32))

    # (S, Px, Nz, Ny) -> (Nx_pad, Nz, Ny) -> drop pad -> (Nz, Ny, Nx):
    # the exact inverse of fp_ray_pallas's input slab layout.
    vol = out.reshape(nx_pad, nz_slab, ny)[:nx]
    return jnp.transpose(vol, (1, 2, 0))
