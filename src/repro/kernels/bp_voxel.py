"""Pallas TPU kernel: voxel-driven backprojector with projection streaming.

TPU adaptation of TIGRE's backprojection kernel (paper SS2.2, Fig 4/5):

* The Pallas grid iterates ``(z_block, angle_chunk)`` with the angle chunk
  innermost; the volume block stays resident in VMEM and is *accumulated*
  across chunks while the next chunk's projections are DMA'd in by the
  pipeline -- exactly the paper's Fig 5 timeline (projections copied to the
  device while the voxel-update kernel runs), realised by BlockSpec
  pipelining instead of CUDA streams.
* Per-voxel detector coordinates decompose as ``fu(x, y)`` and
  ``fv = z * m(x, y) + c(x, y)``: the in-plane fields are computed once per
  angle and reused for all ``Bz`` planes of the block.
* The (Nv, Nu) bilinear fetch is a flat 4-tap ``jnp.take`` gather
  (interpret-validated; Mosaic dynamic-gather on hardware).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.geometry import ConeGeometry
from .fp_ray import angle_constants


def _bp_kernel(consts_ref, zs_ref, proj_ref, out_ref, *, geo: ConeGeometry,
               bz: int, ca: int, weight: str):
    """One (z_block, angle_chunk) grid step.

    ``zs_ref[0, 0]`` is the (traced) global starting plane of the output
    slab: the kernel updates planes ``[z_start, z_start + z_planes)`` of
    ``geo``'s volume — the full volume when ``z_planes == Nz``, one
    streamed axial slab otherwise (the angle axis is additive, so chunked
    accumulation reproduces the monolithic result exactly).
    """
    c_idx = pl.program_id(1)
    zb_idx = pl.program_id(0)
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    dz, dy, dx = geo.d_voxel
    dv, du = geo.d_detector
    offz, offy, offx = geo.off_origin
    offv, offu = geo.off_detector

    xs = (jnp.arange(nx, dtype=jnp.float32) - (nx - 1) / 2.0) * dx + offx
    ys = (jnp.arange(ny, dtype=jnp.float32) - (ny - 1) / 2.0) * dy + offy
    z0 = zb_idx * bz
    zs = ((jnp.arange(bz, dtype=jnp.float32) + z0.astype(jnp.float32)
           + zs_ref[0, 0]) - (nz - 1) / 2.0) * dz + offz

    X = xs[None, :]
    Y = ys[:, None]

    def angle_body(i, acc):
        cst = consts_ref[0, i]
        sx, sy = cst[0], cst[1]
        # cos/sin recovered from e_u = (-sin, cos)
        sth, cth = -cst[5], cst[6]
        p = X * cth + Y * sth                      # (Ny, Nx)
        q = -X * sth + Y * cth
        depth = geo.DSO - p
        mag = geo.DSD / depth
        fu = (q * mag - offu) / du + (nu - 1) / 2.0      # (Ny, Nx)
        fv_scale = mag / dv                               # (Ny, Nx)
        if weight == "fdk":
            w2d = (geo.DSO / depth) ** 2
        elif weight == "pmatched":
            w2d = (geo.DSD / depth) ** 2 * (geo.DSO / geo.DSD)
        else:
            w2d = jnp.ones_like(depth)

        p2d = proj_ref[0, i]                       # (Nv, Nu)
        flat = p2d.reshape(-1)

        i0 = jnp.floor(fu)
        wu = fu - i0
        i0i = i0.astype(jnp.int32)

        def z_body(k, acc):
            fv = zs[k] * fv_scale - (offv / dv) + (nv - 1) / 2.0  # (Ny, Nx)
            j0 = jnp.floor(fv)
            wv = fv - j0
            j0i = j0.astype(jnp.int32)

            def tap(jj, ii, w):
                ok = (jj >= 0) & (jj < nv) & (ii >= 0) & (ii < nu)
                idx = (jnp.clip(jj, 0, nv - 1) * nu
                       + jnp.clip(ii, 0, nu - 1))
                return jnp.where(ok, jnp.take(flat, idx) * w, 0.0)

            val = (tap(j0i, i0i, (1 - wv) * (1 - wu))
                   + tap(j0i, i0i + 1, (1 - wv) * wu)
                   + tap(j0i + 1, i0i, wv * (1 - wu))
                   + tap(j0i + 1, i0i + 1, wv * wu))
            return acc.at[k].add(val * w2d)

        return jax.lax.fori_loop(0, bz, z_body, acc)

    acc = jax.lax.fori_loop(0, ca, angle_body,
                            jnp.zeros((bz, ny, nx), jnp.float32))

    @pl.when(c_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += acc


def bp_voxel_pallas(proj: jnp.ndarray, geo: ConeGeometry, angles,
                    z_block: int = 16, angle_chunk: int = 8,
                    weight: str = "fdk", interpret: bool = True,
                    z_start=0, z_planes: int = None) -> jnp.ndarray:
    """Backproject with the Pallas kernel.

    VMEM working set: ``Bz * Ny * Nx`` volume block (resident, accumulated)
    + double-buffered ``angle_chunk`` projections -- the paper's Alg 2
    budget ("two buffers of size N_angles ... plus the image piece").

    ``z_start`` (traced OK) + ``z_planes`` (static) select an axial slab
    of ``geo``'s volume (the paper's per-device image pieces) — the
    out-of-core streaming executor accumulates angle chunks into such
    slabs.  ``angles`` may be traced (see :mod:`repro.core.backend`).
    """
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    planes = nz if z_planes is None else z_planes
    n_angles = angles.shape[0] if hasattr(angles, "shape") else len(angles)
    # Pad-to-divisor escape hatch: prime-sized axes used to force the
    # dispatch heuristic down to block=1 (one grid step per plane/angle).
    # Instead, pad the z grid (extra planes computed then dropped) and the
    # angle axis (projections zero-masked — BP is linear in the data, so
    # zero rows contribute nothing; angles duplicate the last entry to
    # keep the geometry table finite).  Exact for any block size.
    z_block = min(int(z_block), planes)
    angle_chunk = min(int(angle_chunk), n_angles)
    n_zb = -(-planes // z_block)
    n_ch = -(-n_angles // angle_chunk)
    planes_pad = n_zb * z_block
    n_ang_pad = n_ch * angle_chunk

    angles = jnp.asarray(angles, jnp.float32)
    proj = jnp.asarray(proj, jnp.float32)
    if n_ang_pad != n_angles:
        tail = n_ang_pad - n_angles
        angles = jnp.concatenate(
            [angles, jnp.broadcast_to(angles[-1:], (tail,))], 0)
        proj = jnp.concatenate(
            [proj, jnp.zeros((tail, nv, nu), proj.dtype)], 0)

    consts = angle_constants(geo, angles).reshape(n_ch, angle_chunk, 8)
    proj_ch = proj.reshape(n_ch, angle_chunk, nv, nu)
    zs_arr = jnp.asarray(z_start, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_bp_kernel, geo=geo, bz=z_block,
                               ca=angle_chunk, weight=weight)
    return pl.pallas_call(
        kernel,
        grid=(n_zb, n_ch),
        in_specs=[
            pl.BlockSpec((1, angle_chunk, 8), lambda z_, c_: (c_, 0, 0)),
            pl.BlockSpec((1, 1), lambda z_, c_: (0, 0)),
            pl.BlockSpec((1, angle_chunk, nv, nu), lambda z_, c_: (c_, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((z_block, ny, nx), lambda z_, c_: (z_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((planes_pad, ny, nx), jnp.float32),
        interpret=interpret,
    )(consts, zs_arr, proj_ch)[:planes]
