"""Pallas TPU kernel: FlashAttention-2 style fused attention.

Hot-spot kernel for the assigned LM architectures' ``prefill_32k`` cells
(32k-token prefill is O(S^2) and dominates those rooflines).  Features
needed by the arch pool:

* causal masking (decoder LMs) or none (hubert encoder),
* grouped-query attention via a KV-head index map (no KV replication in
  HBM: the ``h // group`` BlockSpec index does the broadcast),
* attention logit soft-capping (gemma2: ``cap * tanh(s / cap)``),
* sliding-window masking (gemma2 local layers, window 4096).

Layout: q (B, Hq, S, D), k/v (B, Hkv, S, D).  Grid (B, Hq, Sq/bq, Skv/bkv)
with the KV dimension innermost; online-softmax running max / sum / acc
live in VMEM scratch across KV steps (FlashAttention-2 schedule: rescale
accumulator, single final normalisation).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], bq: int, bkv: int):
    kv_idx = pl.program_id(3)
    n_kv = pl.num_programs(3)
    q_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0] * scale                       # (bq, D)
    k = k_ref[0, 0]                               # (bkv, D)
    v = v_ref[0, 0]                               # (bkv, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bkv)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = kv_idx * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                           # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                        # (bq, bkv)
    corr = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * corr
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(kv_idx == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """Fused attention.  q: (B, Hq, S, D); k/v: (B, Hkv, S, D), Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    if sq % bq or skv % bkv:
        raise ValueError(f"seq lens ({sq},{skv}) not divisible by blocks "
                         f"({bq},{bkv})")
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bkv=bkv)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, sq // bq, skv // bkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h_, q_, k_, g=group: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h_, q_, k_, g=group: (b_, h_ // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
