"""Pallas TPU kernel: Joseph forward projector with marching-axis streaming.

TPU adaptation of TIGRE's texture-cached ray-driven projection kernel
(paper SS2.1, Fig 2).  Design notes (see DESIGN.md SS4):

* The volume is laid out as marching-axis slabs ``(S, Px, Nz, Ny)`` (a pure
  transpose+reshape of the (Nz, Ny, Nx) volume).  The Pallas grid iterates
  ``(angle, slab)`` with the slab dimension innermost, *accumulating* into
  the same output block -- the Pallas pipeline's automatic double-buffering
  of the next slab's HBM->VMEM DMA while the current slab computes is the
  in-kernel image of the paper's two-projection-buffer overlap scheme.
* CUDA texture trilinear interpolation has no TPU analogue.  Joseph's
  method needs one bilinear (z, y) interpolation per marching plane; we
  decompose it into a per-``u`` column gather along y (lane-wise dynamic
  gather) followed by a 2-tap ``take_along_axis`` in z.  Both are regular,
  vectorisable accesses; validated in interpret mode on CPU, lowerable via
  Mosaic dynamic-gather on real TPUs.
* Per-angle geometry scalars are precomputed on the host into a small
  ``(A, 8)`` table (the analogue of TIGRE's constant memory).

The kernel only handles x-dominant angles; callers rotate the scene by
-90 deg for y-dominant ones (repro.core.projector handles the split).
"""

from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.geometry import ConeGeometry


def angle_constants(geo: ConeGeometry, angles) -> jnp.ndarray:
    """(A, 8) per-angle table: src(3), det_c(2), e_u(2), pad.

    Built with jnp so ``angles`` may be a *traced* array: the wrappers in
    :mod:`repro.core.backend` / :mod:`repro.kernels.ops` jit once per
    static key and reuse the compiled kernel across angle values instead
    of retracing per call.
    """
    a = jnp.asarray(angles, jnp.float32)
    c, s = jnp.cos(a), jnp.sin(a)
    z = jnp.zeros_like(a)
    return jnp.stack([
        geo.DSO * c,                    # Sx
        geo.DSO * s,                    # Sy
        z,                              # Sz
        -(geo.DSD - geo.DSO) * c,       # det_c x
        -(geo.DSD - geo.DSO) * s,       # det_c y
        -s,                             # e_u x
        c,                              # e_u y
        z,
    ], axis=-1)


def _fp_kernel(consts_ref, xc_ref, z0_ref, vol_ref, out_ref, *,
               geo: ConeGeometry, px: int, nz_slab: int):
    """One (angle, slab) grid step: accumulate Px marching planes.

    ``vol_ref`` holds ``nz_slab`` z planes starting at the (traced) global
    plane ``z0_ref[0, 0]`` — the full volume when ``nz_slab == Nz``, a
    streamed axial slab otherwise.  Interpolation taps outside the slab
    evaluate to zero, so partial projections over disjoint slabs sum to
    the monolithic integral exactly (the paper's splitting claim).
    """
    s_idx = pl.program_id(1)
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    dz, dy, dx = geo.d_voxel
    dv, du = geo.d_detector
    offz, offy, offx = geo.off_origin
    offv, offu = geo.off_detector
    z0 = z0_ref[0, 0]

    c = consts_ref[0]
    sx, sy, sz = c[0], c[1], c[2]
    dcx, dcy = c[3], c[4]
    eux, euy = c[5], c[6]

    u = (jnp.arange(nu, dtype=jnp.float32) - (nu - 1) / 2.0) * du + offu
    v = (jnp.arange(nv, dtype=jnp.float32) - (nv - 1) / 2.0) * dv + offv
    # ray direction components (detector pixel minus source)
    d_x = dcx + u * eux - sx                       # (Nu,)
    d_y = dcy + u * euy - sy                       # (Nu,)
    d_z = v - sz                                   # (Nv,)
    # segment length per marching plane: |d| / |d_x| * dx
    norm = jnp.sqrt(d_x[None, :] ** 2 + d_y[None, :] ** 2
                    + d_z[:, None] ** 2)
    seg = norm / jnp.maximum(jnp.abs(d_x)[None, :], 1e-9) * dx
    inv_dx = 1.0 / jnp.where(jnp.abs(d_x) < 1e-9, 1e-9, d_x)

    vol_block = vol_ref[0]                         # (Px, Nz, Ny)

    def plane_body(p, acc):
        x = xc_ref[0, p]
        s_par = (x - sx) * inv_dx                  # (Nu,)
        yw = sy + s_par * d_y                      # (Nu,)
        fj = (yw - offy) / dy + (ny - 1) / 2.0     # (Nu,)
        fk = ((sz + s_par[None, :] * d_z[:, None] - offz) / dz
              + (nz - 1) / 2.0) - z0               # (Nv, Nu), slab-local
        plane = vol_block[p]                       # (nz_slab, Ny)

        # --- y interpolation: gather two columns per u, blend -------------
        j0 = jnp.floor(fj)
        wj = fj - j0
        j0i = j0.astype(jnp.int32)
        j0c = jnp.clip(j0i, 0, ny - 1)
        j1c = jnp.clip(j0i + 1, 0, ny - 1)
        ok0 = (j0i >= 0) & (j0i < ny)
        ok1 = (j0i + 1 >= 0) & (j0i + 1 < ny)
        col0 = jnp.take(plane, j0c, axis=1)        # (Nz, Nu)
        col1 = jnp.take(plane, j1c, axis=1)
        colz = (col0 * jnp.where(ok0, (1.0 - wj), 0.0)[None, :]
                + col1 * jnp.where(ok1, wj, 0.0)[None, :])   # (Nz, Nu)

        # --- z interpolation: 2-tap take_along_axis -----------------------
        k0 = jnp.floor(fk)
        wk = fk - k0
        k0i = k0.astype(jnp.int32)
        k0c = jnp.clip(k0i, 0, nz_slab - 1)
        k1c = jnp.clip(k0i + 1, 0, nz_slab - 1)
        t0 = jnp.take_along_axis(colz, k0c, axis=0)          # (Nv, Nu)
        t1 = jnp.take_along_axis(colz, k1c, axis=0)
        val = (t0 * jnp.where((k0i >= 0) & (k0i < nz_slab), 1.0 - wk, 0.0)
               + t1 * jnp.where((k0i + 1 >= 0) & (k0i + 1 < nz_slab),
                                wk, 0.0))

        w = ((s_par > 0.0) & (s_par <= 1.0)).astype(jnp.float32)[None, :]
        return acc + val * w

    acc = jax.lax.fori_loop(0, px, plane_body,
                            jnp.zeros((nv, nu), jnp.float32))

    @pl.when(s_idx == 0)
    def _init():
        out_ref[0] = jnp.zeros_like(out_ref[0])

    out_ref[0] += acc * seg


def fp_ray_pallas(vol: jnp.ndarray, geo: ConeGeometry, angles,
                  slab_planes: int = 16, interpret: bool = True,
                  z0=0) -> jnp.ndarray:
    """Forward-project x-dominant ``angles`` with the Pallas kernel.

    ``slab_planes`` (Px) sets the marching-axis slab streamed per grid step;
    the VMEM working set is ``Px * Nz * Ny * 4`` bytes for the slab plus one
    ``(Nv, Nu)`` accumulator and output block (the paper's "two projection
    buffers" become the pipeline's double-buffered output window).

    ``vol`` may be an axial slab of ``geo``'s volume: z planes
    ``[z0, z0 + vol.shape[0])`` — the result is that slab's *partial*
    projection, and summing over a disjoint slab partition reproduces the
    monolithic projection exactly, which is how the out-of-core streaming
    executor drives this kernel.  ``angles`` and ``z0`` may be traced
    (the cached-jit dispatch in :mod:`repro.core.backend` relies on it).
    """
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    slab_planes = min(int(slab_planes), nx)
    n_slabs = -(-nx // slab_planes)
    nx_pad = n_slabs * slab_planes
    nz_slab = vol.shape[0]
    n_angles = angles.shape[0] if hasattr(angles, "shape") else len(angles)

    # (nz_slab, Ny, Nx) -> (S, Px, nz_slab, Ny): marching-axis slabs.
    # Non-divisor slab_planes pads the marching axis with zero planes —
    # zero voxels contribute zero line integral, so the result is exact
    # (and the autotuner may therefore pick any block <= Nx).
    vol_t = jnp.transpose(jnp.asarray(vol), (2, 0, 1))
    if nx_pad != nx:
        vol_t = jnp.concatenate(
            [vol_t, jnp.zeros((nx_pad - nx, nz_slab, ny), vol_t.dtype)], 0)
    vol_slabs = vol_t.reshape(n_slabs, slab_planes, nz_slab, ny)
    consts = angle_constants(geo, angles)
    xc = np.asarray(
        (np.arange(nx_pad) - (nx - 1) / 2.0) * geo.d_voxel[2]
        + geo.off_origin[2], np.float32).reshape(n_slabs, slab_planes)
    z0_arr = jnp.asarray(z0, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_fp_kernel, geo=geo, px=slab_planes,
                               nz_slab=nz_slab)
    return pl.pallas_call(
        kernel,
        grid=(n_angles, n_slabs),
        in_specs=[
            pl.BlockSpec((1, 8), lambda a_, s_: (a_, 0)),
            pl.BlockSpec((1, slab_planes), lambda a_, s_: (s_, 0)),
            pl.BlockSpec((1, 1), lambda a_, s_: (0, 0)),
            pl.BlockSpec((1, slab_planes, nz_slab, ny),
                         lambda a_, s_: (s_, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nv, nu), lambda a_, s_: (a_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_angles, nv, nu), jnp.float32),
        interpret=interpret,
    )(consts, jnp.asarray(xc), z0_arr, vol_slabs)
