"""Pallas TPU kernel: Joseph forward projector with marching-axis streaming.

TPU adaptation of TIGRE's texture-cached ray-driven projection kernel
(paper SS2.1, Fig 2).  Design notes (see DESIGN.md SS4):

* The volume is laid out as marching-axis slabs ``(S, Px, Nz, Ny)`` (a pure
  transpose+reshape of the (Nz, Ny, Nx) volume).  The Pallas grid iterates
  ``(angle, slab)`` with the slab dimension innermost, *accumulating* into
  the same output block -- the Pallas pipeline's automatic double-buffering
  of the next slab's HBM->VMEM DMA while the current slab computes is the
  in-kernel image of the paper's two-projection-buffer overlap scheme.
* CUDA texture trilinear interpolation has no TPU analogue.  Joseph's
  method needs one bilinear (z, y) interpolation per marching plane; we
  decompose it into a per-``u`` column gather along y (lane-wise dynamic
  gather) followed by a 2-tap ``take_along_axis`` in z.  Both are regular,
  vectorisable accesses; validated in interpret mode on CPU, lowerable via
  Mosaic dynamic-gather on real TPUs.
* Per-angle geometry scalars are precomputed on the host into a small
  ``(A, 8)`` table (the analogue of TIGRE's constant memory).

The kernel only handles x-dominant angles; callers rotate the scene by
-90 deg for y-dominant ones (repro.core.projector handles the split).
"""

from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.geometry import ConeGeometry


def angle_constants(geo: ConeGeometry, angles: np.ndarray) -> np.ndarray:
    """(A, 8) per-angle table: src(3), det_c(2), e_u(2), pad."""
    a = np.asarray(angles, np.float64)
    c, s = np.cos(a), np.sin(a)
    out = np.stack([
        geo.DSO * c,                    # Sx
        geo.DSO * s,                    # Sy
        np.zeros_like(a),               # Sz
        -(geo.DSD - geo.DSO) * c,       # det_c x
        -(geo.DSD - geo.DSO) * s,       # det_c y
        -s,                             # e_u x
        c,                              # e_u y
        np.zeros_like(a),
    ], axis=-1)
    return out.astype(np.float32)


def _fp_kernel(consts_ref, xc_ref, vol_ref, out_ref, *, geo: ConeGeometry,
               px: int):
    """One (angle, slab) grid step: accumulate Px marching planes."""
    s_idx = pl.program_id(1)
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    dz, dy, dx = geo.d_voxel
    dv, du = geo.d_detector
    offz, offy, offx = geo.off_origin
    offv, offu = geo.off_detector

    c = consts_ref[0]
    sx, sy, sz = c[0], c[1], c[2]
    dcx, dcy = c[3], c[4]
    eux, euy = c[5], c[6]

    u = (jnp.arange(nu, dtype=jnp.float32) - (nu - 1) / 2.0) * du + offu
    v = (jnp.arange(nv, dtype=jnp.float32) - (nv - 1) / 2.0) * dv + offv
    # ray direction components (detector pixel minus source)
    d_x = dcx + u * eux - sx                       # (Nu,)
    d_y = dcy + u * euy - sy                       # (Nu,)
    d_z = v - sz                                   # (Nv,)
    # segment length per marching plane: |d| / |d_x| * dx
    norm = jnp.sqrt(d_x[None, :] ** 2 + d_y[None, :] ** 2
                    + d_z[:, None] ** 2)
    seg = norm / jnp.maximum(jnp.abs(d_x)[None, :], 1e-9) * dx
    inv_dx = 1.0 / jnp.where(jnp.abs(d_x) < 1e-9, 1e-9, d_x)

    vol_block = vol_ref[0]                         # (Px, Nz, Ny)

    def plane_body(p, acc):
        x = xc_ref[0, p]
        s_par = (x - sx) * inv_dx                  # (Nu,)
        yw = sy + s_par * d_y                      # (Nu,)
        fj = (yw - offy) / dy + (ny - 1) / 2.0     # (Nu,)
        fk = ((sz + s_par[None, :] * d_z[:, None] - offz) / dz
              + (nz - 1) / 2.0)                    # (Nv, Nu)
        plane = vol_block[p]                       # (Nz, Ny)

        # --- y interpolation: gather two columns per u, blend -------------
        j0 = jnp.floor(fj)
        wj = fj - j0
        j0i = j0.astype(jnp.int32)
        j0c = jnp.clip(j0i, 0, ny - 1)
        j1c = jnp.clip(j0i + 1, 0, ny - 1)
        ok0 = (j0i >= 0) & (j0i < ny)
        ok1 = (j0i + 1 >= 0) & (j0i + 1 < ny)
        col0 = jnp.take(plane, j0c, axis=1)        # (Nz, Nu)
        col1 = jnp.take(plane, j1c, axis=1)
        colz = (col0 * jnp.where(ok0, (1.0 - wj), 0.0)[None, :]
                + col1 * jnp.where(ok1, wj, 0.0)[None, :])   # (Nz, Nu)

        # --- z interpolation: 2-tap take_along_axis -----------------------
        k0 = jnp.floor(fk)
        wk = fk - k0
        k0i = k0.astype(jnp.int32)
        k0c = jnp.clip(k0i, 0, nz - 1)
        k1c = jnp.clip(k0i + 1, 0, nz - 1)
        z0 = jnp.take_along_axis(colz, k0c, axis=0)          # (Nv, Nu)
        z1 = jnp.take_along_axis(colz, k1c, axis=0)
        val = (z0 * jnp.where((k0i >= 0) & (k0i < nz), 1.0 - wk, 0.0)
               + z1 * jnp.where((k0i + 1 >= 0) & (k0i + 1 < nz), wk, 0.0))

        w = ((s_par > 0.0) & (s_par <= 1.0)).astype(jnp.float32)[None, :]
        return acc + val * w

    acc = jax.lax.fori_loop(0, px, plane_body,
                            jnp.zeros((nv, nu), jnp.float32))

    @pl.when(s_idx == 0)
    def _init():
        out_ref[0] = jnp.zeros_like(out_ref[0])

    out_ref[0] += acc * seg


def fp_ray_pallas(vol: jnp.ndarray, geo: ConeGeometry, angles: np.ndarray,
                  slab_planes: int = 16, interpret: bool = True
                  ) -> jnp.ndarray:
    """Forward-project x-dominant ``angles`` with the Pallas kernel.

    ``slab_planes`` (Px) sets the marching-axis slab streamed per grid step;
    the VMEM working set is ``Px * Nz * Ny * 4`` bytes for the slab plus one
    ``(Nv, Nu)`` accumulator and output block (the paper's "two projection
    buffers" become the pipeline's double-buffered output window).
    """
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    if nx % slab_planes:
        raise ValueError(f"Nx={nx} not divisible by slab_planes={slab_planes}")
    n_slabs = nx // slab_planes
    a = np.asarray(angles, np.float32)
    n_angles = len(a)

    # (Nz, Ny, Nx) -> (S, Px, Nz, Ny): marching-axis slabs
    vol_slabs = jnp.transpose(vol, (2, 0, 1)).reshape(
        n_slabs, slab_planes, nz, ny)
    consts = jnp.asarray(angle_constants(geo, a))
    xc = np.asarray(
        (np.arange(nx) - (nx - 1) / 2.0) * geo.d_voxel[2] + geo.off_origin[2],
        np.float32).reshape(n_slabs, slab_planes)

    kernel = functools.partial(_fp_kernel, geo=geo, px=slab_planes)
    return pl.pallas_call(
        kernel,
        grid=(n_angles, n_slabs),
        in_specs=[
            pl.BlockSpec((1, 8), lambda a_, s_: (a_, 0)),
            pl.BlockSpec((1, slab_planes), lambda a_, s_: (s_, 0)),
            pl.BlockSpec((1, slab_planes, nz, ny), lambda a_, s_: (s_, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nv, nu), lambda a_, s_: (a_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_angles, nv, nu), jnp.float32),
        interpret=interpret,
    )(consts, jnp.asarray(xc), vol_slabs)
