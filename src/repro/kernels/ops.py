"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to auto-detection: True on CPU hosts (this
container), False on real TPU backends where Mosaic compiles the kernels.

Compiled functions are cached per static key (geometry, block sizes,
weight, interpret) and take ``angles`` as a *traced* argument, so
repeated calls reuse one executable.  The previous wrappers built
``jax.jit(partial(...))`` inside every call — each invocation allocated
a fresh jit wrapper and retraced from scratch (angles were baked in as
static constants), which made every FDK filter step or per-iteration
kernel call pay full trace+compile cost.  ``cache_info()`` exposes the
hit counters; ``tests/test_backend.py`` has the regression test.
"""

from __future__ import annotations

from functools import lru_cache
from typing import Optional

import jax
import jax.numpy as jnp

from . import bp_voxel as _bp
from . import flash_attention as _fa
from . import fp_ray as _fp
from . import tv_grad as _tv
from repro.core.geometry import ConeGeometry


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@lru_cache(maxsize=None)
def _fp_compiled(geo: ConeGeometry, slab_planes: int, interpret: bool):
    @jax.jit
    def f(vol, angles):
        return _fp.fp_ray_pallas(vol, geo, angles, slab_planes=slab_planes,
                                 interpret=interpret)
    return f


@lru_cache(maxsize=None)
def _bp_compiled(geo: ConeGeometry, z_block: int, angle_chunk: int,
                 weight: str, interpret: bool):
    @jax.jit
    def f(proj, angles):
        return _bp.bp_voxel_pallas(proj, geo, angles, z_block=z_block,
                                   angle_chunk=angle_chunk, weight=weight,
                                   interpret=interpret)
    return f


@lru_cache(maxsize=None)
def _tv_compiled(eps: float, z_block: int, interpret: bool):
    @jax.jit
    def f(vol):
        return _tv.tv_grad_pallas(vol, eps=eps, z_block=z_block,
                                  interpret=interpret)
    return f


def cache_info():
    """lru statistics of the compiled-wrapper caches (regression-tested:
    repeated calls must hit, never rebuild)."""
    return {"fp": _fp_compiled.cache_info(),
            "bp": _bp_compiled.cache_info(),
            "tv": _tv_compiled.cache_info()}


def clear_cache() -> None:
    _fp_compiled.cache_clear()
    _bp_compiled.cache_clear()
    _tv_compiled.cache_clear()


def fp_ray_project(vol, geo: ConeGeometry, angles, slab_planes: int = 16,
                   interpret: Optional[bool] = None):
    """Joseph forward projection (x-dominant angles) via the Pallas kernel."""
    interpret = _auto_interpret() if interpret is None else interpret
    return _fp_compiled(geo, slab_planes, interpret)(vol,
                                                     jnp.asarray(angles))


def bp_voxel_backproject(proj, geo: ConeGeometry, angles, z_block: int = 16,
                         angle_chunk: int = 8, weight: str = "fdk",
                         interpret: Optional[bool] = None):
    """Voxel-driven backprojection via the Pallas kernel."""
    interpret = _auto_interpret() if interpret is None else interpret
    return _bp_compiled(geo, z_block, angle_chunk, weight, interpret)(
        proj, jnp.asarray(angles))


def tv_gradient_fused(vol, eps: float = 1e-6, z_block: int = 16,
                      interpret: Optional[bool] = None):
    """Fused TV-gradient stencil via the Pallas kernel."""
    interpret = _auto_interpret() if interpret is None else interpret
    return _tv_compiled(eps, z_block, interpret)(vol)


def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: Optional[bool] = None):
    """FlashAttention-2 style fused attention (GQA-aware)."""
    interpret = _auto_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_kv=block_kv, interpret=interpret)
