"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to auto-detection: True on CPU hosts (this
container), False on real TPU backends where Mosaic compiles the kernels.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import bp_voxel as _bp
from . import flash_attention as _fa
from . import fp_ray as _fp
from . import tv_grad as _tv
from repro.core.geometry import ConeGeometry


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fp_ray_project(vol, geo: ConeGeometry, angles, slab_planes: int = 16,
                   interpret: Optional[bool] = None):
    """Joseph forward projection (x-dominant angles) via the Pallas kernel."""
    interpret = _auto_interpret() if interpret is None else interpret
    fn = jax.jit(partial(_fp.fp_ray_pallas, geo=geo,
                         angles=np.asarray(angles),
                         slab_planes=slab_planes, interpret=interpret))
    return fn(vol)


def bp_voxel_backproject(proj, geo: ConeGeometry, angles, z_block: int = 16,
                         angle_chunk: int = 8, weight: str = "fdk",
                         interpret: Optional[bool] = None):
    """Voxel-driven backprojection via the Pallas kernel."""
    interpret = _auto_interpret() if interpret is None else interpret
    fn = jax.jit(partial(_bp.bp_voxel_pallas, geo=geo,
                         angles=np.asarray(angles), z_block=z_block,
                         angle_chunk=angle_chunk, weight=weight,
                         interpret=interpret))
    return fn(proj)


def tv_gradient_fused(vol, eps: float = 1e-6, z_block: int = 16,
                      interpret: Optional[bool] = None):
    """Fused TV-gradient stencil via the Pallas kernel."""
    interpret = _auto_interpret() if interpret is None else interpret
    return jax.jit(partial(_tv.tv_grad_pallas, eps=eps, z_block=z_block,
                           interpret=interpret))(vol)


def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: Optional[bool] = None):
    """FlashAttention-2 style fused attention (GQA-aware)."""
    interpret = _auto_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_kv=block_kv, interpret=interpret)
