"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import ConeGeometry
from repro.core.projector import backproject_voxel, forward_project_joseph
from repro.core.regularization import tv_gradient as _tv_gradient


def fp_ray_ref(vol: jnp.ndarray, geo: ConeGeometry, angles: np.ndarray
               ) -> jnp.ndarray:
    """Oracle for fp_ray: the pure-JAX Joseph projector (x-dominant)."""
    return forward_project_joseph(vol, geo, jnp.asarray(angles), xdom=True)


def bp_voxel_ref(proj: jnp.ndarray, geo: ConeGeometry, angles: np.ndarray,
                 weight: str = "fdk") -> jnp.ndarray:
    """Oracle for bp_voxel: the pure-JAX voxel-driven backprojector."""
    return backproject_voxel(proj, geo, jnp.asarray(angles), weight=weight)


def tv_grad_ref(vol: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Oracle for tv_grad: autograd of the TV objective."""
    return _tv_gradient(vol, eps)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jnp.ndarray:
    """Oracle for flash_attention: dense softmax attention with the same
    masking / capping semantics (GQA via head repetition)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(d)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v).astype(q.dtype)
