"""Pallas TPU kernel: fused TV-gradient stencil (paper SS2.3 hot-spot).

Computes the exact gradient of the smoothed isotropic TV objective
``sum sqrt(|forward-diff|^2 + eps^2)`` in closed form, fused into a single
VMEM pass per z block (the unfused jnp version materialises 7+ temporaries).
The closed form matches ``jax.grad(tv_value)``:

    g_i = sum_e (f_i - f_{i+e}) / m_i  +  sum_e (f_i - f_{i-e}) / m_{i-e}

with ``m`` the smoothed gradient-magnitude field (edge-replicate diffs).
Blocks carry a 1-plane z halo, prepared by the caller as an overlapping
slab stack (the same trick the distributed regulariser uses at device
granularity -- paper Fig 6 at kernel granularity).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _diffs(f):
    """Edge-replicate forward differences (append semantics) in z, y, x."""
    dz = jnp.concatenate([f[1:] - f[:-1], jnp.zeros_like(f[-1:])], 0)
    dy = jnp.concatenate([f[:, 1:] - f[:, :-1], jnp.zeros_like(f[:, -1:])], 1)
    dx = jnp.concatenate([f[:, :, 1:] - f[:, :, :-1],
                          jnp.zeros_like(f[:, :, -1:])], 2)
    return dz, dy, dx


def _tv_grad_kernel(f_ref, out_ref, *, eps: float, bz: int):
    """f block: (1, bz + 2, Ny, Nx) with 1-plane halo; out: (1, bz, ...)."""
    f = f_ref[0]
    dz, dy, dx = _diffs(f)
    # interior blocks carry real halo planes: their dz at the local last
    # plane must use the halo (the concatenate already did), but the *global*
    # last plane's dz must vanish (edge-replicate).  The caller pads the
    # global ends by replication, which zeroes those diffs automatically.
    m = jnp.sqrt(dz * dz + dy * dy + dx * dx + eps * eps)
    inv_m = 1.0 / m

    # g = [sum_e (f_i - f_{i+e})] / m_i + sum_e (f_i - f_{i-e}) / m_{i-e}
    g = -(dz + dy + dx) * inv_m
    # backward terms: (f_i - f_{i-e}) / m_{i-e} = dz_{i-e} / m_{i-e} shifted
    t = dz * inv_m
    g = g + jnp.concatenate([jnp.zeros_like(t[:1]), t[:-1]], 0)
    t = dy * inv_m
    g = g + jnp.concatenate([jnp.zeros_like(t[:, :1]), t[:, :-1]], 1)
    t = dx * inv_m
    g = g + jnp.concatenate([jnp.zeros_like(t[:, :, :1]), t[:, :, :-1]], 2)

    out_ref[0] = g[1:1 + bz]


def tv_grad_pallas(vol: jnp.ndarray, eps: float = 1e-6, z_block: int = 16,
                   interpret: bool = True) -> jnp.ndarray:
    """Fused TV gradient.  ``vol`` is (Nz, Ny, Nx); returns same shape."""
    nz, ny, nx = vol.shape
    if nz % z_block:
        raise ValueError(f"Nz={nz} not divisible by z_block={z_block}")
    n_zb = nz // z_block
    # overlapping slab stack with 1-plane halos; global ends replicated
    padded = jnp.concatenate([vol[:1], vol, vol[-1:]], axis=0)
    idx = (np.arange(n_zb)[:, None] * z_block
           + np.arange(z_block + 2)[None, :])          # (n_zb, bz+2)
    slabs = padded[jnp.asarray(idx)]                    # (n_zb, bz+2, Ny, Nx)

    kernel = functools.partial(_tv_grad_kernel, eps=eps, bz=z_block)
    out = pl.pallas_call(
        kernel,
        grid=(n_zb,),
        in_specs=[pl.BlockSpec((1, z_block + 2, ny, nx),
                               lambda z_: (z_, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, z_block, ny, nx), lambda z_: (z_, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_zb, z_block, ny, nx), jnp.float32),
        interpret=interpret,
    )(slabs)
    return out.reshape(nz, ny, nx)
