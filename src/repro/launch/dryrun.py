import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # compile-throughput flags: the dry-run only needs the partitioned HLO
    # and buffer assignment, not fast CPU codegen (single-core container)
    "--xla_backend_optimization_level=0 "
    "--xla_llvm_disable_expensive_passes=true")

"""Multi-pod dry-run (deliverable e/f/g).

For every (architecture x input-shape) cell, lower + compile the step on
the production mesh -- 16x16 single pod and 2x16x16 two pods -- and record
memory_analysis / cost_analysis / collective traffic.  Succeeding here
proves the sharding config is coherent at 256/512 chips; the output feeds
EXPERIMENTS.md SSDry-run and SSRoofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
        --shape train_4k --multi-pod --out experiments/dryrun.json
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import (ARCH_NAMES, SHAPES, cell_skip_reason, get_config,
                           input_specs)
from repro.launch.hlo_analysis import (analytic_hbm_traffic,
                                       model_flops_decode,
                                       model_flops_prefill,
                                       model_flops_train,
                                       roofline_from_compiled)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step


# unrolled-compile tractability cutoff: above this, the two-point layer
# extrapolation protocol is used (see dryrun_cell)
_UNROLL_MAX_LAYERS = 24


def _model_flops(cfg, shape: str) -> float:
    seq, batch = SHAPES[shape]
    if shape.startswith("train"):
        return model_flops_train(cfg, seq, batch)
    if shape.startswith("prefill"):
        return model_flops_prefill(cfg, seq, batch)
    return model_flops_decode(cfg, batch)


def dryrun_cell(arch: str, shape: str, mesh, n_chips: int,
                verbose: bool = True, roofline: bool = True,
                cfg_overrides: Optional[Dict[str, Any]] = None,
                **step_kw) -> Dict[str, Any]:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    skip = cell_skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape, "status": "skip",
                "reason": skip}
    t0 = time.time()
    try:
        with mesh:
            # Two compiles per cell:
            # 1. scan-over-layers (the real runtime config): its buffer
            #    assignment gives the realistic per-device memory -- XLA
            #    reuses scan-body buffers across iterations.
            # 2. unrolled: XLA cost_analysis counts while-loop bodies
            #    once, so FLOPs/bytes/collectives come from this one.
            #    Skipped when roofline=False (the multi-pod pass only
            #    proves sharding coherence; the roofline table is
            #    single-pod per the protocol).
            built_s = build_step(cfg, mesh, shape, unroll=False, **step_kw)
            compiled_s = built_s.jitted.lower(*built_s.in_specs).compile()
            mem = compiled_s.memory_analysis()
            t_mem = time.time() - t0
            extrapolated = False
            if roofline and cfg.n_layers <= _UNROLL_MAX_LAYERS:
                built = build_step(cfg, mesh, shape, unroll=True, **step_kw)
                lowered = built.jitted.lower(*built.in_specs)
                t_lower = time.time() - t0 - t_mem
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower - t_mem
                hlo = compiled.as_text()
                rf = roofline_from_compiled(
                    compiled, hlo, n_chips,
                    model_flops=_model_flops(cfg, shape))
            elif roofline:
                # two-point layer extrapolation: FLOPs / HBM bytes /
                # collective bytes are exactly linear in the repeat count
                # (every repeat is the same subgraph), so compiling the
                # unrolled build at R=1 and R=2 and extending to R is
                # exact -- and the only tractable protocol for 40-80-layer
                # archs on this single-core container.
                extrapolated = True
                R = cfg.n_repeats
                pts = {}
                for r in (1, 2):
                    cfg_r = dataclasses.replace(
                        cfg, n_layers=len(cfg.prelude) + len(cfg.pattern) * r)
                    built_r = build_step(cfg_r, mesh, shape, unroll=True,
                                         **step_kw)
                    comp_r = built_r.jitted.lower(
                        *built_r.in_specs).compile()
                    pts[r] = roofline_from_compiled(comp_r,
                                                    comp_r.as_text(),
                                                    n_chips)
                t_lower = 0.0
                t_compile = time.time() - t0 - t_mem

                def ext(a, b):
                    return a + (R - 1) * (b - a)

                rf = pts[1]
                rf.flops = ext(pts[1].flops, pts[2].flops)
                rf.hbm_bytes = ext(pts[1].hbm_bytes, pts[2].hbm_bytes)
                rf.coll_bytes = ext(pts[1].coll_bytes, pts[2].coll_bytes)
                rf.coll_detail = {
                    k: int(ext(pts[1].coll_detail[k], pts[2].coll_detail[k]))
                    for k in pts[1].coll_detail}
                rf.model_flops = _model_flops(cfg, shape)
            else:
                compiled, t_lower, t_compile = compiled_s, 0.0, t_mem
                hlo = compiled_s.as_text()
                rf = roofline_from_compiled(
                    compiled, hlo, n_chips,
                    model_flops=_model_flops(cfg, shape))
            seq, batch = SHAPES[shape]
            model_shard = mesh.shape["model"]
            data_shard = n_chips // model_shard
            # analytic HBM model: the CPU backend inflates 'bytes accessed'
            # for bf16 programs (f32 conversion round-trips); see
            # EXPERIMENTS.md caveats.  Use as the memory term.
            xla_bytes = rf.hbm_bytes
            rf.hbm_bytes = analytic_hbm_traffic(cfg, shape, seq, batch,
                                                model_shard, data_shard)
        row = {
            "xla_bytes_per_dev": xla_bytes,
            "arch": arch, "shape": shape, "status": "ok",
            "extrapolated": extrapolated,
            "n_chips": n_chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "argument_bytes_per_device": getattr(
                mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(
                mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(
                mem, "temp_size_in_bytes", None),
            # donated outputs alias arguments on TPU; args+temp is the
            # honest high-water estimate for the real runtime
            "peak_bytes_per_device": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)),
            **rf.row(),
            "coll_detail": rf.coll_detail,
        }
        if verbose:
            print(f"[ok] {arch:22s} {shape:12s} "
                  f"flops={rf.flops:.3e} hbm={rf.hbm_bytes:.3e} "
                  f"coll={rf.coll_bytes:.3e} bound={rf.bottleneck:10s} "
                  f"peak/dev={row['peak_bytes_per_device']/2**30:.2f}GiB "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
                  flush=True)
        return row
    except Exception as e:  # noqa: BLE001 -- report, don't abort the sweep
        if verbose:
            print(f"[FAIL] {arch} {shape}: {e}", flush=True)
            traceback.print_exc()
        return {"arch": arch, "shape": shape, "status": "fail",
                "error": f"{type(e).__name__}: {e}"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (512 chips) instead of 16x16 (256)")
    ap.add_argument("--out", default=None, help="write JSON report here")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-roofline", action="store_true",
                    help="compile-only pass (skip the unrolled build); "
                         "use for the multi-pod sharding-coherence sweep")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    n_chips = 512 if args.multi_pod else 256
    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    shapes = [args.shape] if args.shape else list(SHAPES)

    print(f"mesh: {dict(mesh.shape)} ({n_chips} chips), "
          f"{len(archs)}x{len(shapes)} cells", flush=True)
    rows = []
    for arch in archs:
        for shape in shapes:
            kw = {}
            if shape.startswith("train") and args.no_zero1:
                kw["zero1"] = False
            rows.append(dryrun_cell(arch, shape, mesh, n_chips,
                                    roofline=not args.no_roofline, **kw))

    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skip" for r in rows)
    n_fail = sum(r["status"] == "fail" for r in rows)
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skip (documented), "
          f"{n_fail} FAIL ==")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"mesh": dict(mesh.shape), "n_chips": n_chips,
                       "rows": rows}, f, indent=1, default=str)
        print(f"wrote {args.out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
