"""HLO-text analysis: collective-traffic accounting + roofline terms.

``collective_bytes(hlo_text)`` sums the result-shape bytes of every
communication op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), per op kind.  ``cost_analysis`` gives FLOPs and HBM
bytes; collectives are NOT in it, hence this parser.

Roofline terms (TPU v5e constants):

    compute    = HLO_FLOPs   / (chips * 197e12 FLOP/s)        [bf16]
    memory     = HLO_bytes   / (chips * 819e9  B/s)
    collective = coll_bytes  / (chips * 50e9 B/s per link * links_used)

We charge each collective byte once against a single ICI link per chip
(conservative: ring algorithms on a 2D torus can stripe across links;
the perf log notes where striping would change the verdict).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# TPU v5e
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (one direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# one shape token: dtype[dims]{layout?}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Result-shape bytes per collective kind (``-done`` ops skipped so
    async pairs are not double-counted)."""
    out: Dict[str, int] = {k: 0 for k in _COLL_KINDS}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.index("\n", m.start())]
        if f"{kind}-done" in line:
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    """Roofline terms.  ``flops`` / ``hbm_bytes`` / ``coll_bytes`` are
    **per-device** quantities: ``cost_analysis`` and ``as_text`` describe
    the single SPMD program every chip runs.  ``model_flops`` is the
    *global* useful work (6ND); per-device comparisons divide by
    ``n_chips``."""
    flops: float                     # HLO FLOPs per device
    hbm_bytes: float                 # HLO bytes accessed per device
    coll_bytes: float                # collective result bytes per device
    n_chips: int
    model_flops: Optional[float] = None
    coll_detail: Optional[Dict[str, int]] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / total HLO FLOPs (remat/dispatch/padding waste)."""
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / (self.flops * self.n_chips)

    @property
    def roofline_fraction(self) -> Optional[float]:
        """(MODEL_FLOPS / chips) / (t_bound * peak): the MFU the compiled
        program could reach if it exactly hits the dominant-term bound."""
        if self.model_flops is None or self.t_bound == 0:
            return None
        return (self.model_flops / self.n_chips) / (self.t_bound
                                                    * PEAK_FLOPS)

    def row(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_from_compiled(compiled, hlo_text: str, n_chips: int,
                           model_flops: Optional[float] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    return Roofline(flops=flops, hbm_bytes=hbm,
                    coll_bytes=float(sum(coll.values())), n_chips=n_chips,
                    model_flops=model_flops, coll_detail=coll)


def model_flops_train(cfg, seq: int, batch: int) -> float:
    """6 * N_active * tokens (fwd+bwd) for dense; MoE counts active params."""
    n_active = active_param_count(cfg)
    return 6.0 * n_active * seq * batch


def model_flops_decode(cfg, batch: int) -> float:
    return 2.0 * active_param_count(cfg) * batch


def model_flops_prefill(cfg, seq: int, batch: int) -> float:
    return 2.0 * active_param_count(cfg) * seq * batch


def total_param_bytes(cfg) -> int:
    import numpy as np
    from repro.models.lm import make_model
    import jax
    import jax.numpy as jnp
    model = make_model(cfg)
    shapes = jax.eval_shape(model.init,
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(shapes))


def analytic_hbm_traffic(cfg, shape: str, seq: int, batch: int,
                         model_shard: int, data_shard: int) -> float:
    """Per-device HBM bytes per step from tensor shapes (the production
    roofline-calculator approach).  Needed because the CPU backend's
    ``bytes accessed`` inflates bf16 programs ~3-5x (bf16 dots convert
    operands to f32 in HBM; on TPU the MXU consumes bf16 directly).

    Model:
      train   = params (fwd read + bwd read + write) + moments (2 x fp32,
                read+write, ZeRO-sharded) + activations (layer boundaries,
                x4: fwd write/read + remat recompute + bwd grad)
      prefill = params read + activations x2
      decode  = params read + KV-cache read + write (+ activations ~0)
    """
    p_dev = total_param_bytes(cfg) / model_shard
    b_loc = max(batch // data_shard, 1)
    act = b_loc * seq * cfg.d_model * 2          # one boundary tensor
    if shape.startswith("train"):
        params_t = 3 * p_dev
        moments_t = 2 * (total_param_bytes(cfg) * 2 / (model_shard
                                                       * data_shard)) * 2
        acts_t = 4 * cfg.n_layers * act
        return params_t + moments_t + acts_t
    if shape.startswith("prefill"):
        return p_dev + 2 * cfg.n_layers * act
    # decode: params + cache traffic; cache ~ 2 * kv * S * hd * layers
    cache = (2 * cfg.n_kv * seq * cfg.hd * 2 * cfg.n_layers
             * b_loc / max(model_shard // 1, 1))
    if cfg.family in ("ssm",):
        cache = cfg.n_layers * b_loc * cfg.d_model * 2 * 64
    return p_dev + 1.5 * cache


def active_param_count(cfg) -> int:
    """Active (per-token) params: embeddings excluded from matmul FLOPs
    except the tied lm head, MoE counts top_k + shared experts only."""
    import numpy as np
    from repro.models.lm import make_model
    import jax
    import jax.numpy as jnp

    model = make_model(cfg)
    shapes = jax.eval_shape(model.init,
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        n = int(np.prod(leaf.shape))
        if "embed" in name:
            n = 0                      # gather, not matmul
        if "moe" in name and "shared" not in name and \
                any(k in name for k in ("w_gate", "w_up", "w_down")):
            n = n * cfg.top_k // max(cfg.n_experts, 1)
        total += n
    # tied unembedding matmul
    total += cfg.vocab * cfg.d_model
    return total
