"""Production meshes.

``make_production_mesh`` builds the dry-run target mesh: a 16x16 pod
(256 chips, TPU v5e topology) with ("data", "model") axes, or the 2-pod
2x16x16 = 512-chip mesh with a leading "pod" axis.  It is a *function*
(never a module-level constant) so importing this module cannot touch JAX
device state before the launcher sets XLA flags.
"""

from __future__ import annotations

import jax

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over the actually-present local devices (tests, CPU)."""
    n = jax.local_device_count()
    assert n % model_axis == 0
    return make_mesh((n // model_axis, model_axis), ("data", "model"))
