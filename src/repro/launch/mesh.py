"""Production meshes.

``make_production_mesh`` builds the dry-run target mesh: a 16x16 pod
(256 chips, TPU v5e topology) with ("data", "model") axes, or the 2-pod
2x16x16 = 512-chip mesh with a leading "pod" axis.  It is a *function*
(never a module-level constant) so importing this module cannot touch JAX
device state before the launcher sets XLA flags.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over the actually-present local devices (tests, CPU)."""
    n = jax.local_device_count()
    assert n % model_axis == 0
    return make_mesh((n // model_axis, model_axis), ("data", "model"))


def make_pod_mesh(pods: int, model_axis: int = 1):
    """Mesh over the local devices with a leading "pod" axis: ``pods``
    equal groups, each a (data, model) grid.  This is how a single-host
    rig (tests, CPU with ``--xla_force_host_platform_device_count``)
    expresses a multi-pod fleet on *real* device handles — the serving
    layer's ``restore_fleet(mesh=...)`` and ``pods_from_mesh`` split it
    back into per-pod groups via :func:`pod_device_groups`."""
    n = jax.local_device_count()
    if pods < 1 or n % pods != 0:
        raise ValueError(f"make_pod_mesh: {n} local devices do not split "
                         f"into {pods} equal pods")
    per = n // pods
    if per % model_axis != 0:
        raise ValueError(f"make_pod_mesh: per-pod device count {per} is "
                         f"not divisible by model_axis={model_axis}")
    return make_mesh((pods, per // model_axis, model_axis),
                     ("pod", "data", "model"))


def pod_device_groups(mesh, pod_axis: str = "pod"):
    """Split a mesh's devices into per-pod groups (one group per index
    along ``pod_axis``).

    This is how the serving layer derives its pods from a production
    mesh: ``make_production_mesh(multi_pod=True)`` has a leading "pod"
    axis, so each slice ``devices[p, ...]`` is one host group, and
    :func:`repro.serve.pool.pods_from_mesh` builds one
    ``DevicePool`` + ``Scheduler`` per group.  A mesh without a pod
    axis is a single pod (all devices in one group).
    """
    if pod_axis not in mesh.axis_names:
        return [list(np.ravel(mesh.devices))]
    axis = mesh.axis_names.index(pod_axis)
    moved = np.moveaxis(mesh.devices, axis, 0)
    return [list(np.ravel(moved[p])) for p in range(moved.shape[0])]
