"""Reconstruction driver: the paper's end-to-end use case.

Runs any TIGRE algorithm against any operator backend (plain / streaming
out-of-core / distributed shard_map) on an analytic phantom, reporting
error against ground truth -- the stand-in for the paper's SS3.2 coffee-bean
(CGLS) and ichthyosaur (OS-SART) reconstructions.

Usage::

    PYTHONPATH=src python -m repro.launch.recon --alg cgls --n 64 \
        --angles 96 --iters 10 --mode plain
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core.geometry import ConeGeometry
from repro.core.operator import CTOperator
from repro.core.splitting import MemoryModel
from repro.core import algorithms as alg
from repro.data import make_ct_dataset


def reconstruct(algname: str = "cgls", n: int = 64, n_angles: int = 96,
                iters: int = 10, mode: str = "plain",
                device_bytes: int = 0, verbose: bool = True):
    geo = ConeGeometry.nice(n)
    vol, angles, proj = make_ct_dataset(geo, n_angles)
    mem = (MemoryModel(device_bytes=device_bytes)
           if device_bytes else MemoryModel())
    op = CTOperator(geo, angles, mode=mode,
                    bp_weight="matched" if algname in ("cgls", "fista")
                    else "pmatched", memory=mem)
    t0 = time.time()
    if algname == "cgls":
        rec = alg.cgls(proj, geo, angles, n_iter=iters, op=op)
    elif algname == "ossart":
        rec = alg.ossart(proj, geo, angles, n_iter=iters,
                         subset_size=max(n_angles // 8, 1), op=op)
    elif algname == "sirt":
        rec = alg.sirt(proj, geo, angles, n_iter=iters, op=op)
    elif algname == "fdk":
        rec = alg.fdk(proj, geo, angles, op=op)
    elif algname == "fista":
        rec = alg.fista_tv(proj, geo, angles, n_iter=iters, op=op)
    elif algname == "asd_pocs":
        rec = alg.asd_pocs(proj, geo, angles, n_iter=iters, op=op)
    else:
        raise ValueError(f"unknown algorithm {algname!r}")
    dt = time.time() - t0
    rec = np.asarray(rec)
    rel = float(np.linalg.norm(rec - vol) / np.linalg.norm(vol))
    if verbose:
        print(f"[recon] {algname} N={n} angles={n_angles} iters={iters} "
              f"mode={mode}: rel_err={rel:.4f} ({dt:.1f}s)")
    return rec, rel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--alg", default="cgls")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--angles", type=int, default=96)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--mode", default="plain",
                    choices=("plain", "stream", "dist"))
    ap.add_argument("--device-bytes", type=int, default=0,
                    help="streaming-mode per-device memory budget")
    args = ap.parse_args()
    reconstruct(args.alg, args.n, args.angles, args.iters, args.mode,
                args.device_bytes)


if __name__ == "__main__":
    main()
