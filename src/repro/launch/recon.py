"""Reconstruction driver: a thin client of the serving scheduler.

Builds a :class:`repro.serve.ReconJob` from the CLI arguments, submits it
to a :class:`repro.serve.Scheduler` and drives it with the threaded
:class:`repro.serve.AsyncDriver`; the scheduler picks the execution mode
(in-core "plain" vs out-of-core "stream") from the planned footprint
unless ``--mode`` forces one, and ``--backend`` selects the kernel
backend (ref | pallas | auto; see docs/operators.md).  ``--mode dist`` bypasses the
scheduler and runs the shard_map backend over the local device mesh.
``--snapshot-dir`` makes the run restart-safe: a SIGTERM parks the job's
step-wise checkpoint durably, and re-running the same command resumes it
bit-identically instead of starting over.  ``--pods N`` serves the job
through a simulated multi-pod fleet instead of a single scheduler
(routing + work stealing; see docs/serve.md); combined with
``--snapshot-dir`` the *fleet* is durable — each pod snapshots into its
own subdirectory, a ``fleet.json`` manifest records the membership, and
a re-run rebuilds the whole fleet with
``MultiPodScheduler.restore_fleet`` and resumes bit-identically.
``--pin-devices`` pins each pod to real local JAX devices through a
pod-axis mesh; the manifest records budgets only, so the restore path
hands the same mesh back to ``restore_fleet`` to re-derive the pins.

``--trace out.json`` enables the process tracer
(:mod:`repro.obs`) for the run and writes a Chrome-trace JSON —
load it at https://ui.perfetto.dev to see the per-slab
H2D / compute / D2H spans on per-device tracks (the paper's Fig 3/5
timelines); ``--prometheus out.prom`` writes a Prometheus-style text
snapshot at exit — the tracer's phase totals and counters plus the
calibration, SLO and memory-margin families.  ``--metrics-port N``
serves the same exposition live over HTTP for the duration of the run
(scrape ``/metrics``; 0 picks a free port), and
``--calibration-report`` prints the modeled-vs-measured calibration
ledger + SLO report as JSON at exit (see docs/observability.md).

Numerics are identical to the old monolithic driver: the scheduler steps
the same algorithm iterators the monolithic entry points wrap.

Usage::

    PYTHONPATH=src python -m repro.launch.recon --alg cgls --n 64 \
        --angles 96 --iters 10 --mode auto
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.geometry import ConeGeometry
from repro.core.operator import CTOperator
from repro.core.splitting import MemoryModel
from repro.core import algorithms as alg
from repro.data import make_ct_dataset
from repro.serve import AsyncDriver, JobStatus, ReconJob, Scheduler


def _job_params(algname: str, n_angles: int) -> dict:
    if algname == "ossart":
        return {"subset_size": max(n_angles // 8, 1)}
    return {}


def reconstruct(algname: str = "cgls", n: int = 64, n_angles: int = 96,
                iters: int = 10, mode: str = "auto",
                device_bytes: int = 0, verbose: bool = True,
                snapshot_dir: str = "", pods: int = 1,
                backend: str = "auto", trace: str = "",
                prometheus: str = "", pin_devices: bool = False,
                metrics_port: int = -1, calibration_report: bool = False,
                autotune: bool = False):
    if autotune:
        # measured block-size tuning for the pallas kernels: first use of
        # each (kind, geometry shape) times a candidate grid and memoises
        # the winner (persisted via REPRO_AUTOTUNE_CACHE when set; pre-
        # bake with tools/autotune.py).  See docs/operators.md.
        from repro.kernels import autotune as _autotune
        _autotune.enable(True)
    # every observability output needs the tracer on: the trace/snapshot
    # exporters read its ring buffer, the live endpoint re-reads it per
    # scrape, and the calibration ledger folds its fleet event log
    if trace or prometheus or calibration_report or metrics_port >= 0:
        from repro import obs
        obs.get_tracer().enable()
        server = None
        if metrics_port >= 0:
            server = obs.MetricsServer(port=metrics_port)
            server.start()
            if verbose:
                print(f"[recon] live metrics at {server.url}")
        try:
            return _reconstruct(algname, n, n_angles, iters, mode,
                                device_bytes, verbose, snapshot_dir,
                                pods, backend, pin_devices)
        finally:
            # written even on a preempted exit: the partial timeline is
            # exactly what you want to look at after a preemption
            if trace:
                obs.write_chrome_trace(trace)
                if verbose:
                    print(f"[recon] chrome trace -> {trace} "
                          f"(load at https://ui.perfetto.dev)")
            if prometheus:
                # the full exposition: tracer families plus the
                # calibration / SLO / memory-margin families
                with open(prometheus, "w") as f:
                    f.write(obs.metrics_text())
                if verbose:
                    print(f"[recon] prometheus snapshot -> {prometheus}")
            if calibration_report:
                import json
                report = {
                    "calibration": obs.CalibrationLedger.from_events()
                                      .report(),
                    "memory": [m.as_dict()
                               for m in obs.memory_calibration()],
                    "slo": obs.slo_report(),
                }
                print(json.dumps(report, indent=2, sort_keys=True))
            if server is not None:
                server.stop()
    return _reconstruct(algname, n, n_angles, iters, mode, device_bytes,
                        verbose, snapshot_dir, pods, backend, pin_devices)


def _reconstruct(algname, n, n_angles, iters, mode, device_bytes,
                 verbose, snapshot_dir, pods, backend, pin_devices=False):
    geo = ConeGeometry.nice(n)
    job_backend = None if backend == "auto" else backend
    vol, angles, proj = make_ct_dataset(geo, n_angles)
    mem = (MemoryModel(device_bytes=device_bytes)
           if device_bytes else MemoryModel())
    t0 = time.time()
    if pods > 1:
        # multi-pod fleet (simulated host groups): the job is routed to
        # the pod whose topology models the cheapest completion; idle
        # pods would steal parked work on a busier trace (bench_serve.py)
        if mode == "dist":
            raise ValueError("--mode dist bypasses the scheduler and "
                             "cannot be combined with --pods")
        import os
        from repro.checkpoint import PreemptionGuard
        from repro.serve import (MultiPodDriver, MultiPodScheduler, Pod,
                                 PodSpec)
        from repro.serve.pool import FLEET_MANIFEST
        guard = PreemptionGuard()
        root = snapshot_dir or None
        mesh = None
        if pin_devices:
            # real device handles: split the local devices into `pods`
            # groups along a leading "pod" mesh axis.  On restore the
            # same mesh re-derives the pins the manifest cannot record.
            from repro.launch.mesh import make_pod_mesh, pod_device_groups
            mesh = make_pod_mesh(pods)
        if root and os.path.isfile(os.path.join(root, FLEET_MANIFEST)):
            # a previous run left a fleet snapshot: rebuild membership +
            # parked jobs and resume them instead of starting over
            mps = MultiPodScheduler.restore_fleet(root, guard=guard,
                                                  mesh=mesh)
        elif mesh is not None:
            groups = pod_device_groups(mesh)
            mps = MultiPodScheduler(
                [Pod(PodSpec(f"pod{i}", n_devices=len(g), memory=mem,
                             jax_devices=tuple(g)), guard=guard)
                 for i, g in enumerate(groups)],
                snapshot_root=root)
        else:
            mps = MultiPodScheduler(
                [Pod(PodSpec(f"pod{i}", n_devices=1, memory=mem),
                     guard=guard) for i in range(pods)],
                snapshot_root=root)
        if mps.restored_jobs:
            jid = mps.restored_jobs[0]
            if verbose:
                done = mps.record(jid).iterations_done
                print(f"[recon] resuming {jid} on a restored "
                      f"{len(mps.pods)}-pod fleet "
                      f"({done} iterations already done)")
        else:
            jid = mps.submit(ReconJob(
                algname, geo, angles, proj, n_iter=iters,
                params=_job_params(algname, n_angles),
                mode=None if mode == "auto" else mode,
                backend=job_backend))
        # periodic per-pod snapshots make a kill -9 recoverable too
        MultiPodDriver(mps, snapshot_every_seconds=1.0 if root else 0.0
                       ).run()
        record = mps.record(jid)
        # parked states only: a FAILED job must fall through to
        # mps.result() below and raise its real error, not masquerade
        # as a resumable preemption
        if record.status in (JobStatus.PREEMPTED, JobStatus.PENDING):
            if verbose:
                where = (f"; fleet snapshot in {root} -- re-run to resume"
                         if root else " (no --snapshot-dir: progress lost)")
                print(f"[recon] fleet preempted after "
                      f"{record.iterations_done}/{iters} iterations{where}")
            return None, None
        if verbose:
            print(f"[recon] pod fleet x{len(mps.pods)}: job ran on "
                  f"{mps.owner(jid).name}")
        rec = mps.result(jid)
    elif mode == "dist":
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model_axis=1)
        op = CTOperator(geo, angles, mode="dist", mesh=mesh,
                        bp_weight="matched" if algname in ("cgls", "fista")
                        else "pmatched", backend=job_backend)
        with mesh:
            rec = _run_monolithic(algname, proj, geo, angles, iters, op)
    else:
        from repro.checkpoint import PreemptionGuard
        sched = Scheduler(n_devices=1, memory=mem,
                          guard=PreemptionGuard(),
                          snapshot_dir=snapshot_dir or None)
        if snapshot_dir and sched.restore(snapshot_dir):
            jid = next(iter(sched.records))   # resume the parked job
            if verbose:
                done = sched.records[jid].iterations_done
                print(f"[recon] resuming {jid} from snapshot "
                      f"({done} iterations already done)")
        else:
            jid = sched.submit(ReconJob(
                algname, geo, angles, proj, n_iter=iters,
                params=_job_params(algname, n_angles),
                mode=None if mode == "auto" else mode,
                backend=job_backend))
        AsyncDriver(sched).run()
        record = sched.records[jid]
        if record.status is JobStatus.PREEMPTED:   # SIGTERM parked it
            if verbose:
                where = (f"; snapshot in {snapshot_dir} -- re-run to resume"
                         if snapshot_dir
                         else " (no --snapshot-dir: progress lost)")
                print(f"[recon] preempted after "
                      f"{record.iterations_done}/{iters} iterations{where}")
            return None, None
        rec = sched.result(jid)
    dt = time.time() - t0
    rec = np.asarray(rec)
    rel = float(np.linalg.norm(rec - vol) / np.linalg.norm(vol))
    if verbose:
        print(f"[recon] {algname} N={n} angles={n_angles} iters={iters} "
              f"mode={mode}: rel_err={rel:.4f} ({dt:.1f}s)")
    return rec, rel


def _run_monolithic(algname, proj, geo, angles, iters, op):
    """Direct (non-scheduled) path for backends the scheduler doesn't own."""
    if algname == "cgls":
        return alg.cgls(proj, geo, angles, n_iter=iters, op=op)
    if algname == "ossart":
        return alg.ossart(proj, geo, angles, n_iter=iters,
                          subset_size=max(len(np.asarray(angles)) // 8, 1),
                          op=op)
    if algname == "sirt":
        return alg.sirt(proj, geo, angles, n_iter=iters, op=op)
    if algname == "fdk":
        return alg.fdk(proj, geo, angles, op=op)
    if algname == "fista":
        return alg.fista_tv(proj, geo, angles, n_iter=iters, op=op)
    if algname == "asd_pocs":
        return alg.asd_pocs(proj, geo, angles, n_iter=iters, op=op)
    raise ValueError(f"unknown algorithm {algname!r}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--alg", default="cgls")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--angles", type=int, default=96)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "plain", "stream", "dist"))
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "ref", "pallas"),
                    help="kernel backend for the operators: the pure-JAX "
                         "projectors (ref), the Pallas TPU kernels "
                         "(pallas; interpret mode off-TPU), or per-JAX-"
                         "backend auto-detection (see docs/operators.md)")
    ap.add_argument("--device-bytes", type=int, default=0,
                    help="per-device memory budget (streaming/placement)")
    ap.add_argument("--snapshot-dir", default="",
                    help="durable checkpoint directory: SIGTERM parks the "
                         "job there; re-running resumes bit-identically")
    ap.add_argument("--pods", type=int, default=1,
                    help="serve through a fleet of this many single-device "
                         "pods (multi-pod routing + work stealing; see "
                         "docs/serve.md); works with --snapshot-dir for "
                         "fleet-level durable resume")
    ap.add_argument("--pin-devices", action="store_true",
                    help="pin each pod to real local JAX devices via a "
                         "pod-axis mesh (local device count must divide "
                         "into --pods); on restore the same mesh "
                         "re-derives the pins the fleet manifest cannot "
                         "record")
    ap.add_argument("--trace", default="",
                    help="enable tracing and write a Chrome-trace JSON "
                         "here (open at https://ui.perfetto.dev; see "
                         "docs/observability.md)")
    ap.add_argument("--prometheus", default="",
                    help="write a Prometheus-style text snapshot (phase "
                         "totals, counters, calibration / SLO / memory-"
                         "margin families) here at exit")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="serve the live Prometheus exposition over HTTP "
                         "on this port for the duration of the run "
                         "(0 = pick a free port); implies tracing")
    ap.add_argument("--calibration-report", action="store_true",
                    help="print the modeled-vs-measured calibration "
                         "ledger + SLO report as JSON at exit; implies "
                         "tracing (see docs/observability.md)")
    ap.add_argument("--autotune", action="store_true",
                    help="measure pallas kernel block sizes on first use "
                         "instead of the static heuristic (equivalent to "
                         "REPRO_AUTOTUNE=1; persist winners across runs "
                         "with REPRO_AUTOTUNE_CACHE=path or pre-bake with "
                         "tools/autotune.py)")
    args = ap.parse_args()
    reconstruct(args.alg, args.n, args.angles, args.iters, args.mode,
                args.device_bytes, snapshot_dir=args.snapshot_dir,
                pods=args.pods, backend=args.backend, trace=args.trace,
                prometheus=args.prometheus, pin_devices=args.pin_devices,
                metrics_port=args.metrics_port,
                calibration_report=args.calibration_report,
                autotune=args.autotune)


if __name__ == "__main__":
    main()
