"""Jitted step builders: train / prefill / serve (decode) per architecture.

Each builder returns ``(step_fn, in_shardings, out_shardings, abstract
inputs)`` ready for ``jax.jit(...).lower(...).compile()`` -- the dry-run
path -- or for real execution on a host mesh (examples/, tests/).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import input_specs
from repro.distributed.sharding import (batch_sharding, make_lm_rules,
                                        param_shardings)
from repro.models.common import ShardingRules
from repro.models.lm import ArchConfig, make_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import zero1_spec


# --------------------------------------------------------------------------
# cache shardings (heuristic: batch axis 0; then heads-like axis 1 if
# divisible by the model axis, else the largest divisible trailing axis)
# --------------------------------------------------------------------------

def cache_shardings(rules: ShardingRules, cache_shapes):
    """Shardings for decode caches.

    Leaves under ``stack`` carry a leading layers axis (replicated); the
    next axis is batch -> ("pod","data"); then the heads-like axis 1 goes
    to "model" when divisible, else the largest divisible trailing axis
    (e.g. the 32k sequence axis when kv-heads = 8 < 16).  Integer ``pos``
    slot arrays are replicated."""
    mesh = rules.mesh
    model_size = mesh.shape["model"]
    batch_axes = rules.rules.get("batch")
    bsz = rules._axis_size(batch_axes)

    def one(path, leaf):
        shape = leaf.shape
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            return NamedSharding(mesh, P())
        stacked = any(getattr(k, "key", None) == "stack" for k in path)
        off = 1 if stacked else 0          # leading layers axis replicated
        if len(shape) - off < 2:
            return NamedSharding(mesh, P())
        entries: list = [None] * len(shape)
        batch_used = shape[off] % bsz == 0 and shape[off] > 0
        if batch_used:
            entries[off] = batch_axes
        cand = None
        if len(shape) - off > 2 and shape[off + 1] % model_size == 0:
            cand = off + 1
        else:
            trailing = [(i, s) for i, s in enumerate(shape[off + 1:],
                                                     off + 1)
                        if s % model_size == 0]
            if trailing:
                cand = max(trailing, key=lambda t: t[1])[0]
        if cand is not None:
            entries[cand] = "model"
        if not batch_used:
            # batch axes idle (e.g. long_500k's global_batch=1): spread the
            # largest remaining divisible axis over them instead
            free = [(i, s) for i, s in enumerate(shape[off + 1:], off + 1)
                    if entries[i] is None and s % bsz == 0]
            if free:
                entries[max(free, key=lambda t: t[1])[0]] = batch_axes
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# --------------------------------------------------------------------------
# optimizer-state shardings
# --------------------------------------------------------------------------

def opt_shardings(p_shard, p_shape, mesh, zero1: bool = False):
    if not zero1:
        moments = p_shard
    else:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

        def z1(ns, sh):
            return NamedSharding(mesh, zero1_spec(ns.spec, sh.shape,
                                                  data_axes, mesh))

        moments = jax.tree.map(z1, p_shard, p_shape)
    return {"m": moments, "v": moments,
            "step": NamedSharding(mesh, P())}


# --------------------------------------------------------------------------
# builders
# --------------------------------------------------------------------------

@dataclasses.dataclass
class BuiltStep:
    fn: Any                       # python callable (to be jitted by caller)
    jitted: Any                   # jax.jit-wrapped with shardings
    in_specs: Tuple               # abstract inputs (ShapeDtypeStructs)
    in_shardings: Tuple
    out_shardings: Any


def _key_struct():
    return jax.ShapeDtypeStruct((2,), jnp.uint32)


def abstract_params(cfg: ArchConfig):
    model = make_model(cfg)
    return jax.eval_shape(model.init, _key_struct())


def build_train_step(cfg: ArchConfig, mesh: Mesh, shape: str = "train_4k",
                     opt: AdamWConfig = AdamWConfig(), zero1: bool = True,
                     remat: bool = True, total_steps: int = 10000,
                     donate: bool = True, unroll: bool = False) -> BuiltStep:
    rules = make_lm_rules(mesh)
    model = make_model(cfg, rules)
    p_shape = abstract_params(cfg)
    p_shard = param_shardings(model, rules, p_shape)
    o_shape = jax.eval_shape(adamw_init, p_shape)
    o_shard = opt_shardings(p_shard, p_shape, mesh, zero1=zero1)
    specs = input_specs(cfg, shape)
    b_shard = batch_sharding(rules, specs)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch["tokens"], batch["labels"],
                              ctx=batch.get("ctx"), remat=remat,
                              unroll=unroll)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr = cosine_schedule(opt_state["step"], 200, total_steps, opt.lr)
        new_p, new_o, metrics = adamw_update(params, grads, opt_state, opt,
                                             lr=lr)
        metrics["loss"] = loss
        return new_p, new_o, metrics

    metrics_shard = {"loss": NamedSharding(mesh, P()),
                     "grad_norm": NamedSharding(mesh, P())}
    jitted = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metrics_shard),
        donate_argnums=(0, 1) if donate else ())
    return BuiltStep(train_step, jitted, (p_shape, o_shape, specs),
                     (p_shard, o_shard, b_shard),
                     (p_shard, o_shard, metrics_shard))


def build_prefill_step(cfg: ArchConfig, mesh: Mesh,
                       shape: str = "prefill_32k",
                       unroll: bool = False) -> BuiltStep:
    rules = make_lm_rules(mesh)
    model = make_model(cfg, rules)
    p_shape = abstract_params(cfg)
    p_shard = param_shardings(model, rules, p_shape)
    specs = input_specs(cfg, shape)
    b_shard = batch_sharding(rules, specs)

    def prefill(params, batch):
        return model.prefill(params, batch["tokens"], ctx=batch.get("ctx"),
                             unroll=unroll)

    bsz = specs["tokens"].shape[0]
    out_shard = rules.named_sharding(("batch", None, None),
                                     (bsz, 1, cfg.vocab))
    jitted = jax.jit(prefill, in_shardings=(p_shard, b_shard),
                     out_shardings=out_shard)
    return BuiltStep(prefill, jitted, (p_shape, specs), (p_shard, b_shard),
                     out_shard)


def build_serve_step(cfg: ArchConfig, mesh: Mesh, shape: str = "decode_32k",
                     donate: bool = True, unroll: bool = False) -> BuiltStep:
    rules = make_lm_rules(mesh)
    model = make_model(cfg, rules)
    p_shape = abstract_params(cfg)
    p_shard = param_shardings(model, rules, p_shape)
    specs = input_specs(cfg, shape)
    c_shard = cache_shardings(rules, specs["caches"])
    tok_shard = batch_sharding(rules, {"token": specs["token"]})["token"]
    pos_shard = NamedSharding(mesh, P())
    in_shardings = [p_shard, tok_shard, pos_shard, c_shard]
    args = [p_shape, specs["token"], specs["pos"], specs["caches"]]
    if "ctx" in specs:
        in_shardings.append(batch_sharding(rules, {"c": specs["ctx"]})["c"])
        args.append(specs["ctx"])

        def serve_step(params, token, pos, caches, ctx):
            return model.decode_step(params, token, pos, caches, ctx=ctx,
                                     unroll=unroll)
    else:
        def serve_step(params, token, pos, caches):
            return model.decode_step(params, token, pos, caches,
                                     unroll=unroll)

    bsz = specs["token"].shape[0]
    logits_shard = rules.named_sharding(("batch", None, None),
                                        (bsz, 1, cfg.vocab))
    jitted = jax.jit(serve_step, in_shardings=tuple(in_shardings),
                     out_shardings=(logits_shard, c_shard),
                     donate_argnums=(3,) if donate else ())
    return BuiltStep(serve_step, jitted, tuple(args), tuple(in_shardings),
                     (logits_shard, c_shard))


def build_step(cfg: ArchConfig, mesh: Mesh, shape: str, **kw) -> BuiltStep:
    """Dispatch on the shape cell kind."""
    if shape.startswith("train"):
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.startswith("prefill"):
        return build_prefill_step(cfg, mesh, shape, **kw)
    return build_serve_step(cfg, mesh, shape, **kw)
