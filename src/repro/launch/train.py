"""Training driver: any assigned arch (reduced or full config) on the local
host mesh, with the full fault-tolerance substrate wired in --
deterministic data, async sharded checkpoints, preemption hook, straggler
watchdog, elastic restore.

Usage (CPU smoke)::

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, PreemptionGuard
from repro.configs import get_config, reduced as reduced_cfg
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.data.tokens import feature_batch
from repro.distributed import StepWatchdog, make_lm_rules, param_shardings
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_train_step
from repro.models.lm import make_model
from repro.optim import AdamWConfig, adamw_init


def train(arch: str, steps: int = 50, use_reduced: bool = True,
          batch: int = 8, seq: int = 128, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 20, model_axis: int = 1, lr: float = 3e-4,
          seed: int = 0, log_every: int = 10, zero1: bool = False,
          guard: Optional[PreemptionGuard] = None, verbose: bool = True):
    cfg = reduced_cfg(arch) if use_reduced else get_config(arch)
    mesh = make_host_mesh(model_axis)
    rules = make_lm_rules(mesh)
    model = make_model(cfg, rules)
    opt_cfg = AdamWConfig(lr=lr)

    data_cfg = TokenPipelineConfig(vocab=cfg.vocab, seq_len=seq,
                                   global_batch=batch, seed=seed)
    pipe = TokenPipeline(data_cfg)

    with mesh:
        # bespoke small-shape step (the production shapes come from configs)
        p_shape = jax.eval_shape(model.init,
                                 jax.ShapeDtypeStruct((2,), jnp.uint32))
        p_shard = param_shardings(model, rules, p_shape)
        params = jax.jit(model.init, out_shardings=p_shard)(
            jax.random.PRNGKey(seed))
        opt_state = adamw_init(params)

        from repro.optim import adamw_update, cosine_schedule

        def step_fn(params, opt_state, tokens, labels, ctx=None):
            def loss_fn(p):
                return model.loss(p, tokens, labels, ctx=ctx)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            lr_t = cosine_schedule(opt_state["step"], 10, steps, opt_cfg.lr)
            new_p, new_o, metrics = adamw_update(params, grads, opt_state,
                                                 opt_cfg, lr=lr_t)
            metrics["loss"] = loss
            return new_p, new_o, metrics

        step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

        mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        guard = guard or PreemptionGuard(install_handler=False)
        dog = StepWatchdog()
        start = 0
        if mgr is not None:
            got = mgr.restore_latest({"params": params, "opt": opt_state})
            if got[0] is not None:
                start = got[0] + 1
                params = got[1]["params"]
                opt_state = got[1]["opt"]
                if verbose:
                    print(f"[train] resumed from step {got[0]}")

        losses = []
        for step in range(start, steps):
            dog.start_step()
            if cfg.encoder_only or cfg.family == "audio":
                feats, labels = feature_batch(data_cfg, step, cfg.d_model)
                tokens = jnp.asarray(feats, cfg.dtype)
            else:
                toks, labels = pipe.batch(step)
                tokens = jnp.asarray(toks)
            ctx = None
            if cfg.family == "vlm":
                rng = np.random.default_rng((seed, step, 99))
                ctx = jnp.asarray(rng.standard_normal(
                    (batch, cfg.n_ctx_tokens, cfg.d_model)), cfg.dtype)
                params, opt_state, metrics = step_jit(
                    params, opt_state, tokens, jnp.asarray(labels), ctx)
            else:
                params, opt_state, metrics = step_jit(
                    params, opt_state, tokens, jnp.asarray(labels))
            loss = float(metrics["loss"])
            losses.append(loss)
            straggler = dog.end_step()
            if verbose and (step % log_every == 0 or step == steps - 1):
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}"
                      + (" [straggler]" if straggler else ""), flush=True)
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step, {"params": params, "opt": opt_state})
            if guard.preempted:
                if mgr is not None:
                    mgr.save(step, {"params": params, "opt": opt_state},
                             blocking=True)
                if verbose:
                    print(f"[train] preempted at step {step}; "
                          "checkpoint committed")
                break
        if mgr is not None:
            mgr.wait()
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    train(args.arch, steps=args.steps, use_reduced=args.reduced,
          batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
          model_axis=args.model_axis, lr=args.lr,
          guard=PreemptionGuard(install_handler=True))


if __name__ == "__main__":
    main()
