"""Assigned-architecture zoo: 10 LM-family architectures as one composable
decoder/encoder LM with pattern-stacked layers.

Families: dense GQA (codeqwen, stablelm), local/global alternating + softcap
(gemma2), MLA (minicpm3), MoE shared+routed top-k (deepseek-moe, moonshot),
hybrid Mamba2 + shared attention (zamba2), sLSTM/mLSTM (xlstm), encoder-only
audio (hubert), cross-attention VLM (llama-3.2-vision).
"""

from .lm import ArchConfig, LM, make_model

__all__ = ["ArchConfig", "LM", "make_model"]
