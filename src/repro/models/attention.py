"""Attention variants: GQA (w/ sliding window, softcap, QK-norm), MLA
(DeepSeek/MiniCPM3 latent KV), and cross-attention (VLM image layers).

Each variant provides ``init``, ``fwd`` (full-sequence: train / prefill,
returning a decode cache) and ``decode`` (single-token with cache).
The full-sequence path uses the Pallas flash-attention kernel when
enabled, else an identical-semantics jnp fallback (XLA path used in the
dry-run so GSPMD owns the sharding).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ShardingRules, apply_rope, dense_init, rms_norm

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    causal: bool = True
    window: Optional[int] = None          # sliding window (gemma2 local)
    softcap: Optional[float] = None       # logit soft-capping (gemma2)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    use_flash: bool = False               # Pallas kernel on the fwd path


def init_gqa(key, cfg: AttnConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], (d, h * hd), 0, dtype),
        "wk": dense_init(ks[1], (d, kvh * hd), 0, dtype),
        "wv": dense_init(ks[2], (d, kvh * hd), 0, dtype),
        "wo": dense_init(ks[3], (h * hd, d), 0, dtype),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.zeros((hd,), dtype)
        p["k_scale"] = jnp.zeros((hd,), dtype)
    return p


GQA_AXES = {
    "wq": ("embed", "heads_x_dim"),
    "wk": ("embed", "kv_x_dim"),
    "wv": ("embed", "kv_x_dim"),
    "wo": ("heads_x_dim", "embed"),
    "q_scale": (None,),
    "k_scale": (None,),
}


_Q_CHUNK = 1024
_KV_ALIGN = 256


def _sdpa(q, k, v, cfg: AttnConfig, q_offset=0):
    """jnp attention with flash-identical masking semantics.

    q: (B, Hq, Sq, D), k/v: (B, Hkv, Skv, D).  ``q_offset`` is the absolute
    position of q[0].

    Long sequences are processed in **query chunks** with *static* KV-range
    slicing: a causal chunk never multiplies KV columns beyond its last
    row, and a sliding-window chunk only touches ``[q0 - window, q1)``.
    This keeps the materialised score block at (B, H, 1024, kv_range) --
    the XLA-path analogue of the Pallas flash kernel's block skipping --
    and makes window layers O(S*w) instead of O(S^2) in both FLOPs and
    HBM traffic (causal layers get the 2x triangle saving).  KV heads are
    broadcast to H (bf16, cheap) so the head axis shards cleanly even when
    Hkv < the mesh model size (gemma2's 8 on a 16-way axis).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / math.sqrt(d)
    qc = _Q_CHUNK if sq > _Q_CHUNK and sq % _Q_CHUNK == 0 else sq
    outs = []
    for q0 in range(0, sq, qc):
        q1 = q0 + qc
        k_lo, k_hi = 0, skv
        if cfg.causal:
            k_hi = min(skv, q_offset + q1)
        if cfg.window is not None:
            k_lo = max(0, (q_offset + q0 - cfg.window + 1)
                       // _KV_ALIGN * _KV_ALIGN)
        k_hi = max(k_hi, k_lo + 1)
        qb = q[:, :, q0:q1].astype(jnp.float32) * scale
        s = jnp.einsum("bhqd,bhkd->bhqk", qb, kf[:, :, k_lo:k_hi])
        if cfg.softcap is not None:
            s = cfg.softcap * jnp.tanh(s / cfg.softcap)
        q_pos = q_offset + q0 + jnp.arange(q1 - q0)[:, None]
        k_pos = k_lo + jnp.arange(k_hi - k_lo)[None, :]
        mask = jnp.ones((q1 - q0, k_hi - k_lo), bool)
        if cfg.causal:
            mask &= k_pos <= q_pos
        if cfg.window is not None:
            mask &= k_pos > q_pos - cfg.window
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        outs.append(jnp.einsum("bhqk,bhkd->bhqd", p, vf[:, :, k_lo:k_hi]))
    o = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)
    return o.astype(q.dtype)


def gqa_fwd(p: Params, x: jnp.ndarray, cfg: AttnConfig, rules: ShardingRules,
            positions=None, make_cache: bool = False):
    """Full-sequence attention.  Returns (out, cache | None)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    if positions is None:
        positions = jnp.arange(s)
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, kvh, hd)
    v = (x @ p["wv"]).reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_scale"])
        k = rms_norm(k, p["k_scale"])
    q = apply_rope(q.swapaxes(1, 2), positions, cfg.rope_theta)  # (B,H,S,D)
    k = apply_rope(k.swapaxes(1, 2), positions, cfg.rope_theta)
    v = v.swapaxes(1, 2)
    q = rules.shard(q, ("batch", "heads", None, None))
    k = rules.shard(k, ("batch", "kv_heads", None, None))
    v = rules.shard(v, ("batch", "kv_heads", None, None))
    if cfg.use_flash:
        from repro.kernels.ops import flash_attention
        o = flash_attention(q, k, v, causal=cfg.causal, window=cfg.window,
                            softcap=cfg.softcap)
    else:
        o = _sdpa(q, k, v, cfg)
    o = o.swapaxes(1, 2).reshape(b, s, h * hd)
    out = o @ p["wo"]
    out = rules.shard(out, ("batch", None, "embed"))
    cache = {"k": k, "v": v} if make_cache else None
    return out, cache


def gqa_decode(p: Params, x: jnp.ndarray, cache, cfg: AttnConfig,
               rules: ShardingRules, pos: jnp.ndarray):
    """One-token decode.  x: (B, 1, D); cache k/v: (B, Hkv, S_cache, D)
    plus ``pos``: (S_cache,) absolute position of each slot (-1 = empty).

    The cache is a **ring buffer**: the new KV is written at
    ``pos % S_cache``.  For full-context layers ``S_cache = S_max`` and the
    ring index is the identity; for sliding-window layers (gemma2 local)
    ``S_cache = window``, which keeps the 32k/500k-context cache at a
    constant few MB.  Validity masks come from the per-slot absolute
    positions, so both layouts share one code path.
    """
    b, _, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    k_new = (x @ p["wk"]).reshape(b, 1, kvh, hd)
    v_new = (x @ p["wv"]).reshape(b, 1, kvh, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_scale"])
        k_new = rms_norm(k_new, p["k_scale"])
    posv = jnp.full((1,), pos)
    q = apply_rope(q.swapaxes(1, 2), posv, cfg.rope_theta)     # (B,H,1,D)
    k_new = apply_rope(k_new.swapaxes(1, 2), posv, cfg.rope_theta)
    v_new = v_new.swapaxes(1, 2)

    s_cache = cache["k"].shape[2]
    slot = pos % s_cache
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, 0, slot, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, 0, slot, 0))
    slot_pos = jax.lax.dynamic_update_slice(cache["pos"],
                                            jnp.full((1,), pos, jnp.int32),
                                            (slot,))
    group = h // kvh
    qg = q.reshape(b, kvh, group, 1, hd)
    # preferred_element_type keeps the cache in bf16 (no f32 copy of the
    # multi-GB cache) while accumulating scores in f32
    scores = jnp.einsum("bkgqd,bkld->bkgql", qg.astype(k.dtype), k,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    if cfg.softcap is not None:
        scores = cfg.softcap * jnp.tanh(scores / cfg.softcap)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if cfg.window is not None:
        valid &= slot_pos > pos - cfg.window
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    pattn = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgql,bkld->bkgqd", pattn.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, h, 1, hd).swapaxes(1, 2).reshape(b, 1, h * hd)
    return (o.astype(x.dtype) @ p["wo"]), {"k": k, "v": v, "pos": slot_pos}


# --------------------------------------------------------------------------
# MLA -- multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    rope_theta: float = 10000.0
    seq_parallel: bool = False


def init_mla(key, cfg: MLAConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 8)
    h = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq_a": dense_init(ks[0], (cfg.d_model, cfg.q_lora_rank), 0, dtype),
        "q_a_scale": jnp.zeros((cfg.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], (cfg.q_lora_rank, h * qd), 0, dtype),
        "wkv_a": dense_init(ks[2], (cfg.d_model,
                                    cfg.kv_lora_rank + cfg.qk_rope_dim), 0,
                            dtype),
        "kv_a_scale": jnp.zeros((cfg.kv_lora_rank,), dtype),
        "wkv_b": dense_init(ks[3], (cfg.kv_lora_rank,
                                    h * (cfg.qk_nope_dim + cfg.v_head_dim)),
                            0, dtype),
        "wo": dense_init(ks[4], (h * cfg.v_head_dim, cfg.d_model), 0, dtype),
    }


MLA_AXES = {
    "wq_a": ("embed", None),
    "q_a_scale": (None,),
    "wq_b": (None, "heads_x_dim"),
    "wkv_a": ("embed", None),
    "kv_a_scale": (None,),
    "wkv_b": (None, "heads_x_dim"),
    "wo": ("heads_x_dim", "embed"),
}


def mla_fwd(p: Params, x: jnp.ndarray, cfg: MLAConfig, rules: ShardingRules,
            positions=None, make_cache: bool = False):
    """MLA full-sequence pass.  The decode cache is the *latent* kv (rank
    kv_lora_rank + rope dim per token) -- the memory-compression point of
    MLA (DESIGN.md SS6 notes the kinship with the paper's memory goal)."""
    b, s, d = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)
    q_lat = rms_norm(x @ p["wq_a"], p["q_a_scale"])
    q = (q_lat @ p["wq_b"]).reshape(b, s, h,
                                    cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions,
                        cfg.rope_theta).swapaxes(1, 2)

    kv_a = x @ p["wkv_a"]                        # (B, S, rank + rope)
    kv_lat, k_rope = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    kv_lat = rms_norm(kv_lat, p["kv_a_scale"])
    k_rope = apply_rope(k_rope[:, None], positions,
                        cfg.rope_theta)[:, 0]    # shared across heads
    kv = (kv_lat @ p["wkv_b"]).reshape(
        b, s, h, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)

    qf = jnp.concatenate([q_nope, q_rope], -1).swapaxes(1, 2)  # (B,H,S,Dq)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (b, s, h, cfg.qk_rope_dim))],
        -1).swapaxes(1, 2)
    vf = v.swapaxes(1, 2)
    # 40 heads do not divide a 16-way model axis; two context-parallel
    # layouts (EXPERIMENTS.md SSPerf B):
    #  * baseline: shard the KV sequence -- GSPMD reduces softmax stats
    #    and the value contraction over seq shards (measured: it instead
    #    all-gathers the sharded score blocks, ~1.6 TB/dev at 32k)
    #  * hillclimbed (mla_seq_parallel): shard the *query* rows -- softmax
    #    is over the local (last) axis, zero attention collectives; K/V
    #    replicated (0.5 GB/dev bf16 at 32k)
    from .perf import FLAGS
    seq_par = cfg.seq_parallel or FLAGS.get("mla_seq_parallel")
    if not seq_par:
        kf = rules.shard(kf, ("batch", None, "seq_kv", None))
        vf = rules.shard(vf, ("batch", None, "seq_kv", None))
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    qc = 1024 if s > 1024 and s % 1024 == 0 else s
    outs = []
    for q0 in range(0, s, qc):
        q1 = q0 + qc
        k_hi = min(s, q1)                       # static causal column skip
        qb = qf[:, :, q0:q1].astype(jnp.float32) * scale
        if seq_par:
            qb = rules.shard(qb, ("batch", None, "seq_q", None))
        sc = jnp.einsum("bhqd,bhkd->bhqk", qb,
                        kf[:, :, :k_hi].astype(jnp.float32))
        mask = (jnp.arange(k_hi)[None, :]
                <= (q0 + jnp.arange(q1 - q0))[:, None])
        sc = jnp.where(mask[None, None], sc, -1e30)
        attn = jax.nn.softmax(sc, axis=-1)
        outs.append(jnp.einsum("bhqk,bhkd->bhqd", attn,
                               vf[:, :, :k_hi].astype(jnp.float32)))
    o = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)
    o = o.swapaxes(1, 2).reshape(b, s, h * cfg.v_head_dim).astype(x.dtype)
    if seq_par:
        o = rules.shard(o, ("batch", "seq_q", None))
    out = o @ p["wo"]
    if seq_par:
        return rules.shard(out, ("batch", "seq_q", None)), (
            {"kv_lat": kv_lat, "k_rope": k_rope,
             "pos": jnp.arange(s, dtype=jnp.int32)} if make_cache else None)
    cache = None
    if make_cache:
        cache = {"kv_lat": kv_lat, "k_rope": k_rope}
    return rules.shard(out, ("batch", None, "embed")), cache


def mla_decode(p: Params, x: jnp.ndarray, cache, cfg: MLAConfig,
               rules: ShardingRules, pos: jnp.ndarray):
    """Decode with the latent cache in the **weight-absorbed** form
    (DeepSeek-V2 App. C): instead of expanding the whole latent cache to
    per-head K/V every step (O(S rank H d) FLOPs -- PFLOPs at 32k), the
    ``wkv_b`` key half is absorbed into the query and the value half is
    applied *after* attention, so all per-step cost is linear in S with
    rank-sized inner dimensions.  The latent cache slots carry a ``pos``
    validity array like the GQA ring cache."""
    b = x.shape[0]
    h = cfg.n_heads
    posv = jnp.full((1,), pos)
    q_lat = rms_norm(x @ p["wq_a"], p["q_a_scale"])
    q = (q_lat @ p["wq_b"]).reshape(b, 1, h,
                                    cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope.swapaxes(1, 2), posv,
                        cfg.rope_theta).swapaxes(1, 2)

    kv_a = x @ p["wkv_a"]
    kv_lat_new, k_rope_new = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    kv_lat_new = rms_norm(kv_lat_new, p["kv_a_scale"])
    k_rope_new = apply_rope(k_rope_new[:, None], posv, cfg.rope_theta)[:, 0]

    kv_lat = jax.lax.dynamic_update_slice(
        cache["kv_lat"], kv_lat_new.astype(cache["kv_lat"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.full((1,), pos, jnp.int32), (pos,))

    # absorb wkv_b: (rank, H*(nope+v)) -> key half (rank,H,nope), value half
    wkv = p["wkv_b"].reshape(cfg.kv_lora_rank, h,
                             cfg.qk_nope_dim + cfg.v_head_dim)
    wk, wv = jnp.split(wkv, [cfg.qk_nope_dim], axis=-1)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk,
                       preferred_element_type=jnp.float32)  # (B,1,H,rank)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    s_nope = jnp.einsum("bqhr,bkr->bhqk", q_abs.astype(kv_lat.dtype),
                        kv_lat, preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(k_rope.dtype),
                        k_rope, preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", attn.astype(kv_lat.dtype),
                       kv_lat, preferred_element_type=jnp.float32)
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat.astype(wv.dtype), wv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, h * cfg.v_head_dim).astype(x.dtype)
    return o @ p["wo"], {"kv_lat": kv_lat, "k_rope": k_rope,
                         "pos": slot_pos}


# --------------------------------------------------------------------------
# cross attention (llama-3.2-vision image layers; stub patch embeddings)
# --------------------------------------------------------------------------

def init_cross(key, cfg: AttnConfig, dtype=jnp.bfloat16) -> Params:
    p = init_gqa(key, cfg, dtype)
    p["q_scale"] = jnp.zeros((cfg.head_dim,), dtype)
    p["k_scale"] = jnp.zeros((cfg.head_dim,), dtype)
    p["gate"] = jnp.zeros((), dtype)   # zero-init tanh gate (llama-vision)
    return p


def cross_fwd(p: Params, x: jnp.ndarray, ctx: jnp.ndarray, cfg: AttnConfig,
              rules: ShardingRules):
    """Text queries attend over (precomputed) image patch embeddings."""
    b, s, d = x.shape
    sk = ctx.shape[1]
    h, kvh, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (ctx @ p["wk"]).reshape(b, sk, kvh, hd)
    v = (ctx @ p["wv"]).reshape(b, sk, kvh, hd)
    q = rms_norm(q, p["q_scale"]).swapaxes(1, 2)
    k = rms_norm(k, p["k_scale"]).swapaxes(1, 2)
    v = v.swapaxes(1, 2)
    q = rules.shard(q, ("batch", "heads", None, None))
    cfg_nc = dataclasses.replace(cfg, causal=False, window=None, softcap=None)
    o = _sdpa(q, k, v, cfg_nc)
    o = o.swapaxes(1, 2).reshape(b, s, h * hd)
    return jnp.tanh(p["gate"]) * (o @ p["wo"])
