"""Shared building blocks: norms, rotary embedding, init, sharding hooks.

Sharding uses *logical axis names* on every parameter / activation; a
:class:`ShardingRules` maps them to mesh axes (DESIGN.md SS5).  On a single
device (smoke tests) the rules are empty and everything is a no-op.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------------
# sharding rules
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ShardingRules:
    """logical axis -> mesh axis (or None).  Missing names -> replicated."""
    mesh: Optional[Mesh] = None
    rules: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def _axis_size(self, mapped) -> int:
        if mapped is None:
            return 1
        names = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        size = 1
        for n in names:
            size *= self.mesh.shape[n]
        return size

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for the logical axes.  If ``shape`` is given, any
        axis whose dimension is not divisible by its mesh-axis size is
        dropped (replicated) -- e.g. 40 MLA heads on a 16-way model axis,
        or a length-1 decode axis."""
        if self.mesh is None:
            return P()
        axes = []
        for i, name in enumerate(logical_axes):
            mapped = self.rules.get(name) if name else None
            if mapped is not None and shape is not None:
                if shape[i] % self._axis_size(mapped) != 0:
                    mapped = None
            axes.append(mapped)
        return P(*axes)

    def shard(self, x, logical_axes: Sequence[Optional[str]]):
        """Apply a sharding constraint (no-op without a mesh; drops
        non-divisible axes)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(logical_axes, x.shape)))

    def named_sharding(self, logical_axes: Sequence[Optional[str]],
                       shape: Optional[Sequence[int]] = None):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


NO_SHARD = ShardingRules()


# --------------------------------------------------------------------------
# initialisation (all params carry .logical_axes metadata via dict pairing)
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM inits)."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, D even); positions: (S,) or broadcastable."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta), x.dtype)  # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def softmax_xent_chunked(x: jnp.ndarray, emb: jnp.ndarray,
                         labels: jnp.ndarray, rules: ShardingRules,
                         chunk: int = 512, softcap: float = 0.0,
                         unroll: bool = False) -> jnp.ndarray:
    """Cross-entropy with the unembedding fused per sequence chunk.

    Never materialises the full (B, S, V) logits -- essential for the 256k
    vocab archs (gemma2) where full logits would be ~16 GiB/device.  The
    vocab axis stays sharded; GSPMD turns the max/sum into collectives.
    ``unroll`` replaces the chunk scan with a python loop (dry-run FLOPs
    accounting); ``softcap`` applies gemma2's final-logit capping.
    """
    b, s, d = x.shape
    n_chunks = s // chunk if s % chunk == 0 else 1
    if s % chunk != 0:
        chunk = s

    def chunk_loss(xc, yc):
        logits = jnp.einsum("bsd,vd->bsv", xc.astype(jnp.float32),
                            emb.astype(jnp.float32))
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        logits = rules.shard(logits, ("batch", None, "vocab"))
        m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    if unroll:
        total = jnp.zeros((), jnp.float32)
        for c in range(n_chunks):
            total = total + chunk_loss(x[:, c * chunk:(c + 1) * chunk],
                                       labels[:, c * chunk:(c + 1) * chunk])
        return total / (b * s)

    def body(carry, inputs):
        xc, yc = inputs                        # (B, chunk, D), (B, chunk)
        return carry + chunk_loss(xc, yc), None

    xr = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    yr = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xr, yr))
    return total / (b * s)
