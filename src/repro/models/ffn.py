"""Feed-forward variants: SwiGLU / GeGLU gated MLPs (dense archs) and the
plain GELU MLP (hubert encoder)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import ShardingRules, dense_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class FFNConfig:
    d_model: int
    d_ff: int
    activation: str = "silu"        # silu (llama/qwen), gelu_tanh (gemma2)
    gated: bool = True              # gated (SwiGLU/GeGLU) vs plain 2-layer
    seq_parallel: bool = False      # shard S (not d_ff) over "model"


def _act(x, name: str):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu_tanh":
        return jax.nn.gelu(x, approximate=True)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=False)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {name!r}")


def init_ffn(key, cfg: FFNConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    if cfg.gated:
        return {
            "w_gate": dense_init(ks[0], (cfg.d_model, cfg.d_ff), 0, dtype),
            "w_up": dense_init(ks[1], (cfg.d_model, cfg.d_ff), 0, dtype),
            "w_down": dense_init(ks[2], (cfg.d_ff, cfg.d_model), 0, dtype),
        }
    return {
        "w_up": dense_init(ks[0], (cfg.d_model, cfg.d_ff), 0, dtype),
        "w_down": dense_init(ks[1], (cfg.d_ff, cfg.d_model), 0, dtype),
    }


FFN_AXES = {
    "w_gate": ("embed", "mlp"),
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
}


def ffn_fwd(p: Params, x: jnp.ndarray, cfg: FFNConfig,
            rules: ShardingRules) -> jnp.ndarray:
    if cfg.gated:
        h = _act(x @ p["w_gate"], cfg.activation) * (x @ p["w_up"])
    else:
        h = _act(x @ p["w_up"], cfg.activation)
    if cfg.seq_parallel:
        # sequence parallelism: weights replicated, tokens sharded
        h = rules.shard(h, ("batch", "seq_q", None))
        out = h @ p["w_down"]
        return rules.shard(out, ("batch", "seq_q", None))
    h = rules.shard(h, ("batch", None, "mlp"))
    out = h @ p["w_down"]
    return rules.shard(out, ("batch", None, "embed"))
