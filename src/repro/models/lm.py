"""Composable LM covering the 10 assigned architectures.

An architecture is a *repeating pattern* of typed blocks, scanned with
``jax.lax.scan`` over the repeat axis (stacked params), plus an optional
prelude (deepseek's first dense layer, zamba2's leftover mamba blocks) and
optional closure-shared blocks (zamba2's shared attention).

Block types
-----------
``attn``         pre-norm GQA + pre-norm gated FFN (llama/qwen style)
``attn_local``   same, sliding-window + softcap (gemma2; sandwich norms)
``attn_global``  same, full attention + softcap (gemma2)
``attn_bidir``   non-causal LayerNorm encoder block (hubert)
``mla``          multi-head latent attention + FFN (minicpm3)
``moe``          GQA attention + MoE FFN (deepseek, moonshot)
``dense``        GQA attention + dense FFN (deepseek first layer)
``xattn``        gated cross-attention over patch embeddings (llama-vision)
``mamba``        Mamba2 block (zamba2)
``mamba_shared`` Mamba2 block followed by the *shared* attention block
``mlstm``/``slstm``  xLSTM blocks

Caches: every cacheable block id owns a stacked (R, ...) cache pytree;
decode scans over repeats consuming/producing cache slices.  Sliding-window
attention uses a ring-buffer cache of ``window`` slots (gemma2 local layers
at 32k+ contexts would otherwise dominate HBM).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import ffn as ffn_mod
from . import mamba2 as mamba_mod
from . import moe as moe_mod
from . import xlstm as xlstm_mod
from .attention import AttnConfig, MLAConfig
from .common import (ShardingRules, dense_init, embed_init, layer_norm,
                     rms_norm, softmax_xent_chunked)
from .ffn import FFNConfig
from .mamba2 import Mamba2Config
from .moe import MoEConfig
from .xlstm import XLSTMConfig

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                         # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    pattern: Tuple[str, ...] = ("attn",)
    prelude: Tuple[str, ...] = ()
    head_dim: int = 0                   # 0 -> d_model // n_heads
    # attention extras
    window: int = 0                     # sliding window (attn_local)
    softcap: float = 0.0                # attention logit softcap
    final_softcap: float = 0.0          # final logit softcap (gemma2)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # MLA (minicpm3)
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    mamba_head_dim: int = 64
    ssd_chunk: int = 256
    # VLM
    n_ctx_tokens: int = 0               # patch-embedding count (stub frontend)
    # misc
    norm: str = "rms"                   # rms | layer
    activation: str = "silu"
    tie_embed: bool = True
    embed_scale: bool = False           # gemma: x *= sqrt(d)
    encoder_only: bool = False
    sub_quadratic: bool = False         # long_500k eligible
    # sequence parallelism: replicate block weights, shard every per-token
    # tensor on the sequence axis over "model" (zero per-layer TP
    # collectives; right when heads don't divide the mesh -- minicpm3's
    # 40 on 16.  See EXPERIMENTS.md SSPerf B.)
    seq_parallel: bool = False
    dtype: Any = jnp.bfloat16

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_repeats(self) -> int:
        n = self.n_layers - len(self.prelude)
        assert n % len(self.pattern) == 0, \
            f"{self.name}: {n} layers not divisible by pattern {self.pattern}"
        return n // len(self.pattern)

    # ---- sub-configs -------------------------------------------------------
    def attn_cfg(self, kind: str) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv=self.n_kv,
            head_dim=self.hd,
            causal=not self.encoder_only and kind != "attn_bidir",
            window=self.window if kind == "attn_local" else None,
            softcap=self.softcap or None, qk_norm=self.qk_norm,
            rope_theta=self.rope_theta)

    def mla_cfg(self) -> MLAConfig:
        return MLAConfig(self.d_model, self.n_heads, self.q_lora_rank,
                         self.kv_lora_rank, self.qk_nope_dim,
                         self.qk_rope_dim, self.v_head_dim, self.rope_theta,
                         seq_parallel=self.seq_parallel)

    def ffn_cfg(self) -> FFNConfig:
        return FFNConfig(self.d_model, self.d_ff, self.activation,
                         gated=self.norm == "rms",
                         seq_parallel=self.seq_parallel)

    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(self.d_model, self.d_expert or self.d_ff,
                         self.n_experts, self.top_k, self.n_shared,
                         activation=self.activation)

    def mamba_cfg(self) -> Mamba2Config:
        return Mamba2Config(self.d_model, d_state=self.ssm_state or 64,
                            head_dim=self.mamba_head_dim,
                            chunk=self.ssd_chunk)

    def xlstm_cfg(self) -> XLSTMConfig:
        return XLSTMConfig(self.d_model, n_heads=self.n_heads)

    def param_count(self) -> int:
        """Approximate parameter count (for 6ND model-FLOPs accounting)."""
        import numpy as np
        model = make_model(self)
        shapes = jax.eval_shape(lambda k: model.init(k), jax.ShapeDtypeStruct(
            (2,), jnp.uint32))
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))


# --------------------------------------------------------------------------
# block init / axes / fwd / decode dispatch
# --------------------------------------------------------------------------

def _norm_init(cfg: ArchConfig, dtype):
    if cfg.norm == "layer":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.zeros((cfg.d_model,), dtype)}


def _apply_norm(p, x, cfg: ArchConfig):
    if cfg.norm == "layer":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


_NORM_AXES = {"scale": (None,), "bias": (None,)}


def init_block(key, kind: str, cfg: ArchConfig) -> Params:
    dt = cfg.dtype
    ks = jax.random.split(key, 4)
    if kind in ("attn", "attn_local", "attn_global", "attn_bidir"):
        p = {"ln1": _norm_init(cfg, dt),
             "attn": attn_mod.init_gqa(ks[0], cfg.attn_cfg(kind), dt),
             "ln2": _norm_init(cfg, dt),
             "ffn": ffn_mod.init_ffn(ks[1], cfg.ffn_cfg(), dt)}
        if kind in ("attn_local", "attn_global"):   # gemma2 sandwich norms
            p["post_ln1"] = _norm_init(cfg, dt)
            p["post_ln2"] = _norm_init(cfg, dt)
        return p
    if kind == "mla":
        return {"ln1": _norm_init(cfg, dt),
                "attn": attn_mod.init_mla(ks[0], cfg.mla_cfg(), dt),
                "ln2": _norm_init(cfg, dt),
                "ffn": ffn_mod.init_ffn(ks[1], cfg.ffn_cfg(), dt)}
    if kind == "moe":
        return {"ln1": _norm_init(cfg, dt),
                "attn": attn_mod.init_gqa(ks[0], cfg.attn_cfg("attn"), dt),
                "ln2": _norm_init(cfg, dt),
                "moe": moe_mod.init_moe(ks[1], cfg.moe_cfg(), dt)}
    if kind == "dense":
        dense_ff = FFNConfig(cfg.d_model, cfg.d_ff, cfg.activation, True)
        return {"ln1": _norm_init(cfg, dt),
                "attn": attn_mod.init_gqa(ks[0], cfg.attn_cfg("attn"), dt),
                "ln2": _norm_init(cfg, dt),
                "ffn": ffn_mod.init_ffn(ks[1], dense_ff, dt)}
    if kind == "xattn":
        return {"ln1": _norm_init(cfg, dt),
                "attn": attn_mod.init_cross(ks[0], cfg.attn_cfg("attn"), dt),
                "ln2": _norm_init(cfg, dt),
                "ffn": ffn_mod.init_ffn(ks[1], cfg.ffn_cfg(), dt)}
    if kind in ("mamba", "mamba_shared"):
        return {"ln1": _norm_init(cfg, dt),
                "mamba": mamba_mod.init_mamba2(ks[0], cfg.mamba_cfg(), dt)}
    if kind == "mlstm":
        return {"ln1": _norm_init(cfg, dt),
                "mlstm": xlstm_mod.init_mlstm(ks[0], cfg.xlstm_cfg(), dt)}
    if kind == "slstm":
        return {"ln1": _norm_init(cfg, dt),
                "slstm": xlstm_mod.init_slstm(ks[0], cfg.xlstm_cfg(), dt)}
    raise ValueError(f"unknown block kind {kind!r}")


def block_axes(kind: str, cfg: Optional[ArchConfig] = None) -> Dict[str, Any]:
    if cfg is not None and cfg.seq_parallel:
        # sequence parallelism: block weights replicated (tokens shard)
        def strip(ax_tree):
            return jax.tree.map(lambda ax: tuple(None for _ in ax),
                                _block_axes(kind),
                                is_leaf=lambda v: isinstance(v, tuple))
        return strip(_block_axes(kind))
    return _block_axes(kind)


def _block_axes(kind: str) -> Dict[str, Any]:
    if kind in ("attn", "attn_local", "attn_global", "attn_bidir"):
        ax = {"ln1": _NORM_AXES, "attn": attn_mod.GQA_AXES,
              "ln2": _NORM_AXES, "ffn": ffn_mod.FFN_AXES}
        if kind in ("attn_local", "attn_global"):
            ax["post_ln1"] = _NORM_AXES
            ax["post_ln2"] = _NORM_AXES
        return ax
    if kind == "mla":
        return {"ln1": _NORM_AXES, "attn": attn_mod.MLA_AXES,
                "ln2": _NORM_AXES, "ffn": ffn_mod.FFN_AXES}
    if kind == "moe":
        return {"ln1": _NORM_AXES, "attn": attn_mod.GQA_AXES,
                "ln2": _NORM_AXES, "moe": moe_mod.MOE_AXES}
    if kind == "dense":
        return {"ln1": _NORM_AXES, "attn": attn_mod.GQA_AXES,
                "ln2": _NORM_AXES, "ffn": ffn_mod.FFN_AXES}
    if kind == "xattn":
        gqa = dict(attn_mod.GQA_AXES)
        gqa["gate"] = ()
        return {"ln1": _NORM_AXES, "attn": gqa,
                "ln2": _NORM_AXES, "ffn": ffn_mod.FFN_AXES}
    if kind in ("mamba", "mamba_shared"):
        return {"ln1": _NORM_AXES, "mamba": mamba_mod.MAMBA2_AXES}
    if kind == "mlstm":
        return {"ln1": _NORM_AXES, "mlstm": xlstm_mod.MLSTM_AXES}
    if kind == "slstm":
        return {"ln1": _NORM_AXES, "slstm": xlstm_mod.SLSTM_AXES}
    raise ValueError(kind)


def _shared_attn_fwd(shared_p, x, cfg: ArchConfig, rules, make_cache,
                     positions):
    """zamba2's shared transformer block (one param set, many call sites)."""
    acfg = cfg.attn_cfg("attn")
    h = _apply_norm(shared_p["ln1"], x, cfg)
    a, cache = attn_mod.gqa_fwd(shared_p["attn"], h, acfg, rules,
                                positions=positions, make_cache=make_cache)
    x = x + a
    h = _apply_norm(shared_p["ln2"], x, cfg)
    x = x + ffn_mod.ffn_fwd(shared_p["ffn"], h, cfg.ffn_cfg(), rules)
    return x, cache


def block_fwd(kind: str, p: Params, x, cfg: ArchConfig, rules,
              ctx=None, shared=None, make_cache=False, positions=None):
    """Returns (x, cache, aux)."""
    zero = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_local", "attn_global", "attn_bidir", "dense",
                "moe"):
        acfg = cfg.attn_cfg(kind if kind.startswith("attn") else "attn")
        h = _apply_norm(p["ln1"], x, cfg)
        a, cache = attn_mod.gqa_fwd(p["attn"], h, acfg, rules,
                                    positions=positions,
                                    make_cache=make_cache)
        if "post_ln1" in p:
            a = _apply_norm(p["post_ln1"], a, cfg)
        x = x + a
        h = _apply_norm(p["ln2"], x, cfg)
        if kind == "moe":
            f, aux = moe_mod.moe_fwd(p["moe"], h, cfg.moe_cfg(), rules)
        else:
            f = ffn_mod.ffn_fwd(p["ffn"], h, cfg.ffn_cfg(), rules)
            aux = zero
        if "post_ln2" in p:
            f = _apply_norm(p["post_ln2"], f, cfg)
        return x + f, cache, aux
    if kind == "mla":
        h = _apply_norm(p["ln1"], x, cfg)
        a, cache = attn_mod.mla_fwd(p["attn"], h, cfg.mla_cfg(), rules,
                                    positions=positions,
                                    make_cache=make_cache)
        x = x + a
        h = _apply_norm(p["ln2"], x, cfg)
        return x + ffn_mod.ffn_fwd(p["ffn"], h, cfg.ffn_cfg(), rules), \
            cache, zero
    if kind == "xattn":
        h = _apply_norm(p["ln1"], x, cfg)
        a = attn_mod.cross_fwd(p["attn"], h, ctx, cfg.attn_cfg("attn"), rules)
        x = x + a
        h = _apply_norm(p["ln2"], x, cfg)
        # cross-attn KV depends only on ctx; decode reuses it via a cache of
        # the projected ctx K/V (built lazily in decode paths)
        return x + ffn_mod.ffn_fwd(p["ffn"], h, cfg.ffn_cfg(), rules), \
            None, zero
    if kind in ("mamba", "mamba_shared"):
        h = _apply_norm(p["ln1"], x, cfg)
        m, mcache = mamba_mod.mamba2_fwd(p["mamba"], h, cfg.mamba_cfg(),
                                         rules, make_cache=make_cache)
        x = x + m
        scache = None
        if kind == "mamba_shared":
            x, scache = _shared_attn_fwd(shared, x, cfg, rules, make_cache,
                                         positions)
        cache = ({"mamba": mcache, "shared": scache}
                 if make_cache and kind == "mamba_shared" else mcache)
        return x, cache, zero
    if kind == "mlstm":
        h = _apply_norm(p["ln1"], x, cfg)
        m, cache = xlstm_mod.mlstm_fwd(p["mlstm"], h, cfg.xlstm_cfg(), rules,
                                       make_cache=make_cache)
        return x + m, cache, zero
    if kind == "slstm":
        h = _apply_norm(p["ln1"], x, cfg)
        m, cache = xlstm_mod.slstm_fwd(p["slstm"], h, cfg.xlstm_cfg(), rules,
                                       make_cache=make_cache)
        return x + m, cache, zero
    raise ValueError(kind)


def _shared_attn_decode(shared_p, x, cache, cfg: ArchConfig, rules, pos):
    acfg = cfg.attn_cfg("attn")
    h = _apply_norm(shared_p["ln1"], x, cfg)
    a, cache = attn_mod.gqa_decode(shared_p["attn"], h, cache, acfg, rules,
                                   pos)
    x = x + a
    h = _apply_norm(shared_p["ln2"], x, cfg)
    x = x + ffn_mod.ffn_fwd(shared_p["ffn"], h, cfg.ffn_cfg(), rules)
    return x, cache


def block_decode(kind: str, p: Params, x, cache, cfg: ArchConfig, rules,
                 pos, ctx=None, shared=None):
    """Single-token step.  Returns (x, cache)."""
    if kind in ("attn", "attn_local", "attn_global", "dense", "moe"):
        acfg = cfg.attn_cfg(kind if kind.startswith("attn") else "attn")
        h = _apply_norm(p["ln1"], x, cfg)
        a, cache = attn_mod.gqa_decode(p["attn"], h, cache, acfg, rules, pos)
        if "post_ln1" in p:
            a = _apply_norm(p["post_ln1"], a, cfg)
        x = x + a
        h = _apply_norm(p["ln2"], x, cfg)
        if kind == "moe":
            f, _ = moe_mod.moe_fwd(p["moe"], h, cfg.moe_cfg(), rules)
        else:
            f = ffn_mod.ffn_fwd(p["ffn"], h, cfg.ffn_cfg(), rules)
        if "post_ln2" in p:
            f = _apply_norm(p["post_ln2"], f, cfg)
        return x + f, cache
    if kind == "mla":
        h = _apply_norm(p["ln1"], x, cfg)
        a, cache = attn_mod.mla_decode(p["attn"], h, cache, cfg.mla_cfg(),
                                       rules, pos)
        x = x + a
        h = _apply_norm(p["ln2"], x, cfg)
        return x + ffn_mod.ffn_fwd(p["ffn"], h, cfg.ffn_cfg(), rules), cache
    if kind == "xattn":
        h = _apply_norm(p["ln1"], x, cfg)
        a = attn_mod.cross_fwd(p["attn"], h, ctx, cfg.attn_cfg("attn"), rules)
        x = x + a
        h = _apply_norm(p["ln2"], x, cfg)
        return x + ffn_mod.ffn_fwd(p["ffn"], h, cfg.ffn_cfg(), rules), cache
    if kind in ("mamba", "mamba_shared"):
        h = _apply_norm(p["ln1"], x, cfg)
        mcache = cache["mamba"] if kind == "mamba_shared" else cache
        m, mcache = mamba_mod.mamba2_decode(p["mamba"], h, mcache,
                                            cfg.mamba_cfg(), rules)
        x = x + m
        if kind == "mamba_shared":
            x, scache = _shared_attn_decode(shared, x, cache["shared"], cfg,
                                            rules, pos)
            return x, {"mamba": mcache, "shared": scache}
        return x, mcache
    if kind == "mlstm":
        h = _apply_norm(p["ln1"], x, cfg)
        m, cache = xlstm_mod.mlstm_decode(p["mlstm"], h, cache,
                                          cfg.xlstm_cfg(), rules)
        return x + m, cache
    if kind == "slstm":
        h = _apply_norm(p["ln1"], x, cfg)
        m, cache = xlstm_mod.slstm_decode(p["slstm"], h, cache,
                                          cfg.xlstm_cfg(), rules)
        return x + m, cache
    raise ValueError(kind)


# --------------------------------------------------------------------------
# cache construction (shapes only; used concretely and via eval_shape)
# --------------------------------------------------------------------------

def block_cache_zeros(kind: str, cfg: ArchConfig, batch: int, s_max: int):
    """Zero-initialised decode cache for one block instance."""
    dt = cfg.dtype
    if kind in ("attn", "attn_global", "dense", "moe"):
        return {"k": jnp.zeros((batch, cfg.n_kv, s_max, cfg.hd), dt),
                "v": jnp.zeros((batch, cfg.n_kv, s_max, cfg.hd), dt),
                "pos": jnp.full((s_max,), -1, jnp.int32)}
    if kind == "attn_local":                    # ring buffer of window slots
        w = min(cfg.window, s_max)
        return {"k": jnp.zeros((batch, cfg.n_kv, w, cfg.hd), dt),
                "v": jnp.zeros((batch, cfg.n_kv, w, cfg.hd), dt),
                "pos": jnp.full((w,), -1, jnp.int32)}
    if kind == "mla":
        return {"kv_lat": jnp.zeros((batch, s_max, cfg.kv_lora_rank), dt),
                "k_rope": jnp.zeros((batch, s_max, cfg.qk_rope_dim), dt),
                "pos": jnp.full((s_max,), -1, jnp.int32)}
    if kind == "xattn":
        return None
    if kind in ("mamba", "mamba_shared"):
        mc = cfg.mamba_cfg()
        w1 = mc.conv_width - 1
        mcache = {"conv": {"x": jnp.zeros((batch, w1, mc.d_inner), dt),
                           "B": jnp.zeros((batch, w1, mc.d_state), dt),
                           "C": jnp.zeros((batch, w1, mc.d_state), dt)},
                  "ssm": jnp.zeros((batch, mc.n_heads, mc.head_dim,
                                    mc.d_state), jnp.float32)}
        if kind == "mamba_shared":
            return {"mamba": mcache,
                    "shared": {"k": jnp.zeros((batch, cfg.n_kv, s_max,
                                               cfg.hd), dt),
                               "v": jnp.zeros((batch, cfg.n_kv, s_max,
                                               cfg.hd), dt),
                               "pos": jnp.full((s_max,), -1, jnp.int32)}}
        return mcache
    if kind == "mlstm":
        xc = cfg.xlstm_cfg()
        return {"conv": jnp.zeros((batch, xc.conv_width - 1, xc.d_inner), dt),
                "C": jnp.zeros((batch, xc.n_heads, xc.head_dim,
                                xc.head_dim), jnp.float32),
                "n": jnp.zeros((batch, xc.n_heads, xc.head_dim), jnp.float32),
                "m": jnp.full((batch, xc.n_heads), -1e30, jnp.float32)}
    if kind == "slstm":
        d = cfg.d_model
        return {"c": jnp.zeros((batch, d), jnp.float32),
                "n": jnp.zeros((batch, d), jnp.float32),
                "m": jnp.full((batch, d), -1e30, jnp.float32),
                "y": jnp.zeros((batch, d), jnp.float32)}
    raise ValueError(kind)


# --------------------------------------------------------------------------
# the model
# --------------------------------------------------------------------------

class LM:
    """Functional model wrapper: ``init``, ``forward``, ``loss``,
    ``prefill``, ``decode_step``, ``init_cache``."""

    def __init__(self, cfg: ArchConfig, rules: Optional[ShardingRules] = None):
        self.cfg = cfg
        self.rules = rules or ShardingRules()

    # ---- init --------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        p: Params = {"embed": embed_init(keys[0], (cfg.vocab, cfg.d_model),
                                         cfg.dtype),
                     "final_norm": _norm_init(cfg, cfg.dtype)}
        if cfg.prelude:
            p["prelude"] = {
                f"p{i}": init_block(jax.random.fold_in(keys[1], i), kind, cfg)
                for i, kind in enumerate(cfg.prelude)}
        r = cfg.n_repeats

        def stacked(kind, base_key):
            leaves = [init_block(jax.random.fold_in(base_key, j), kind, cfg)
                      for j in range(r)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

        p["stack"] = {f"b{i}": stacked(kind, jax.random.fold_in(keys[2], i))
                      for i, kind in enumerate(cfg.pattern)}
        if "mamba_shared" in cfg.pattern:
            p["shared_attn"] = {
                "ln1": _norm_init(cfg, cfg.dtype),
                "attn": attn_mod.init_gqa(keys[3], cfg.attn_cfg("attn"),
                                          cfg.dtype),
                "ln2": _norm_init(cfg, cfg.dtype),
                "ffn": ffn_mod.init_ffn(keys[4], cfg.ffn_cfg(), cfg.dtype)}
        if not cfg.tie_embed:
            p["lm_head"] = dense_init(keys[5], (cfg.vocab, cfg.d_model), 1,
                                      cfg.dtype)
        return p

    def param_axes(self, params=None) -> Dict[str, Any]:
        """Logical-axes pytree matching ``init`` output (stacked blocks get
        a leading ``layers`` axis -> None).  If ``params`` (or its abstract
        shapes) is given, the template is pruned to its exact structure --
        the template is a superset (e.g. LayerNorm bias vs RMS scale)."""
        cfg = self.cfg

        def prepend(ax_tree):
            return jax.tree.map(lambda ax: ("layers",) + tuple(ax), ax_tree,
                                is_leaf=lambda v: isinstance(v, tuple))

        axes: Dict[str, Any] = {"embed": ("vocab", "embed"),
                                "final_norm": _NORM_AXES}
        if cfg.prelude:
            axes["prelude"] = {f"p{i}": block_axes(kind, cfg)
                               for i, kind in enumerate(cfg.prelude)}
        axes["stack"] = {f"b{i}": prepend(block_axes(kind, cfg))
                         for i, kind in enumerate(cfg.pattern)}
        if "mamba_shared" in cfg.pattern:
            axes["shared_attn"] = {"ln1": _NORM_AXES,
                                   "attn": attn_mod.GQA_AXES,
                                   "ln2": _NORM_AXES,
                                   "ffn": ffn_mod.FFN_AXES}
        if not cfg.tie_embed:
            axes["lm_head"] = ("vocab", "embed")
        if params is None:
            return axes

        def walk(ax_node, p_node):
            if isinstance(p_node, dict):
                return {k: walk(ax_node[k], v) for k, v in p_node.items()}
            return tuple(ax_node)

        return walk(axes, params)

    # ---- forward -----------------------------------------------------------
    def _embed(self, p, tokens):
        cfg = self.cfg
        if tokens.dtype in (jnp.int32, jnp.int64):
            x = jnp.take(p["embed"], tokens, axis=0)
        else:
            x = tokens.astype(cfg.dtype)        # stub frontend: embeddings in
        if cfg.embed_scale:
            x = x * math.sqrt(cfg.d_model)
        return self.rules.shard(x, ("batch", None, "embed"))

    def forward(self, p: Params, tokens, ctx=None, make_cache: bool = False,
                remat: bool = True, unroll: bool = False):
        """Full-sequence pass.  Returns (hidden, caches, aux_loss).

        ``unroll=True`` replaces the repeat-axis ``lax.scan`` with a Python
        loop: needed by the dry-run because XLA's cost_analysis counts a
        while-loop body once regardless of trip count, which would
        under-report FLOPs/bytes/collectives by ~n_layers.
        """
        cfg, rules = self.cfg, self.rules
        x = self._embed(p, tokens)
        s = x.shape[1]
        positions = jnp.arange(s)
        aux_total = jnp.zeros((), jnp.float32)
        caches: Dict[str, Any] = {}

        if cfg.prelude:
            for i, kind in enumerate(cfg.prelude):
                x, cache, aux = block_fwd(kind, p["prelude"][f"p{i}"], x, cfg,
                                          rules, ctx=ctx,
                                          shared=p.get("shared_attn"),
                                          make_cache=make_cache,
                                          positions=positions)
                aux_total = aux_total + aux
                caches[f"p{i}"] = cache

        shared = p.get("shared_attn")

        def unit(x, unit_params):
            aux_u = jnp.zeros((), jnp.float32)
            ucaches = {}
            for i, kind in enumerate(cfg.pattern):
                x, cache, aux = block_fwd(kind, unit_params[f"b{i}"], x, cfg,
                                          rules, ctx=ctx, shared=shared,
                                          make_cache=make_cache,
                                          positions=positions)
                aux_u = aux_u + aux
                ucaches[f"b{i}"] = cache
            return x, ucaches, aux_u

        if remat:
            unit = jax.checkpoint(unit)

        if unroll:
            ys = []
            for r in range(cfg.n_repeats):
                unit_params = jax.tree.map(lambda a: a[r], p["stack"])
                x, ucaches, aux_u = unit(x, unit_params)
                aux_total = aux_total + aux_u
                ys.append(ucaches)
            if make_cache:
                caches["stack"] = jax.tree.map(lambda *zs: jnp.stack(zs),
                                               *ys)
        else:
            def body(carry, unit_params):
                x, aux = carry
                x, ucaches, aux_u = unit(x, unit_params)
                return (x, aux + aux_u), ucaches

            (x, aux_total), stack_caches = jax.lax.scan(
                body, (x, aux_total), p["stack"])
            if make_cache:
                caches["stack"] = stack_caches
        x = _apply_norm(p["final_norm"], x, cfg)
        return x, (caches if make_cache else None), aux_total

    def logits(self, p: Params, hidden):
        cfg = self.cfg
        emb = p["embed"] if cfg.tie_embed else p["lm_head"]
        lg = jnp.einsum("bsd,vd->bsv", hidden.astype(jnp.float32),
                        emb.astype(jnp.float32))
        if cfg.final_softcap:
            lg = cfg.final_softcap * jnp.tanh(lg / cfg.final_softcap)
        return lg

    def loss(self, p: Params, tokens, labels, ctx=None, remat: bool = True,
             unroll: bool = False):
        """Mean token cross-entropy (+ MoE aux)."""
        cfg = self.cfg
        hidden, _, aux = self.forward(p, tokens, ctx=ctx, remat=remat,
                                      unroll=unroll)
        emb = p["embed"] if cfg.tie_embed else p["lm_head"]
        xent = softmax_xent_chunked(hidden, emb, labels, self.rules,
                                    softcap=cfg.final_softcap,
                                    unroll=unroll)
        return xent + aux

    # ---- serving -----------------------------------------------------------
    def init_cache(self, batch: int, s_max: int):
        cfg = self.cfg
        caches: Dict[str, Any] = {}
        for i, kind in enumerate(cfg.prelude):
            caches[f"p{i}"] = block_cache_zeros(kind, cfg, batch, s_max)
        r = cfg.n_repeats

        def stack_zeros(kind):
            one = block_cache_zeros(kind, cfg, batch, s_max)
            return jax.tree.map(
                lambda z: jnp.broadcast_to(z[None], (r,) + z.shape), one)

        caches["stack"] = {f"b{i}": stack_zeros(kind)
                           for i, kind in enumerate(cfg.pattern)
                           if block_cache_zeros(kind, cfg, batch,
                                                s_max) is not None}
        return caches

    def prefill(self, p: Params, tokens, ctx=None, unroll: bool = False):
        """Prefill: hidden states + last-position logits (no cache return in
        the lowered serving path -- decode cells lower ``decode_step``)."""
        hidden, _, _ = self.forward(p, tokens, ctx=ctx, make_cache=False,
                                    remat=False, unroll=unroll)
        return self.logits(p, hidden[:, -1:])

    def decode_step(self, p: Params, token, pos, caches, ctx=None,
                    unroll: bool = False):
        """One-token decode.  token: (B, 1) int32 (or (B, 1, D) features);
        pos: scalar int32.  Returns (logits (B, 1, V), new caches)."""
        cfg, rules = self.cfg, self.rules
        x = self._embed(p, token)
        new_caches: Dict[str, Any] = {}
        for i, kind in enumerate(cfg.prelude):
            x, c = block_decode(kind, p["prelude"][f"p{i}"], x,
                                caches.get(f"p{i}"), cfg, rules, pos, ctx=ctx,
                                shared=p.get("shared_attn"))
            new_caches[f"p{i}"] = c
        shared = p.get("shared_attn")

        def body(x, slices):
            unit_params, unit_caches = slices
            ucaches = {}
            for i, kind in enumerate(cfg.pattern):
                cid = f"b{i}"
                x, c = block_decode(kind, unit_params[cid], x,
                                    unit_caches.get(cid), cfg, rules, pos,
                                    ctx=ctx, shared=shared)
                if cid in unit_caches:
                    ucaches[cid] = c
            return x, ucaches

        if unroll:
            ys = []
            for r in range(cfg.n_repeats):
                sl = jax.tree.map(lambda a: a[r],
                                  (p["stack"], caches["stack"]))
                x, uc = body(x, sl)
                ys.append(uc)
            stack_caches = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
        else:
            x, stack_caches = jax.lax.scan(body, x,
                                           (p["stack"], caches["stack"]))
        new_caches["stack"] = stack_caches
        x = _apply_norm(p["final_norm"], x, cfg)
        return self.logits(p, x), new_caches


def make_model(cfg: ArchConfig, rules: Optional[ShardingRules] = None) -> LM:
    return LM(cfg, rules)
