"""Mamba2 (SSD) block -- zamba2-7b's backbone.

Implements the *chunked* state-space-dual algorithm (Mamba2 paper SS6):
the sequence is split into chunks of length L; within a chunk the output
is an attention-like masked matmul (MXU-friendly), across chunks a short
``lax.scan`` carries the (H, P, N) state.  This is the TPU-native
formulation -- a per-step scan would serialise 4k+ tiny updates, while the
chunked form is O(S L) + O(S N P / L) dense matmuls.

Decode is the O(1) recurrent update: ``S <- a S + dt B x^T; y = C S``.

Layout: d_inner = expand * d_model, heads H = d_inner / head_dim(P=64),
B/C shared across heads in ``n_groups`` groups (we use 1), state N.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ShardingRules, dense_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64               # N  (zamba2: ssm_state=64)
    head_dim: int = 64              # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256                # SSD chunk length L
    dt_min: float = 1e-3
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def d_conv_ch(self) -> int:     # channels through the causal conv
        return self.d_inner + 2 * self.d_state


def init_mamba2(key, cfg: Mamba2Config, dtype=jnp.bfloat16) -> Params:
    """Projections for z / x / B / C / dt are SEPARATE parameters.

    Mathematically identical to the packed ``in_proj`` (one matmul over
    the concatenated output), but slicing a packed model-sharded axis at
    non-shard-aligned offsets (z|xBC|dt at 7168/14464 of 14576) makes
    GSPMD reshard every piece via collective-permutes -- measured at
    ~0.5 GB/layer/pass on the 256-chip mesh (EXPERIMENTS.md SSPerf C3).
    Separate column-parallel projections shard each output cleanly."""
    ks = jax.random.split(key, 8)
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    dt = jnp.exp(jax.random.uniform(ks[2], (h,))
                 * (math.log(cfg.dt_max) - math.log(cfg.dt_min))
                 + math.log(cfg.dt_min))
    return {
        "w_z": dense_init(ks[0], (cfg.d_model, di), 0, dtype),
        "w_x": dense_init(ks[1], (cfg.d_model, di), 0, dtype),
        "w_B": dense_init(ks[4], (cfg.d_model, n), 0, dtype),
        "w_C": dense_init(ks[5], (cfg.d_model, n), 0, dtype),
        "w_dt": dense_init(ks[6], (cfg.d_model, h), 0, dtype),
        "conv_x": (jax.random.normal(ks[7], (cfg.conv_width, di))
                   * 0.1).astype(dtype),
        "conv_xb": jnp.zeros((di,), dtype),
        "conv_B": (jax.random.normal(jax.random.fold_in(ks[7], 1),
                                     (cfg.conv_width, n)) * 0.1).astype(dtype),
        "conv_Bb": jnp.zeros((n,), dtype),
        "conv_C": (jax.random.normal(jax.random.fold_in(ks[7], 2),
                                     (cfg.conv_width, n)) * 0.1).astype(dtype),
        "conv_Cb": jnp.zeros((n,), dtype),
        "dt_bias": jnp.log(jnp.expm1(dt)).astype(jnp.float32),   # softplus^-1
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[3], (di, cfg.d_model), 0, dtype),
    }


MAMBA2_AXES = {
    "w_z": ("embed", "inner"),
    "w_x": ("embed", "inner"),
    "w_B": ("embed", None),
    "w_C": ("embed", None),
    "w_dt": ("embed", None),
    "conv_x": (None, "inner"),
    "conv_xb": ("inner",),
    "conv_B": (None, None),
    "conv_Bb": (None,),
    "conv_C": (None, None),
    "conv_Cb": (None,),
    "dt_bias": (None,),
    "a_log": (None,),
    "d_skip": (None,),
    "norm_scale": ("inner",),
    "out_proj": ("inner", "embed"),
}


def _causal_conv(xbc, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d.  xbc: (B, S, C); state: (B, W-1, C) or None.
    Returns (out, new_state)."""
    bsz, s, c = xbc.shape
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((bsz, width - 1, c), xbc.dtype)
    padded = jnp.concatenate([state, xbc], axis=1)           # (B, S+W-1, C)
    out = sum(padded[:, i:i + s] * w[i][None, None, :] for i in range(width))
    new_state = padded[:, -(width - 1):] if width > 1 else state
    return jax.nn.silu(out + b[None, None, :]), new_state


def _rms(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * (1 + scale.astype(jnp.float32))).astype(x.dtype)


def _ssd_chunked(xh, dt, a, B, C, cfg: Mamba2Config,
                 init_state: Optional[jnp.ndarray] = None):
    """Chunked SSD.  xh: (Bz, S, H, P); dt: (Bz, S, H); a: (H,) (negative);
    B, C: (Bz, S, N).  Returns (y (Bz,S,H,P), final_state (Bz,H,P,N))."""
    bsz, s, h, p = xh.shape
    n = B.shape[-1]
    L = min(cfg.chunk, s)
    assert s % L == 0, f"seq {s} not divisible by chunk {L}"
    nc = s // L

    # per-step log decay: log a_t = dt_t * a  (a < 0)
    loga = dt * a[None, None, :]                              # (Bz, S, H)
    xc = xh.reshape(bsz, nc, L, h, p)
    dtc = dt.reshape(bsz, nc, L, h)
    logac = loga.reshape(bsz, nc, L, h)
    Bc = B.reshape(bsz, nc, L, n)
    Cc = C.reshape(bsz, nc, L, n)

    cum = jnp.cumsum(logac, axis=2)                           # (Bz,nc,L,H)
    total = cum[:, :, -1]                                     # (Bz,nc,H)

    # intra-chunk: M[t,s] = (C_t . B_s) exp(cum_t - cum_s) 1[s<=t]
    # The (Bz,nc,L,L,H) mask tensor is the working-set hog; it is sharded
    # over H ("inner" heads on the model axis) and kept in the compute
    # dtype (bf16 in training) -- decays are computed in fp32 first.
    cdtype = xh.dtype
    cb = jnp.einsum("bcln,bcmn->bclm", Cc.astype(cdtype), Bc.astype(cdtype))
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (Bz,nc,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    m = jnp.where(tri[None, None, :, :, None],
                  jnp.exp(decay).astype(cdtype), 0)
    m = m * cb[..., None]                                     # (Bz,nc,L,L,H)
    xdt = (xc * dtc[..., None].astype(cdtype))                # (Bz,nc,L,H,P)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", m, xdt)

    # chunk states: S_c = sum_s exp(total - cum_s) dt_s B_s x_s^T
    w = jnp.exp(total[:, :, None, :] - cum)                   # (Bz,nc,L,H)
    sc = jnp.einsum("bclh,bcln,bclhp->bchpn", w * dtc, Bc, xc)

    # inter-chunk recurrence over nc chunks
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        sc_k, total_k = inp                                   # (Bz,H,P,N),(Bz,H)
        out_state = state                                     # state BEFORE chunk
        new = state * jnp.exp(total_k)[:, :, None, None] + sc_k
        return new, out_state

    scs = jnp.moveaxis(sc, 1, 0)                              # (nc,Bz,H,P,N)
    totals = jnp.moveaxis(total, 1, 0)                        # (nc,Bz,H)
    final, prev_states = jax.lax.scan(step, init_state.astype(jnp.float32),
                                      (scs.astype(jnp.float32), totals))
    prev = jnp.moveaxis(prev_states, 0, 1)                    # (Bz,nc,H,P,N)

    # inter-chunk output: y_t += C_t . (exp(cum_t) * S_prev)
    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc.astype(cdtype),
                         prev.astype(cdtype), jnp.exp(cum).astype(cdtype))
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, final


def mamba2_fwd(p: Params, x: jnp.ndarray, cfg: Mamba2Config,
               rules: ShardingRules, make_cache: bool = False):
    """Full-sequence Mamba2 block.  x: (B, S, D).  Cache: conv states +
    SSM state for decode."""
    bsz, s, d = x.shape
    h, pd, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    z = rules.shard(x @ p["w_z"], ("batch", None, "inner"))
    xr = rules.shard(x @ p["w_x"], ("batch", None, "inner"))
    Br = x @ p["w_B"]
    Cr = x @ p["w_C"]
    dtr = x @ p["w_dt"]
    xin, conv_x = _causal_conv(xr, p["conv_x"], p["conv_xb"])
    B, conv_B = _causal_conv(Br, p["conv_B"], p["conv_Bb"])
    C, conv_C = _causal_conv(Cr, p["conv_C"], p["conv_Cb"])
    conv_state = {"x": conv_x, "B": conv_B, "C": conv_C}
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"][None, None])
    a = -jnp.exp(p["a_log"])
    xh = xin.reshape(bsz, s, h, pd)
    # head (tensor) parallelism: H over the model axis keeps the SSD
    # intra-chunk tensor local and makes out_proj row-parallel
    from .perf import FLAGS
    if FLAGS.get("mamba_head_constraints", True):
        xh = rules.shard(xh, ("batch", None, "heads_inner", None))
        dt = rules.shard(dt, ("batch", None, "heads_inner"))
    y, state = _ssd_chunked(xh, dt, a, B.astype(jnp.float32),
                            C.astype(jnp.float32), cfg)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, cfg.d_inner).astype(x.dtype)
    y = _rms(y * jax.nn.silu(z), p["norm_scale"])
    out = y @ p["out_proj"]
    out = rules.shard(out, ("batch", None, "embed"))
    cache = ({"conv": conv_state, "ssm": state} if make_cache else None)
    return out, cache


def mamba2_decode(p: Params, x: jnp.ndarray, cache, cfg: Mamba2Config,
                  rules: ShardingRules):
    """One-token decode.  x: (B, 1, D); cache {conv {x,B,C}, ssm
    (B,H,P,N)}."""
    bsz = x.shape[0]
    h, pd, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    z = x @ p["w_z"]
    xr = x @ p["w_x"]
    dtr = x @ p["w_dt"]
    xin, conv_x = _causal_conv(xr, p["conv_x"], p["conv_xb"],
                               state=cache["conv"]["x"])
    B, conv_B = _causal_conv(x @ p["w_B"], p["conv_B"], p["conv_Bb"],
                             state=cache["conv"]["B"])
    C, conv_C = _causal_conv(x @ p["w_C"], p["conv_C"], p["conv_Cb"],
                             state=cache["conv"]["C"])
    conv_state = {"x": conv_x, "B": conv_B, "C": conv_C}
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"][None, None])
    a = -jnp.exp(p["a_log"])
    xh = xin.reshape(bsz, 1, h, pd).astype(jnp.float32)

    decay = jnp.exp(dt[:, 0] * a[None, :])                    # (B, H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], B[:, 0], xh[:, 0])
    state = cache["ssm"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0], state)[:, None]   # (B,1,H,P)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, 1, cfg.d_inner).astype(x.dtype)
    y = _rms(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out_proj"], {"conv": conv_state, "ssm": state}
