"""Mixture-of-Experts FFN (deepseek-moe-16b, moonshot-v1-16b-a3b).

Fine-grained MoE: ``n_experts`` routed experts with top-``k`` softmax
routing, optional always-on shared experts (DeepSeek-MoE's 2 shared), and a
load-balance auxiliary loss.

Dispatch is **gather/scatter based** (dropless-with-capacity), not the
classic one-hot-matmul dispatch: the (T, E, C) einsum dispatch costs
O(T^2 k d) FLOPs (C ~ Tk/E), which at 1M tokens would dwarf the expert
compute and wreck the MODEL_FLOPS/HLO_FLOPs roofline ratio.  The gather
formulation keeps HLO FLOPs at the *active* compute 6 T k d_ff d and turns
dispatch into memory ops:

    pos_in_expert = cumsum(one-hot assignment) per expert  (O(T E) adds)
    buffer[e, c] <- token t  (scatter, overflow slots dropped)
    expert FFN on (E, C, d) via batched einsum                (MXU)
    out[t] += gate * result[e, c]                            (scatter-add)

Experts are sharded over the ``model`` ("expert") mesh axis -- expert
parallelism.  Under GSPMD the token gather across the data axis lowers to
an all-gather (baseline); the hillclimbed shard_map all-to-all variant
lives in the perf notes (EXPERIMENTS.md SSPerf).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ShardingRules, dense_init
from .ffn import FFNConfig, ffn_fwd, init_ffn

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_expert: int                   # per-expert FFN hidden size (1408)
    n_experts: int = 64
    top_k: int = 6
    n_shared: int = 0               # deepseek: 2 always-on shared experts
    capacity_factor: float = 1.25
    aux_coef: float = 0.01
    activation: str = "silu"

    @property
    def shared_cfg(self) -> Optional[FFNConfig]:
        if self.n_shared == 0:
            return None
        return FFNConfig(self.d_model, self.n_shared * self.d_expert,
                         self.activation, gated=True)


def init_moe(key, cfg: MoEConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_expert
    std = 1.0 / (d ** 0.5)
    p = {
        "router": dense_init(ks[0], (d, e), 0, jnp.float32),
        "w_gate": (jax.random.truncated_normal(ks[1], -2, 2, (e, d, f))
                   * std).astype(dtype),
        "w_up": (jax.random.truncated_normal(ks[2], -2, 2, (e, d, f))
                 * std).astype(dtype),
        "w_down": (jax.random.truncated_normal(ks[3], -2, 2, (e, f, d))
                   * (1.0 / f ** 0.5)).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = init_ffn(ks[4], cfg.shared_cfg, dtype)
    return p


MOE_AXES = {
    "router": ("embed", None),
    "w_gate": ("expert", "embed", None),
    "w_up": ("expert", "embed", None),
    "w_down": ("expert", None, "embed"),
    "shared": {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
               "w_down": ("mlp", "embed")},
}


def _act(x, name):
    return jax.nn.silu(x) if name == "silu" else jax.nn.gelu(x)


def _rank_in_expert(flat_e: jnp.ndarray, e: int) -> jnp.ndarray:
    """Rank of each assignment within its expert (sort-based; the one-hot
    cumsum baseline costs O((Tk)^2 E)-class in XLA's reduce-window model,
    measured as a 100x useful-FLOPs inflation at 1M tokens -- SSPerf A1)."""
    from .perf import FLAGS
    if FLAGS.get("moe_onehot_dispatch"):
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        return jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    seg_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                 jnp.cumsum(counts)[:-1]])
    rank_sorted = (jnp.arange(flat_e.shape[0], dtype=jnp.int32)
                   - seg_start[sorted_e])
    return jnp.zeros_like(flat_e).at[order].set(rank_sorted)


def moe_fwd(p: Params, x: jnp.ndarray, cfg: MoEConfig, rules: ShardingRules
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out, aux_loss).  x: (B, S, D).

    **Locality-chunked dispatch** (SSPerf A3): tokens are grouped into
    ``g`` chunks aligned with the data mesh axis; every chunk builds its
    own (E, C/g) capacity buffers from its *local* tokens, so the
    token gather and the combine scatter never cross data shards -- the
    GSPMD-expressible equivalent of expert-parallel all-to-all.  The only
    cross-shard traffic left is the model-axis psum of the k partial
    expert outputs per token (which TP needs anyway).  Compared to the
    global (E, C) formulation this removed a ~1 TB/dev all-gather of the
    token stream (EXPERIMENTS.md SSPerf A2->A3)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    # dispatch-chunk count: the data-axis size (1 without a mesh);
    # tiny decode batches keep g=1 so capacity floors stay exact
    g = rules._axis_size(rules.rules.get("batch")) if rules.mesh else 1
    if t % g or (t // g) < 256:
        g = 1
    tc = t // g
    cap = max(int(cfg.capacity_factor * tc * k / e + 1), min(tc, 64))
    xt = x.reshape(t, d)
    xg = xt.reshape(g, tc, d)
    xg = rules.shard(xg, ("batch", None, None))

    logits = xg.astype(jnp.float32) @ p["router"]            # (g, Tc, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (g, Tc, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch): E * sum_e density_e * mean-prob_e
    density = jnp.zeros((e,), jnp.float32).at[
        expert_idx.reshape(-1)].add(1.0 / (t * k))
    aux = cfg.aux_coef * e * jnp.sum(
        density * probs.reshape(t, e).mean(0))

    def dispatch_chunk(xc, eidx, gates):
        """One chunk: local buffers (E, C, D) -> expert FFN partials."""
        flat_e = eidx.reshape(-1)                            # (Tc*k,)
        pos_in_e = _rank_in_expert(flat_e, e)
        slot = flat_e * cap + pos_in_e
        slot = jnp.where(pos_in_e < cap, slot, e * cap)      # overflow
        token_of = jnp.arange(tc, dtype=jnp.int32).repeat(k)
        buf_tok = jnp.full((e * cap + 1,), 0, jnp.int32).at[slot].set(
            token_of, mode="drop")[:-1].reshape(e, cap)
        buf_used = jnp.zeros((e * cap + 1,), jnp.bool_).at[slot].set(
            True, mode="drop")[:-1].reshape(e, cap)
        xd = jnp.take(xc, buf_tok, axis=0) \
            * buf_used[..., None].astype(xc.dtype)           # (E, C, D)
        slot_gate = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
            gates.reshape(-1), mode="drop")[:-1].reshape(e, cap)
        return xd, buf_tok, slot_gate

    xd, buf_tok, slot_gate = jax.vmap(dispatch_chunk)(xg, expert_idx,
                                                      gate_vals)
    xd = rules.shard(xd, ("batch", "expert", None, None))    # (g,E,C,D)

    # batched expert FFN -- fully local: g over data, E over model
    h = _act(jnp.einsum("gecd,edf->gecf", xd, p["w_gate"]), cfg.activation)
    h = h * jnp.einsum("gecd,edf->gecf", xd, p["w_up"])
    h = rules.shard(h, ("batch", "expert", None, None))
    yd = jnp.einsum("gecf,efd->gecd", h, p["w_down"])        # (g,E,C,D)

    # combine: per-chunk scatter-add (local); GSPMD psums the k expert
    # partials over the model axis
    weighted = yd * slot_gate[..., None].astype(yd.dtype)

    def combine_chunk(w, toks):
        return jnp.zeros((tc, d), w.dtype).at[toks.reshape(-1)].add(
            w.reshape(e * cap, d))

    out = jax.vmap(combine_chunk)(weighted, buf_tok)         # (g, Tc, D)
    out = rules.shard(out, ("batch", None, None))
    out = out.reshape(t, d)

    if cfg.n_shared:
        out = out + ffn_fwd(p["shared"], xt[None], cfg.shared_cfg,
                            rules)[0]
    return out.reshape(b, s, d).astype(x.dtype), aux
