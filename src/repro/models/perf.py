"""Perf-variant flags for the hillclimb loop (EXPERIMENTS.md SSPerf).

Read at trace time by the model code; mutated by benchmarks/perf_probe.py.
Defaults are the shipping configuration (post-hillclimb)."""

FLAGS = {
    # mLSTM: chunked query processing with static causal block skipping
    # (replaces the (B,H,S,S) gate tensor + seq_q resharding constraint).
    # Baseline (paper-faithful parallel form) = False; flipped by the
    # hillclimb after measurement (EXPERIMENTS.md SSPerf).
    "mlstm_chunked": False,
    # MoE: baseline one-hot-cumsum dispatch (True) vs sort-based ranking
    # (False, hillclimbed default) -- see SSPerf iteration A1
    "moe_onehot_dispatch": False,
    # MLA: query-row sharded attention (hillclimb B1, 9x) vs seq_kv
    # sharding (baseline; GSPMD gathers the sharded score blocks)
    "mla_seq_parallel": True,
    # mamba2: explicit heads_inner constraints on xh/dt (baseline True);
    # False lets GSPMD propagate from the in_proj column sharding
    "mamba_head_constraints": True,
    # save fwd collective results across remat instead of recomputing
    # them in the backward pass (hillclimb C)
    "remat_save_collectives": False,
}
