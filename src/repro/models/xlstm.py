"""xLSTM blocks (xlstm-350m): mLSTM (matrix memory, parallelisable) and
sLSTM (scalar memory, sequential recurrence).

* mLSTM uses the *stabilised parallel form* (xLSTM paper App. A): with
  log-forget gates f and log-input gates i, the attention-like weight is

      D[t, s] = exp( (F_t - F_s) + i_s - m_t ),   F_t = sum_{r<=t} log f_r

  with a per-row max-stabiliser m_t; output = (D @ V) / max(|n|, 1).  This
  is a quadratic masked matmul, same compute class as attention -- MXU
  friendly.  Decode keeps the (H, P, P) matrix state recurrently.

* sLSTM is inherently sequential (the paper's point: true recurrence with
  memory mixing cannot be parallelised) -- a ``lax.scan`` over time with a
  block-diagonal (per-head) recurrent matrix.  Documented as the
  latency-bound layer in the roofline notes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ShardingRules, dense_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int = 4
    proj_factor: float = 2.0        # mLSTM up-projection factor
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return int(self.proj_factor * self.d_model)

    @property
    def head_dim(self) -> int:
        assert self.d_inner % self.n_heads == 0
        return self.d_inner // self.n_heads


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def init_mlstm(key, cfg: XLSTMConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 8)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        "w_up": dense_init(ks[0], (d, 2 * di), 0, dtype),      # [x, z] branch
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], (di, di), 0, dtype),
        "wk": dense_init(ks[3], (di, di), 0, dtype),
        "wv": dense_init(ks[4], (di, di), 0, dtype),
        "w_if": dense_init(ks[5], (di, 2 * h), 0, jnp.float32),  # i, f gates
        "b_if": jnp.concatenate([jnp.zeros((h,)),
                                 3.0 * jnp.ones((h,))]).astype(jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "w_down": dense_init(ks[6], (di, d), 0, dtype),
    }


MLSTM_AXES = {
    # wq/wk/wv are square (di, di): row-parallel (contraction over the
    # sharded inner dim -> psum) -- both dims on "model" would be invalid
    "w_up": ("embed", "inner"), "conv_w": (None, "inner"),
    "conv_b": ("inner",), "wq": ("inner", None), "wk": ("inner", None),
    "wv": ("inner", None), "w_if": ("inner", None), "b_if": (None,),
    "norm_scale": ("inner",), "w_down": ("inner", "embed"),
}


def _causal_conv(x, w, b, state=None):
    bsz, s, c = x.shape
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((bsz, width - 1, c), x.dtype)
    padded = jnp.concatenate([state, x], axis=1)
    out = sum(padded[:, i:i + s] * w[i][None, None, :] for i in range(width))
    return jax.nn.silu(out + b[None, None, :]), padded[:, -(width - 1):]


def _multihead_rms(x, scale, nh, eps=1e-6):
    """Per-head RMS norm on (B, S, di) viewed as (B, S, H, P)."""
    b, s, di = x.shape
    xh = x.reshape(b, s, nh, di // nh).astype(jnp.float32)
    var = jnp.mean(jnp.square(xh), -1, keepdims=True)
    xh = (xh * jax.lax.rsqrt(var + eps)).reshape(b, s, di)
    return (xh * (1 + scale.astype(jnp.float32))).astype(x.dtype)


def mlstm_fwd(p: Params, x: jnp.ndarray, cfg: XLSTMConfig,
              rules: ShardingRules, make_cache: bool = False):
    """Parallel (stabilised) mLSTM.  x: (B, S, D)."""
    bsz, s, d = x.shape
    h, pd, di = cfg.n_heads, cfg.head_dim, cfg.d_inner
    xz = x @ p["w_up"]
    xz = rules.shard(xz, ("batch", None, "inner"))
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"])
    q = (xc @ p["wq"]).reshape(bsz, s, h, pd).swapaxes(1, 2)   # (B,H,S,P)
    k = (xc @ p["wk"]).reshape(bsz, s, h, pd).swapaxes(1, 2) / math.sqrt(pd)
    v = (xi @ p["wv"]).reshape(bsz, s, h, pd).swapaxes(1, 2)

    gates = xc.astype(jnp.float32) @ p["w_if"] + p["b_if"]     # (B,S,2H)
    ig, fg = jnp.split(gates, 2, axis=-1)                      # (B,S,H)
    logf = jax.nn.log_sigmoid(fg).swapaxes(1, 2)               # (B,H,S)
    logi = ig.swapaxes(1, 2)                                   # (B,H,S)
    F = jnp.cumsum(logf, axis=-1)                              # (B,H,S)

    from .perf import FLAGS
    if FLAGS.get("mlstm_chunked") and s > 1024 and s % 1024 == 0:
        # hillclimbed variant (EXPERIMENTS.md SSPerf): process query
        # chunks with static causal column skipping.  Exact: every s <= t
        # of a chunk's rows lies inside the [0, q1) slice.  Removes the
        # (B,H,S,S) tensor AND its seq_q resharding (the baseline's
        # collective hog).
        ys = []
        qc = 1024
        for q0 in range(0, s, qc):
            q1 = q0 + qc
            logD = (F[:, :, q0:q1, None] - F[:, :, None, :q1]
                    + logi[:, :, None, :q1])
            tri = (jnp.arange(q1)[None, :]
                   <= (q0 + jnp.arange(qc))[:, None])
            logD = jnp.where(tri[None, None], logD, -jnp.inf)
            mrow = jnp.maximum(jnp.max(logD, axis=-1, keepdims=True), 0.0)
            D = jnp.exp(logD - mrow)
            sc = jnp.einsum("bhtp,bhsp->bhts", q[:, :, q0:q1],
                            k[:, :, :q1]).astype(jnp.float32)
            wts = sc * D
            num = jnp.einsum("bhts,bhsp->bhtp", wts.astype(q.dtype),
                             v[:, :, :q1])
            den = jnp.maximum(jnp.abs(jnp.sum(wts, -1, keepdims=True)),
                              jnp.exp(-mrow)[..., 0:1])
            ys.append((num.astype(jnp.float32) / den).astype(x.dtype))
        yh = jnp.concatenate(ys, axis=2)                       # (B,H,S,P)
    else:
        # paper-faithful stabilised parallel form (baseline).
        # The (B, H, S, S) gate matrix is the working-set hog; with only 4
        # heads it is sharded over the *query* sequence axis instead
        # (sequence parallelism on the model axis).
        logD = (F[:, :, :, None] - F[:, :, None, :] + logi[:, :, None, :])
        logD = rules.shard(logD, ("batch", None, "seq_q", None))
        tri = jnp.tril(jnp.ones((s, s), bool))
        logD = jnp.where(tri[None, None], logD, -jnp.inf)
        mrow = jnp.max(logD, axis=-1, keepdims=True)           # (B,H,S,1)
        mrow = jnp.maximum(mrow, 0.0)                          # n >= 1 guard
        D = jnp.exp(logD - mrow).astype(q.dtype)               # (B,H,S,S)

        scores = jnp.einsum("bhtp,bhsp->bhts", q, k).astype(jnp.float32)
        wts = scores * D.astype(jnp.float32)                   # (B,H,S,S)
        num = jnp.einsum("bhts,bhsp->bhtp", wts.astype(q.dtype), v)
        den = jnp.maximum(jnp.abs(jnp.sum(wts, -1, keepdims=True)),
                          jnp.exp(-mrow)[..., 0:1])            # >= exp(-m)
        yh = (num.astype(jnp.float32) / den).astype(x.dtype)   # (B,H,S,P)

    y = yh.swapaxes(1, 2).reshape(bsz, s, di)
    y = _multihead_rms(y, p["norm_scale"], h)
    y = y * jax.nn.silu(z)
    out = y @ p["w_down"]
    out = rules.shard(out, ("batch", None, "embed"))
    cache = None
    if make_cache:
        # recurrent state: C (B,H,P,P), n (B,H,P), m (B,H)
        cache = {"conv": conv_state,
                 "C": jnp.zeros((bsz, h, pd, pd), jnp.float32),
                 "n": jnp.zeros((bsz, h, pd), jnp.float32),
                 "m": jnp.full((bsz, h), -1e30, jnp.float32)}
        # note: prefill-to-decode state handoff recomputes the final state
        # recurrently in serve paths; the parallel form here is train-only.
    return out, cache


def mlstm_decode(p: Params, x: jnp.ndarray, cache, cfg: XLSTMConfig,
                 rules: ShardingRules):
    """O(1) recurrent mLSTM step (xLSTM eq. 19-27)."""
    bsz = x.shape[0]
    h, pd = cfg.n_heads, cfg.head_dim
    xz = x @ p["w_up"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"],
                                  state=cache["conv"])
    q = (xc @ p["wq"]).reshape(bsz, h, pd).astype(jnp.float32)
    k = ((xc @ p["wk"]).reshape(bsz, h, pd) / math.sqrt(pd)).astype(jnp.float32)
    v = (xi @ p["wv"]).reshape(bsz, h, pd).astype(jnp.float32)
    gates = xc[:, 0].astype(jnp.float32) @ p["w_if"] + p["b_if"]
    ig, fg = jnp.split(gates, 2, axis=-1)                      # (B,H)
    logf = jax.nn.log_sigmoid(fg)

    m_new = jnp.maximum(logf + cache["m"], ig)                 # (B,H)
    fw = jnp.exp(logf + cache["m"] - m_new)[..., None]
    iw = jnp.exp(ig - m_new)[..., None]
    C = cache["C"] * fw[..., None] + iw[..., None] * v[..., :, None] * k[..., None, :]
    n = cache["n"] * fw + iw * k
    num = jnp.einsum("bhpq,bhq->bhp", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q)),
                      jnp.exp(-m_new))[..., None]
    yh = (num / den).reshape(bsz, 1, cfg.d_inner).astype(x.dtype)
    y = _multihead_rms(yh, p["norm_scale"], h)
    y = y * jax.nn.silu(z)
    return y @ p["w_down"], {"conv": conv_state, "C": C, "n": n, "m": m_new}


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def init_slstm(key, cfg: XLSTMConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    # 4 gates (i, f, z, o), each d -> d input proj + per-head recurrent
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), 0, dtype),
        "r_heads": (jax.random.normal(ks[1], (4, h, hd, hd))
                    / math.sqrt(hd)).astype(jnp.float32),
        "bias": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                                 jnp.zeros((2 * d,))]).astype(jnp.float32),
        "norm_scale": jnp.zeros((d,), dtype),
        # post-recurrence gated FFN (proj factor 4/3, GeLU)
        "w_ffn_up": dense_init(ks[2], (d, 2 * int(4 * d / 3)), 0, dtype),
        "w_ffn_down": dense_init(ks[3], (int(4 * d / 3), d), 0, dtype),
    }


SLSTM_AXES = {
    "w_in": ("embed", "inner"), "r_heads": (None, None, None, None),
    "bias": (None,), "norm_scale": (None,),
    "w_ffn_up": ("embed", "mlp"), "w_ffn_down": ("mlp", "embed"),
}


def _slstm_scan(gates_seq, r_heads, bias, h, hd, state):
    """Sequential sLSTM recurrence.  gates_seq: (S, B, 4D); state: dict of
    (B, D) [c, n, m, y]."""

    def step(carry, g_t):
        c, n, m, y = carry
        bsz = y.shape[0]
        yh = y.reshape(bsz, h, hd)
        # recurrent contribution per gate from the block-diagonal R
        rec = jnp.einsum("ghpq,bhq->gbhp", r_heads, yh).reshape(4, bsz, h * hd)
        z_in = g_t.astype(jnp.float32) + bias[None] \
            + jnp.concatenate([rec[0], rec[1], rec[2], rec[3]], axis=-1)
        d = h * hd
        ig, fg, zg, og = (z_in[:, :d], z_in[:, d:2 * d],
                          z_in[:, 2 * d:3 * d], z_in[:, 3 * d:])
        logf = jax.nn.log_sigmoid(fg)
        m_new = jnp.maximum(logf + m, ig)
        i_st = jnp.exp(ig - m_new)
        f_st = jnp.exp(logf + m - m_new)
        c_new = f_st * c + i_st * jnp.tanh(zg)
        n_new = f_st * n + i_st
        y_new = jax.nn.sigmoid(og) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, y_new), y_new

    (c, n, m, y), ys = jax.lax.scan(
        step, (state["c"], state["n"], state["m"], state["y"]), gates_seq)
    return ys, {"c": c, "n": n, "m": m, "y": y}


def _slstm_zero_state(bsz, d):
    return {"c": jnp.zeros((bsz, d), jnp.float32),
            "n": jnp.zeros((bsz, d), jnp.float32),
            "m": jnp.full((bsz, d), -1e30, jnp.float32),
            "y": jnp.zeros((bsz, d), jnp.float32)}


def slstm_fwd(p: Params, x: jnp.ndarray, cfg: XLSTMConfig,
              rules: ShardingRules, make_cache: bool = False):
    bsz, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    gates = (x @ p["w_in"]).swapaxes(0, 1)                     # (S, B, 4D)
    ys, state = _slstm_scan(gates, p["r_heads"], p["bias"], h, hd,
                            _slstm_zero_state(bsz, d))
    y = ys.swapaxes(0, 1).astype(x.dtype)                      # (B, S, D)
    y = _multihead_rms(y, p["norm_scale"], h)
    # gated FFN (GeLU, pf 4/3)
    u, g = jnp.split(y @ p["w_ffn_up"], 2, axis=-1)
    out = (jax.nn.gelu(u) * g) @ p["w_ffn_down"]
    out = rules.shard(out, ("batch", None, "embed"))
    return out, (state if make_cache else None)


def slstm_decode(p: Params, x: jnp.ndarray, cache, cfg: XLSTMConfig,
                 rules: ShardingRules):
    bsz, _, d = x.shape
    h = cfg.n_heads
    hd = d // h
    gates = (x @ p["w_in"]).swapaxes(0, 1)                     # (1, B, 4D)
    ys, state = _slstm_scan(gates, p["r_heads"], p["bias"], h, hd, cache)
    y = ys.swapaxes(0, 1).astype(x.dtype)
    y = _multihead_rms(y, p["norm_scale"], h)
    u, g = jnp.split(y @ p["w_ffn_up"], 2, axis=-1)
    return (jax.nn.gelu(u) * g) @ p["w_ffn_down"], state
