"""Observability: process-wide tracing, fleet event log, exporters.

See :mod:`repro.obs.trace` for the span recorder and the Chrome-trace /
Prometheus exporters, :mod:`repro.obs.events` for the fleet event
taxonomy, and ``docs/observability.md`` for the user guide.
"""

from .events import FLEET_EVENT_KINDS, fleet_event, fleet_event_log
from .trace import (PHASE_CATEGORIES, InstantEvent, Span, SpanHandle,
                    Tracer, begin, chrome_trace, context, enabled, end,
                    event, get_tracer, incr, prometheus_snapshot,
                    set_tracer, span, write_chrome_trace)

__all__ = [
    "FLEET_EVENT_KINDS", "fleet_event", "fleet_event_log",
    "PHASE_CATEGORIES", "InstantEvent", "Span", "SpanHandle", "Tracer",
    "begin", "chrome_trace", "context", "enabled", "end", "event",
    "get_tracer", "incr", "prometheus_snapshot", "set_tracer", "span",
    "write_chrome_trace",
]
