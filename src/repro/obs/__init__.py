"""Observability: process-wide tracing, fleet event log, exporters.

See :mod:`repro.obs.trace` for the span recorder and the Chrome-trace /
Prometheus exporters, :mod:`repro.obs.events` for the fleet event
taxonomy, :mod:`repro.obs.calibration` for the modeled-vs-measured
calibration ledger and memory-margin gauges, :mod:`repro.obs.slo` for
deadline-attainment accounting, :mod:`repro.obs.http` for the live
metrics endpoint, and ``docs/observability.md`` for the user guide.
"""

from .calibration import (CAL_EVENT_KINDS, CalibrationKey,
                          CalibrationLedger, CalibrationStat, MemoryMargin,
                          calibration_prometheus, memory_calibration)
from .events import FLEET_EVENT_KINDS, fleet_event, fleet_event_log
from .http import MetricsServer, metrics_text
from .slo import SLOTier, slo_prometheus, slo_report
from .trace import (PHASE_CATEGORIES, InstantEvent, Span, SpanHandle,
                    Tracer, begin, chrome_trace, context, enabled, end,
                    event, get_tracer, incr, prometheus_snapshot,
                    set_tracer, span, write_chrome_trace)

__all__ = [
    "CAL_EVENT_KINDS", "CalibrationKey", "CalibrationLedger",
    "CalibrationStat", "MemoryMargin", "calibration_prometheus",
    "memory_calibration", "MetricsServer", "metrics_text",
    "SLOTier", "slo_prometheus", "slo_report",
    "FLEET_EVENT_KINDS", "fleet_event", "fleet_event_log",
    "PHASE_CATEGORIES", "InstantEvent", "Span", "SpanHandle", "Tracer",
    "begin", "chrome_trace", "context", "enabled", "end", "event",
    "get_tracer", "incr", "prometheus_snapshot", "set_tracer", "span",
    "write_chrome_trace",
]
