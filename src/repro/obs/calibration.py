"""Calibration ledger: are the serving cost models telling the truth?

Every scheduling decision — makespan routing, deadline admission,
steal/migrate benefit checks, predictive scale-up — rides on modeled
seconds (step/init EMAs, :meth:`~repro.core.plan.CommSchedule.
transfer_seconds`) and modeled bytes (:class:`~repro.core.plan.
ExecutionPlan` footprints).  The fleet event log
(:mod:`repro.obs.events`) already records the modeled and measured
value side by side on each decision; this module folds that stream into
an *answer*: per ``(geometry, algorithm, backend, pod)`` and per event
kind, the signed bias (measured − modeled), absolute-error
percentiles, and an EMA-drift flag that names the pod whose cost model
has gone stale.

Memory is calibrated the same way: the staged ``bytes=`` attributes on
h2d/prefetch/d2h/reduce spans give a measured per-device high-water
mark, compared against the modeled footprint committed at placement
(``place`` events' ``bytes=``).  The ratio is exported as a
safety-margin gauge so an under-modeled footprint is visible *before*
it OOMs a real GPU.

Everything here is pure stdlib (no numpy/jax) so the obs package stays
importable anywhere, and every reader tolerates a half-written stream:
events missing one side of the comparison still count as observed.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

from .events import fleet_event_log
from .trace import InstantEvent, Tracer, get_tracer

__all__ = [
    "CAL_EVENT_KINDS", "CalibrationKey", "CalibrationStat",
    "CalibrationLedger", "MemoryMargin", "memory_calibration",
    "calibration_prometheus",
]

#: Event kinds the ledger folds.  ``admit``/``step`` carry both sides of
#: the comparison; ``complete``/``reject``/``migrate``/``scale-up`` carry
#: one side (or none) and contribute observation counts + totals only.
CAL_EVENT_KINDS = ("admit", "step", "complete", "reject", "migrate",
                   "scale-up")

#: Span categories whose ``bytes=`` attrs are device-resident staging
#: traffic (the measured side of memory calibration).
_STAGING_CATS = ("h2d", "prefetch", "d2h", "reduce")


def _percentile(xs: List[float], p: float) -> float:
    """Nearest-rank percentile (p in [0, 100]); 0.0 on empty input.

    Duplicated from :mod:`repro.serve.metrics` on purpose: serve imports
    obs, so obs cannot import serve back.
    """
    if not xs:
        return 0.0
    s = sorted(xs)
    k = max(0, min(len(s) - 1, int(round(p / 100.0 * (len(s) - 1)))))
    return s[k]


@dataclasses.dataclass(frozen=True)
class CalibrationKey:
    """One cost-model population: same geometry, algorithm, backend, pod.

    Events that predate the attribute enrichment (or kinds that have no
    job identity, like ``scale-up``) group under ``"-"`` placeholders
    rather than being dropped — a stale emitter is itself a calibration
    finding.
    """
    geometry: str = "-"
    algorithm: str = "-"
    backend: str = "-"
    pod: str = "-"

    @staticmethod
    def of(ev: InstantEvent) -> "CalibrationKey":
        a = ev.attrs
        pod = a.get("pod") or a.get("dst") or a.get("src") or "-"
        return CalibrationKey(
            geometry=str(a.get("geo", "-")),
            algorithm=str(a.get("alg", "-")),
            backend=str(a.get("backend") or "-"),
            pod=str(pod))


@dataclasses.dataclass
class CalibrationStat:
    """Accumulated modeled-vs-measured evidence for one (key, kind)."""
    key: CalibrationKey
    kind: str
    events: int = 0          # every event of this kind seen for the key
    samples: int = 0         # events carrying BOTH modeled_s and measured_s
    modeled_total_s: float = 0.0
    measured_total_s: float = 0.0
    errors_s: List[float] = dataclasses.field(default_factory=list)
    drift_ema: float = 0.0   # EMA of |relative error|
    drift: bool = False

    @property
    def bias_s(self) -> float:
        """Mean signed error (measured − modeled); + means the model is
        optimistic (work costs more than priced)."""
        if not self.errors_s:
            return 0.0
        return sum(self.errors_s) / len(self.errors_s)

    def abs_error_percentile(self, p: float) -> float:
        return _percentile([abs(e) for e in self.errors_s], p)

    def as_dict(self) -> Dict:
        return {
            "geometry": self.key.geometry,
            "algorithm": self.key.algorithm,
            "backend": self.key.backend,
            "pod": self.key.pod,
            "kind": self.kind,
            "events": self.events,
            "samples": self.samples,
            "modeled_total_s": self.modeled_total_s,
            "measured_total_s": self.measured_total_s,
            "bias_s": self.bias_s,
            "abs_p50_s": self.abs_error_percentile(50),
            "abs_p95_s": self.abs_error_percentile(95),
            "abs_max_s": self.abs_error_percentile(100),
            "drift_ema": self.drift_ema,
            "drift": self.drift,
        }


class CalibrationLedger:
    """Fold the fleet event stream into per-(key, kind) calibration stats.

    ``drift`` fires on a (key, kind) when the EMA of the *relative*
    absolute error (|measured − modeled| / max(modeled, eps)) exceeds
    ``drift_threshold`` after at least ``drift_min_samples`` two-sided
    samples — and clears again once accurate samples pull the EMA back
    under the threshold, so a one-off compile hiccup does not
    permanently condemn a pod.  :meth:`stale_pods` names the pods with
    any firing flag; that is the operator-facing output.
    """

    def __init__(self, drift_threshold: float = 0.5,
                 drift_min_samples: int = 4,
                 alpha: float = 0.3) -> None:
        if not (0.0 < alpha <= 1.0):
            raise ValueError("alpha must be in (0, 1]")
        self.drift_threshold = float(drift_threshold)
        self.drift_min_samples = int(drift_min_samples)
        self.alpha = float(alpha)
        self._stats: Dict[Tuple[CalibrationKey, str], CalibrationStat] = {}

    @classmethod
    def from_events(cls, events: Optional[Iterable[InstantEvent]] = None,
                    **kwargs) -> "CalibrationLedger":
        """Build a ledger from an event iterable (default: the process
        tracer's fleet event log, in order)."""
        led = cls(**kwargs)
        if events is None:
            events = fleet_event_log()
        for ev in events:
            led.fold(ev)
        return led

    def fold(self, ev: InstantEvent) -> None:
        """Fold one fleet event; non-calibration kinds are ignored."""
        if ev.name not in CAL_EVENT_KINDS:
            return
        key = CalibrationKey.of(ev)
        st = self._stats.get((key, ev.name))
        if st is None:
            st = self._stats[(key, ev.name)] = CalibrationStat(key, ev.name)
        st.events += 1
        modeled = ev.attrs.get("modeled_s")
        measured = ev.attrs.get("measured_s")
        if isinstance(modeled, (int, float)):
            st.modeled_total_s += float(modeled)
        if isinstance(measured, (int, float)):
            st.measured_total_s += float(measured)
        if not (isinstance(modeled, (int, float))
                and isinstance(measured, (int, float))):
            return
        err = float(measured) - float(modeled)
        st.samples += 1
        st.errors_s.append(err)
        rel = abs(err) / max(abs(float(modeled)), 1e-9)
        st.drift_ema = (rel if st.samples == 1
                        else self.alpha * rel
                        + (1 - self.alpha) * st.drift_ema)
        st.drift = (st.samples >= self.drift_min_samples
                    and st.drift_ema > self.drift_threshold)

    # ---- views -------------------------------------------------------------

    def entries(self) -> List[CalibrationStat]:
        """All stats, deterministically ordered (key fields, then kind)."""
        return sorted(self._stats.values(),
                      key=lambda s: (s.key.geometry, s.key.algorithm,
                                     s.key.backend, s.key.pod, s.kind))

    def samples_by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for st in self._stats.values():
            out[st.kind] = out.get(st.kind, 0) + st.samples
        return out

    def events_by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for st in self._stats.values():
            out[st.kind] = out.get(st.kind, 0) + st.events
        return out

    def stale_pods(self) -> List[str]:
        """Pods with at least one firing drift flag (sorted, deduped)."""
        return sorted({st.key.pod for st in self._stats.values()
                       if st.drift})

    def report(self) -> Dict:
        """JSON-able calibration report (what ``recon
        --calibration-report`` and ``bench_serve --json`` embed)."""
        return {
            "entries": [st.as_dict() for st in self.entries()],
            "samples_by_kind": self.samples_by_kind(),
            "events_by_kind": self.events_by_kind(),
            "stale_pods": self.stale_pods(),
            "drift_threshold": self.drift_threshold,
        }


# ---- memory calibration ----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MemoryMargin:
    """Modeled-vs-staged bytes for one (pod, device) track.

    ``margin`` is modeled / measured: > 1 means the planner's footprint
    over-covers the observed staging high-water mark (safe); < 1 means a
    single staged transfer already exceeded the modeled footprint — the
    memory model is optimistic and a real GPU would be at OOM risk.
    """
    pod: str
    device: str
    modeled_bytes: int
    measured_bytes: int

    @property
    def margin(self) -> float:
        if self.measured_bytes <= 0:
            return float("inf")
        return self.modeled_bytes / self.measured_bytes

    def as_dict(self) -> Dict:
        m = self.margin
        return {"pod": self.pod, "device": self.device,
                "modeled_bytes": self.modeled_bytes,
                "measured_bytes": self.measured_bytes,
                "margin": (None if m == float("inf") else m)}


def memory_calibration(tracer: Optional[Tracer] = None) -> List[MemoryMargin]:
    """Per-(pod, device) memory margins from the current trace.

    Measured: the max ``bytes=`` attribute over staging-category spans on
    that device track.  Modeled: the max footprint committed there by
    ``place`` events.  Tracks with only one side known are still
    reported (modeled or measured 0) so a missing instrumentation leg is
    visible rather than silently fine.
    """
    tr = tracer if tracer is not None else get_tracer()
    measured: Dict[Tuple[str, str], int] = {}
    modeled: Dict[Tuple[str, str], int] = {}
    for sp in tr.spans():
        if sp.cat not in _STAGING_CATS:
            continue
        nbytes = sp.attrs.get("bytes")
        if not isinstance(nbytes, (int, float)):
            continue
        k = (str(sp.attrs.get("pod") or "-"),
             str(sp.attrs.get("device", "-")))
        measured[k] = max(measured.get(k, 0), int(nbytes))
    for ev in tr.events():
        if ev.name != "place":
            continue
        nbytes = ev.attrs.get("bytes")
        if not isinstance(nbytes, (int, float)):
            continue
        k = (str(ev.attrs.get("pod") or "-"),
             str(ev.attrs.get("device", "-")))
        modeled[k] = max(modeled.get(k, 0), int(nbytes))
    out = [MemoryMargin(pod, dev, modeled.get((pod, dev), 0),
                        measured.get((pod, dev), 0))
           for pod, dev in sorted(set(measured) | set(modeled))]
    return out


# ---- Prometheus exposition -------------------------------------------------


def _esc(v: object) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"')


def _labels(**kv) -> str:
    return ("{" + ",".join(f'{k}="{_esc(v)}"' for k, v in kv.items())
            + "}")


def calibration_prometheus(
        ledger: Optional[CalibrationLedger] = None,
        margins: Optional[List[MemoryMargin]] = None) -> str:
    """Prometheus text for the calibration + memory-margin families.

    Family headers are always emitted, even with zero series, so a
    scraper (and :mod:`tools.validate_trace`) can assert the families
    exist on an idle or serve-free process.
    """
    if ledger is None:
        ledger = CalibrationLedger.from_events()
    if margins is None:
        margins = memory_calibration()
    lines = [
        "# HELP repro_calibration_samples_total modeled-vs-measured "
        "samples folded per (geometry, algorithm, backend, pod, kind)",
        "# TYPE repro_calibration_samples_total counter",
    ]
    ents = ledger.entries()
    for st in ents:
        lines.append(
            "repro_calibration_samples_total"
            + _labels(geo=st.key.geometry, alg=st.key.algorithm,
                      backend=st.key.backend, pod=st.key.pod,
                      kind=st.kind)
            + f" {st.samples}")
    lines += ["# HELP repro_calibration_bias_seconds mean signed error "
              "(measured - modeled); positive = model optimistic",
              "# TYPE repro_calibration_bias_seconds gauge"]
    for st in ents:
        if st.samples:
            lines.append(
                "repro_calibration_bias_seconds"
                + _labels(geo=st.key.geometry, alg=st.key.algorithm,
                          backend=st.key.backend, pod=st.key.pod,
                          kind=st.kind)
                + f" {st.bias_s:.9g}")
    lines += ["# HELP repro_calibration_abs_p95_seconds p95 absolute "
              "modeled-vs-measured error",
              "# TYPE repro_calibration_abs_p95_seconds gauge"]
    for st in ents:
        if st.samples:
            lines.append(
                "repro_calibration_abs_p95_seconds"
                + _labels(geo=st.key.geometry, alg=st.key.algorithm,
                          backend=st.key.backend, pod=st.key.pod,
                          kind=st.kind)
                + f" {st.abs_error_percentile(95):.9g}")
    lines += ["# HELP repro_calibration_drift 1 when a pod's cost model "
              "EMA-drifted past the threshold",
              "# TYPE repro_calibration_drift gauge"]
    for pod in ledger.stale_pods():
        lines.append("repro_calibration_drift" + _labels(pod=pod) + " 1")
    lines += ["# HELP repro_memory_modeled_bytes max footprint committed "
              "at placement per (pod, device)",
              "# TYPE repro_memory_modeled_bytes gauge"]
    for m in margins:
        lines.append("repro_memory_modeled_bytes"
                     + _labels(pod=m.pod, device=m.device)
                     + f" {m.modeled_bytes}")
    lines += ["# HELP repro_memory_watermark_bytes max staged bytes "
              "observed per (pod, device)",
              "# TYPE repro_memory_watermark_bytes gauge"]
    for m in margins:
        lines.append("repro_memory_watermark_bytes"
                     + _labels(pod=m.pod, device=m.device)
                     + f" {m.measured_bytes}")
    lines += ["# HELP repro_memory_margin_ratio modeled / measured bytes; "
              "< 1 means the memory model is optimistic (OOM risk)",
              "# TYPE repro_memory_margin_ratio gauge"]
    for m in margins:
        if m.margin != float("inf"):
            lines.append("repro_memory_margin_ratio"
                         + _labels(pod=m.pod, device=m.device)
                         + f" {m.margin:.9g}")
    return "\n".join(lines) + "\n"
