"""Fleet event taxonomy: the structured log of serving-layer decisions.

Every scheduler/fleet decision lands as one :class:`~repro.obs.trace.
InstantEvent` in the process tracer, tagged with a ``kind`` from
:data:`FLEET_EVENT_KINDS` plus whatever identity is known at the call
site (``job``, ``pod``, ``device``).  Cost-model events carry both the
*modeled* seconds (what the scheduler predicted from its EMAs /
ExecutionPlan) and the *measured* seconds, so autoscale thrash, steal
ping-pong, and preemption storms can be debugged from one ordered log
instead of test output archaeology.

Kinds
-----
``submit``      job accepted into a scheduler queue
``place``       job reserved a device slot (before executor init)
``admit``       executor init finished, job RUNNING
                (``measured_s`` = init seconds, ``modeled_s`` = init EMA)
``step``        one outer iteration finished
                (``measured_s`` = wall, ``modeled_s`` = step EMA x passes)
``park``        job preempted: checkpointed + requeued
``complete``    job finished (``measured_s`` = submit-to-done latency)
``fail``        job failed (``error`` attr)
``reject``      deadline model refused the job at admission
``export``      job serialized to the transfer dir (steal/drain egress)
``import``      job adopted from the transfer dir (steal/drain ingress)
``drain``       a scheduler parked all running jobs (shutdown/steal prep)
``pod-add``     pod joined the fleet
``pod-remove``  pod left the fleet
``scale-up``    autoscaler grew the fleet  (``load`` = backlog seconds)
``scale-down``  autoscaler shrank the fleet
``snapshot``    durable scheduler snapshot written
``live-snapshot`` a *running* job's committed step state persisted
                without parking it (``it`` = the committed iteration)
``migrate``     a running job preempted at its step boundary and moved
                live to another pod (``src``/``dst`` pods)
"""

from __future__ import annotations

from typing import List, Optional

from .trace import InstantEvent, event, get_tracer

__all__ = ["FLEET_EVENT_KINDS", "fleet_event", "fleet_event_log"]

FLEET_EVENT_KINDS = (
    "submit", "place", "admit", "step", "park", "complete", "fail",
    "reject", "export", "import", "drain", "pod-add", "pod-remove",
    "scale-up", "scale-down", "snapshot", "live-snapshot", "migrate",
)


def fleet_event(kind: str, **attrs) -> None:
    """Record one fleet event (no-op when tracing is disabled).

    ``kind`` must come from :data:`FLEET_EVENT_KINDS` — an unknown kind
    raises immediately so call sites cannot silently fork the taxonomy.
    """
    if kind not in FLEET_EVENT_KINDS:
        raise ValueError(f"unknown fleet event kind: {kind!r}")
    event(kind, **attrs)


def fleet_event_log(job: Optional[str] = None, kind: Optional[str] = None,
                    pod: Optional[str] = None) -> List[InstantEvent]:
    """The recorded fleet events, in order, optionally filtered."""
    out = [e for e in get_tracer().events()
           if e.name in FLEET_EVENT_KINDS]
    if kind is not None:
        out = [e for e in out if e.name == kind]
    if job is not None:
        out = [e for e in out if e.attrs.get("job") == job]
    if pod is not None:
        out = [e for e in out if e.attrs.get("pod") == pod]
    return out
