"""Live metrics endpoint: scrape a running fleet instead of autopsying it.

A stdlib-only (``http.server``) threaded HTTP server exposing, at
``GET /metrics``, the full Prometheus text snapshot: the tracer's span /
event / counter families (:func:`repro.obs.trace.prometheus_snapshot`)
plus the calibration, memory-margin (:mod:`repro.obs.calibration`) and
SLO (:mod:`repro.obs.slo`) families derived live from the same ring
buffer.  ``recon --metrics-port N`` starts one around a reconstruction;
a serving process (:class:`~repro.serve.driver.MultiPodDriver`) can hold
one for its whole lifetime — every request re-reads the tracer, so the
scrape always reflects the current ring buffer.

The server binds ``127.0.0.1`` by default and port 0 picks a free port
(the bound port is returned by :meth:`MetricsServer.start` — handy for
tests).  Request handling runs on daemon threads; :meth:`stop` shuts the
listener down and joins the serve thread.
"""

from __future__ import annotations

import http.server
import threading
from typing import Optional

from .calibration import CalibrationLedger, calibration_prometheus, \
    memory_calibration
from .slo import slo_prometheus
from .trace import prometheus_snapshot

__all__ = ["MetricsServer", "metrics_text"]


def metrics_text() -> str:
    """The full Prometheus exposition: tracer + calibration + SLO
    families, rebuilt from the live tracer on every call."""
    return (prometheus_snapshot()
            + calibration_prometheus(CalibrationLedger.from_events(),
                                     memory_calibration())
            + slo_prometheus())


class _Handler(http.server.BaseHTTPRequestHandler):
    # quiet: scrapes every few seconds would otherwise spam stderr
    def log_message(self, fmt, *args):  # noqa: A003 - stdlib signature
        pass

    def do_GET(self):  # noqa: N802 - stdlib naming
        if self.path.split("?", 1)[0] not in ("/", "/metrics"):
            self.send_error(404, "try /metrics")
            return
        try:
            body = metrics_text().encode("utf-8")
        except Exception as e:   # a scrape must never kill the server
            self.send_error(500, f"metrics snapshot failed: {e!r}")
            return
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)


class MetricsServer:
    """Threaded live metrics endpoint; usable as a context manager.

    >>> from repro.obs.http import MetricsServer
    >>> srv = MetricsServer(port=0)
    >>> port = srv.start()
    >>> port > 0
    True
    >>> srv.stop()
    """

    def __init__(self, port: int = 0, host: str = "127.0.0.1") -> None:
        self.host = host
        self.port = port
        self._httpd: Optional[http.server.ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    def start(self) -> int:
        """Bind and serve in a daemon thread; returns the bound port."""
        if self._httpd is not None:
            return self.port
        self._httpd = http.server.ThreadingHTTPServer(
            (self.host, self.port), _Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="repro-metrics",
                                        daemon=True)
        self._thread.start()
        return self.port

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def stop(self) -> None:
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._httpd, self._thread = None, None

    def __enter__(self) -> "MetricsServer":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False
