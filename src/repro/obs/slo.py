"""SLO accounting: deadline attainment and latency percentiles per tier.

The serving layer's promise to a tenant is its ``deadline_seconds`` and
its priority tier; this module turns the fleet event log into the
operator's view of whether that promise held.  For each priority level
it reports

* **deadline attainment**: of the jobs that declared a deadline, the
  fraction that completed inside it — rejects (the model refused the
  job at admission) and late completions both count against it;
* **queue-wait** and **end-to-end latency** percentiles over completed
  jobs (the ``complete`` event carries both measurements directly).

All inputs come from the structured event stream
(:func:`repro.obs.events.fleet_event_log`), so the report can be built
post-mortem from any traced run, or live by the metrics endpoint
(:mod:`repro.obs.http`).  Pure stdlib, like the rest of ``obs``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

from .events import fleet_event_log
from .trace import InstantEvent

__all__ = ["SLOTier", "slo_report", "slo_prometheus"]


def _percentile(xs: List[float], p: float) -> float:
    """Nearest-rank percentile; 0.0 on empty input (serve's convention,
    re-implemented here because obs cannot import serve)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = max(0, min(len(s) - 1, int(round(p / 100.0 * (len(s) - 1)))))
    return s[k]


@dataclasses.dataclass
class SLOTier:
    """Accumulated outcomes for one priority level."""
    priority: int
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    rejected: int = 0          # deadline admission refused the job
    deadline_jobs: int = 0     # jobs that declared a deadline
    deadline_met: int = 0
    deadline_missed: int = 0   # completed, but late (+ rejects, separately)
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    queue_waits_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def attainment(self) -> float:
        """Met deadlines / declared deadlines; 1.0 when no job declared
        one (an SLO nobody asked for is trivially held)."""
        if self.deadline_jobs == 0:
            return 1.0
        return self.deadline_met / self.deadline_jobs

    def as_dict(self) -> Dict:
        return {
            "priority": self.priority,
            "submitted": self.submitted,
            "completed": self.completed,
            "failed": self.failed,
            "rejected": self.rejected,
            "deadline_jobs": self.deadline_jobs,
            "deadline_met": self.deadline_met,
            "deadline_missed": self.deadline_missed,
            "attainment": self.attainment,
            "latency_p50_s": _percentile(self.latencies_s, 50),
            "latency_p95_s": _percentile(self.latencies_s, 95),
            "queue_wait_p50_s": _percentile(self.queue_waits_s, 50),
            "queue_wait_p95_s": _percentile(self.queue_waits_s, 95),
        }


def _tier(tiers: Dict[int, SLOTier], priority: int) -> SLOTier:
    t = tiers.get(priority)
    if t is None:
        t = tiers[priority] = SLOTier(priority)
    return t


def slo_report(events: Optional[Iterable[InstantEvent]] = None) -> Dict:
    """Fold the fleet event log into per-priority SLO outcomes.

    ``complete`` events carry ``measured_s`` (end-to-end latency),
    ``queue_wait_s``, ``deadline_s`` and ``priority`` directly;
    ``reject`` carries ``priority`` and ``deadline_s``.  Jobs whose
    events predate those attributes join through the ``submit`` event's
    ``priority`` and otherwise land in tier 0 — a half-instrumented
    stream degrades to coarser tiers, never to a crash.
    """
    if events is None:
        events = fleet_event_log()
    prio_of: Dict[str, int] = {}
    tiers: Dict[int, SLOTier] = {}
    for ev in events:
        a = ev.attrs
        job = a.get("job")
        if ev.name == "submit":
            p = int(a.get("priority", 0) or 0)
            if job:
                prio_of[job] = p
            _tier(tiers, p).submitted += 1
            continue
        if ev.name not in ("complete", "fail", "reject"):
            continue
        p = a.get("priority")
        if p is None:
            p = prio_of.get(job, 0)
        t = _tier(tiers, int(p))
        if ev.name == "fail":
            t.failed += 1
            continue
        deadline = a.get("deadline_s") or 0.0
        if ev.name == "reject":
            t.rejected += 1
            if deadline > 0:
                t.deadline_jobs += 1
                t.deadline_missed += 1
            continue
        t.completed += 1
        latency = a.get("measured_s")
        if isinstance(latency, (int, float)):
            t.latencies_s.append(float(latency))
        qw = a.get("queue_wait_s")
        if isinstance(qw, (int, float)):
            t.queue_waits_s.append(float(qw))
        if deadline > 0:
            t.deadline_jobs += 1
            if isinstance(latency, (int, float)) and latency <= deadline:
                t.deadline_met += 1
            else:
                t.deadline_missed += 1
    ordered = [tiers[p] for p in sorted(tiers)]
    total_decl = sum(t.deadline_jobs for t in ordered)
    total_met = sum(t.deadline_met for t in ordered)
    return {
        "tiers": [t.as_dict() for t in ordered],
        "overall_attainment": (total_met / total_decl if total_decl
                               else 1.0),
        "deadline_jobs": total_decl,
    }


def slo_prometheus(report: Optional[Dict] = None) -> str:
    """Prometheus text for the SLO families; headers always emitted."""
    if report is None:
        report = slo_report()
    lines = ["# HELP repro_slo_attainment_ratio met deadlines / declared "
             "deadlines per priority tier",
             "# TYPE repro_slo_attainment_ratio gauge"]
    tiers = report.get("tiers", [])
    for t in tiers:
        lines.append(f'repro_slo_attainment_ratio{{priority="'
                     f'{t["priority"]}"}} {t["attainment"]:.9g}')
    lines += ["# HELP repro_slo_latency_p95_seconds end-to-end latency "
              "p95 per priority tier",
              "# TYPE repro_slo_latency_p95_seconds gauge"]
    for t in tiers:
        lines.append(f'repro_slo_latency_p95_seconds{{priority="'
                     f'{t["priority"]}"}} {t["latency_p95_s"]:.9g}')
    lines += ["# HELP repro_slo_queue_wait_p95_seconds queue wait p95 "
              "per priority tier",
              "# TYPE repro_slo_queue_wait_p95_seconds gauge"]
    for t in tiers:
        lines.append(f'repro_slo_queue_wait_p95_seconds{{priority="'
                     f'{t["priority"]}"}} {t["queue_wait_p95_s"]:.9g}')
    lines += ["# HELP repro_slo_completed_total completed jobs per "
              "priority tier",
              "# TYPE repro_slo_completed_total counter"]
    for t in tiers:
        lines.append(f'repro_slo_completed_total{{priority="'
                     f'{t["priority"]}"}} {t["completed"]}')
    return "\n".join(lines) + "\n"
