"""Process-wide, thread-safe span/event tracing (paper Fig 3/5 timelines).

The paper's evidence for the multi-GPU streaming design is a per-GPU
timeline attributing wall time to H2D staging, kernel compute, and D2H
copy-back.  This module is the measurement side of that argument: a
lock-cheap span recorder whose output reproduces those timelines from a
*real* run, exported either as Chrome trace-event JSON (loadable in
Perfetto / ``chrome://tracing``, one track per device per pod) or as a
Prometheus-style text snapshot of the aggregated phase counters.

Design rules
------------
* **Zero cost when disabled.**  The module-level helpers (:func:`span`,
  :func:`event`, :func:`context`, :func:`begin`) check a single attribute
  and return a shared no-op object; no allocation, no lock, no clock read.
* **Lock-cheap when enabled.**  A span takes two ``time.monotonic()``
  reads and one short critical section appending to a bounded ring buffer
  (``deque(maxlen=...)``) and bumping the aggregate counters.
* **Monotonic clocks.**  All timestamps are ``time.monotonic()`` seconds;
  exports rebase to the earliest record so traces start near zero.
* **Cross-thread spans.**  ``h = begin("init", job=...)`` on one thread,
  ``end(h)`` on another; the span is attributed to the opening thread.
* **Ambient context.**  ``with context(job="job-3", pod="p0"): ...``
  merges attributes into every span/event opened on that thread, which is
  how streaming-loop spans acquire their job/pod identity without
  plumbing labels through every call signature.

Everything here is pure stdlib -- the package must stay importable
without jax so exporters can run anywhere (CI validators, notebooks).
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

__all__ = [
    "Span", "InstantEvent", "SpanHandle", "Tracer", "get_tracer",
    "set_tracer", "span", "event", "begin", "end", "context", "incr",
    "enabled", "chrome_trace", "write_chrome_trace", "prometheus_snapshot",
]

# Phase categories folded into ``phase_seconds`` accounting; spans with
# other categories are still recorded and exported, these are just the
# ones ServeMetrics surfaces (ISSUE 6 / paper Fig 9 bins + compile).
# "prefetch" is CommSchedule lookahead staging (h2d issued ahead of the
# consuming compute; carries a bytes= attr so Perfetto shows effective
# bandwidth per transfer) and "reduce" the cross-shard combine of the
# dominance-split dist FP (ISSUE 7).
PHASE_CATEGORIES = ("h2d", "compute", "d2h", "compile", "plan",
                    "prefetch", "reduce")


def _jsonable(v: Any) -> Any:
    """Coerce attr values for JSON export (numpy scalars -> python)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    item = getattr(v, "item", None)
    if item is not None:
        try:
            return item()
        except (TypeError, ValueError):
            pass
    return str(v)


@dataclasses.dataclass(frozen=True)
class Span:
    """One closed interval: ``[t0, t1]`` monotonic seconds."""
    name: str
    cat: str
    t0: float
    t1: float
    thread: int
    seq: int
    attrs: Dict[str, Any]

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass(frozen=True)
class InstantEvent:
    """One point event (the fleet event log's record type)."""
    name: str
    t: float
    thread: int
    seq: int
    attrs: Dict[str, Any]


class SpanHandle:
    """Open span returned by :meth:`Tracer.begin` (close with ``end``)."""
    __slots__ = ("name", "cat", "t0", "thread", "attrs", "_gen")

    def __init__(self, name: str, cat: str, t0: float, thread: int,
                 attrs: Dict[str, Any], gen: int):
        self.name, self.cat, self.t0 = name, cat, t0
        self.thread, self.attrs, self._gen = thread, attrs, gen


class _NullSpan:
    """Shared no-op context manager: the disabled-tracer fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _SpanCtx:
    """Live span context manager (only built when tracing is enabled)."""
    __slots__ = ("_tracer", "_name", "_cat", "_attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self._name, self._cat, self._attrs = name, cat, attrs

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self._tracer._finish_span(self._name, self._cat, self._t0,
                                  time.monotonic(), threading.get_ident(),
                                  self._attrs)
        return False


class _CtxMgr:
    """Pushes ambient attrs onto the thread's context for its duration."""
    __slots__ = ("_tracer", "_attrs", "_saved")

    def __init__(self, tracer: "Tracer", attrs: Dict[str, Any]):
        self._tracer = tracer
        self._attrs = attrs

    def __enter__(self):
        tls = self._tracer._tls_state()
        self._saved = tls.ctx
        tls.ctx = {**tls.ctx, **self._attrs}
        return self

    def __exit__(self, *exc):
        self._tracer._tls_state().ctx = self._saved
        return False


class Tracer:
    """Bounded, thread-safe recorder of spans + instant events.

    ``capacity`` bounds the ring buffer; aggregate counters
    (``phase_seconds``, span/event counts) keep running even after old
    records have been evicted, so the Prometheus snapshot stays honest on
    long runs.
    """

    def __init__(self, capacity: int = 1 << 16,
                 enabled: Optional[bool] = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_TRACE", "") not in ("", "0")
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._records: deque = deque(maxlen=self.capacity)
        self._seq = itertools.count()
        self._gen = 0                   # bumped by clear(): orphans handles
        self._phase: Dict[str, float] = {}
        self._span_counts: Dict[str, int] = {}
        self._event_counts: Dict[str, int] = {}
        self._counters: Dict[str, int] = {}
        self._total_records = 0
        self._tls = threading.local()

    # -- lifecycle ---------------------------------------------------------

    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        """Drop all records and counters (open handles become no-ops)."""
        with self._lock:
            self._records.clear()
            self._phase.clear()
            self._span_counts.clear()
            self._event_counts.clear()
            self._counters.clear()
            self._total_records = 0
            self._gen += 1
        # thread-local phase totals are reset lazily per thread
        tls = self._tls_state()
        tls.phase = {}

    def _tls_state(self):
        tls = self._tls
        if not hasattr(tls, "ctx"):
            tls.ctx = {}
            tls.phase = {}
        return tls

    def _merged_attrs(self, attrs: Dict[str, Any]) -> Dict[str, Any]:
        ctx = self._tls_state().ctx
        if ctx:
            merged = dict(ctx)
            merged.update(attrs)
            return merged
        return attrs

    # -- recording ---------------------------------------------------------

    def span(self, name: str, cat: Optional[str] = None,
             **attrs) -> Union[_SpanCtx, _NullSpan]:
        if not self.enabled:
            return _NULL
        return _SpanCtx(self, name, cat or name, self._merged_attrs(attrs))

    def begin(self, name: str, cat: Optional[str] = None,
              **attrs) -> Optional[SpanHandle]:
        if not self.enabled:
            return None
        return SpanHandle(name, cat or name, time.monotonic(),
                          threading.get_ident(), self._merged_attrs(attrs),
                          self._gen)

    def end(self, handle: Optional[SpanHandle], **attrs) -> None:
        if handle is None or not self.enabled or handle._gen != self._gen:
            return
        merged = handle.attrs if not attrs else {**handle.attrs, **attrs}
        self._finish_span(handle.name, handle.cat, handle.t0,
                          time.monotonic(), handle.thread, merged)

    def _finish_span(self, name: str, cat: str, t0: float, t1: float,
                     thread: int, attrs: Dict[str, Any]) -> None:
        if not self.enabled:
            return
        dur = t1 - t0
        with self._lock:
            seq = next(self._seq)
            self._records.append(Span(name, cat, t0, t1, thread, seq, attrs))
            self._total_records += 1
            self._phase[cat] = self._phase.get(cat, 0.0) + dur
            self._span_counts[cat] = self._span_counts.get(cat, 0) + 1
        phase = self._tls_state().phase
        phase[cat] = phase.get(cat, 0.0) + dur

    def event(self, name: str, **attrs) -> None:
        if not self.enabled:
            return
        merged = self._merged_attrs(attrs)
        with self._lock:
            seq = next(self._seq)
            self._records.append(InstantEvent(name, time.monotonic(),
                                              threading.get_ident(), seq,
                                              merged))
            self._total_records += 1
            self._event_counts[name] = self._event_counts.get(name, 0) + 1

    def incr(self, name: str, n: int = 1) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def context(self, **attrs) -> Union[_CtxMgr, _NullSpan]:
        if not self.enabled:
            return _NULL
        return _CtxMgr(self, attrs)

    # -- accessors ---------------------------------------------------------

    def records(self) -> List[Union[Span, InstantEvent]]:
        with self._lock:
            return list(self._records)

    def spans(self, cat: Optional[str] = None,
              name: Optional[str] = None) -> List[Span]:
        out = [r for r in self.records() if isinstance(r, Span)]
        if cat is not None:
            out = [s for s in out if s.cat == cat]
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def events(self, kind: Optional[str] = None,
               job: Optional[str] = None) -> List[InstantEvent]:
        out = [r for r in self.records() if isinstance(r, InstantEvent)]
        if kind is not None:
            out = [e for e in out if e.name == kind]
        if job is not None:
            out = [e for e in out if e.attrs.get("job") == job]
        return out

    def phase_seconds(self) -> Dict[str, float]:
        """Aggregate seconds per span category since the last clear()."""
        with self._lock:
            return dict(self._phase)

    def thread_phase_seconds(self) -> Dict[str, float]:
        """Per-category seconds accumulated by the *calling thread* only
        (used by the executor to attribute phases to one job's step)."""
        return dict(self._tls_state().phase)

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    def dropped(self) -> int:
        """Records evicted by the ring buffer since the last clear()."""
        with self._lock:
            return self._total_records - len(self._records)

    # -- exporters ---------------------------------------------------------

    def chrome_trace(self, records: Optional[Sequence] = None) -> dict:
        return chrome_trace(self.records() if records is None else records)

    def write_chrome_trace(self, path: str,
                           records: Optional[Sequence] = None) -> None:
        trace = self.chrome_trace(records)
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(trace, fh)

    def prometheus(self) -> str:
        with self._lock:
            phase = dict(self._phase)
            span_counts = dict(self._span_counts)
            event_counts = dict(self._event_counts)
            counters = dict(self._counters)
            dropped = self._total_records - len(self._records)
        lines = [
            "# HELP repro_phase_seconds_total wall seconds per span category",
            "# TYPE repro_phase_seconds_total counter",
        ]
        for k in sorted(phase):
            lines.append(f'repro_phase_seconds_total{{phase="{k}"}} '
                         f"{phase[k]:.9f}")
        lines += ["# HELP repro_spans_total closed spans per category",
                  "# TYPE repro_spans_total counter"]
        for k in sorted(span_counts):
            lines.append(f'repro_spans_total{{cat="{k}"}} {span_counts[k]}')
        lines += ["# HELP repro_events_total fleet events per kind",
                  "# TYPE repro_events_total counter"]
        for k in sorted(event_counts):
            lines.append(f'repro_events_total{{kind="{k}"}} '
                         f"{event_counts[k]}")
        for k in sorted(counters):
            lines += [f"# HELP repro_{k}_total incr() counter {k!r}",
                      f"# TYPE repro_{k}_total counter",
                      f"repro_{k}_total {counters[k]}"]
        lines += ["# HELP repro_trace_dropped_records ring-buffer evictions",
                  "# TYPE repro_trace_dropped_records gauge",
                  f"repro_trace_dropped_records {dropped}"]
        return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------
# Chrome trace-event export (module-level so it works on raw record lists)
# --------------------------------------------------------------------------

def _track_of(rec) -> tuple:
    """(process label, thread label) for one record -> Perfetto track."""
    pod = rec.attrs.get("pod")
    proc = str(pod) if pod not in (None, "") else "proc"
    dev = rec.attrs.get("device")
    if dev is not None:
        return proc, f"device{dev}"
    return proc, f"thread-{rec.thread}"


def chrome_trace(records: Iterable[Union[Span, InstantEvent]]) -> dict:
    """Records -> Chrome trace-event JSON dict (Perfetto-loadable).

    One *process* per pod, one *thread* track per device (falling back to
    the OS thread for unattributed records): loading the file into
    ui.perfetto.dev reproduces the paper's Fig 3/5 per-GPU timelines.
    """
    recs = sorted(records, key=lambda r: r.seq)
    base = min((r.t0 if isinstance(r, Span) else r.t for r in recs),
               default=0.0)
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    events: List[dict] = []
    meta: List[dict] = []
    for r in recs:
        proc, track = _track_of(r)
        if proc not in pids:
            pids[proc] = len(pids) + 1
            meta.append({"name": "process_name", "ph": "M",
                         "pid": pids[proc], "tid": 0,
                         "args": {"name": proc}})
        pid = pids[proc]
        tkey = (pid, track)
        if tkey not in tids:
            tids[tkey] = len(tids) + 1
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": tids[tkey], "args": {"name": track}})
        tid = tids[tkey]
        args = {k: _jsonable(v) for k, v in r.attrs.items()}
        if isinstance(r, Span):
            events.append({"name": r.name, "cat": r.cat, "ph": "X",
                           "ts": (r.t0 - base) * 1e6,
                           "dur": r.duration * 1e6,
                           "pid": pid, "tid": tid, "args": args})
        else:
            events.append({"name": r.name, "cat": "event", "ph": "i",
                           "ts": (r.t - base) * 1e6, "s": "t",
                           "pid": pid, "tid": tid, "args": args})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


# --------------------------------------------------------------------------
# module-level API over the process-wide tracer
# --------------------------------------------------------------------------

_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-wide tracer (tests); returns the previous one."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


def enabled() -> bool:
    return _TRACER.enabled


def span(name: str, cat: Optional[str] = None, **attrs):
    t = _TRACER
    if not t.enabled:
        return _NULL
    return t.span(name, cat, **attrs)


def event(name: str, **attrs) -> None:
    t = _TRACER
    if t.enabled:
        t.event(name, **attrs)


def begin(name: str, cat: Optional[str] = None, **attrs):
    t = _TRACER
    if not t.enabled:
        return None
    return t.begin(name, cat, **attrs)


def end(handle, **attrs) -> None:
    t = _TRACER
    if t.enabled:
        t.end(handle, **attrs)


def context(**attrs):
    t = _TRACER
    if not t.enabled:
        return _NULL
    return t.context(**attrs)


def incr(name: str, n: int = 1) -> None:
    t = _TRACER
    if t.enabled:
        t.incr(name, n)


def write_chrome_trace(path: str) -> None:
    _TRACER.write_chrome_trace(path)


def prometheus_snapshot() -> str:
    return _TRACER.prometheus()
