"""Optimizer substrate: AdamW with schedules, global-norm clipping, ZeRO-1
optimizer-state sharding, and int8 error-feedback gradient compression."""

from .adamw import (AdamWConfig, adamw_init, adamw_update, global_norm,
                    clip_by_global_norm)
from .schedules import cosine_schedule, linear_warmup
from .compression import (compress_int8, decompress_int8,
                          make_error_feedback_state, ef_compress_update)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_warmup",
           "compress_int8", "decompress_int8", "make_error_feedback_state",
           "ef_compress_update"]
