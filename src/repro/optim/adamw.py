"""AdamW in pure JAX pytrees (no optax dependency in this container).

State: fp32 first/second moments (+ step counter).  With ``zero1=True`` the
moment trees carry a ``zero1_spec`` that additionally shards them over the
``data`` axis on the largest divisible dimension -- ZeRO-1: every data-
parallel rank keeps 1/N of the optimizer state, at the cost of an
all-gather of the updated params (GSPMD inserts it from the output
sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params):
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), gn


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr: Optional[jnp.ndarray] = None):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        pp, mm, vv = upd(p, g, m, v)
        new_p.append(pp); new_m.append(mm); new_v.append(vv)
    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "step": step},
            {"grad_norm": gn})


def zero1_spec(param_spec, shape, data_axes=("data",), mesh=None):
    """Extend a param PartitionSpec to shard optimizer moments over the
    data axis on the first dimension that is (a) unsharded and (b)
    divisible by the data-axis size (ZeRO-1).  Falls back to the param
    spec when no dimension qualifies."""
    from jax.sharding import PartitionSpec as P
    if mesh is None:
        return param_spec
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % dsize == 0:
            entries[i] = data_axes if len(data_axes) > 1 else data_axes[0]
            return P(*entries)
    return param_spec
