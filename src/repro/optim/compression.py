"""Int8 error-feedback gradient compression (distributed-optimization trick
for slow cross-pod links).

Each gradient leaf is quantised to int8 with a per-leaf fp32 scale before
the cross-pod reduction; the quantisation error is fed back into the next
step's gradient (error feedback keeps SGD/Adam convergence, Karimireddy et
al. 2019).  On a 2-pod mesh this cuts the data-parallel all-reduce volume
over the inter-pod links by 4x (bf16 -> int8); see EXPERIMENTS.md SSPerf.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantisation: returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def make_error_feedback_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress_update(grads, ef_state):
    """Apply error feedback then compress: returns (quantised tree of
    (q, scale) pairs, new ef state).  The caller reduces the quantised
    values across pods and decompresses."""
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, ef_state)

    def comp(g):
        q, s = compress_int8(g)
        err = g - decompress_int8(q, s)
        return (q, s), err

    flat, treedef = jax.tree.flatten(corrected)
    qs, errs = zip(*(comp(g) for g in flat)) if flat else ((), ())
    return (jax.tree.unflatten(treedef, list(qs)),
            jax.tree.unflatten(treedef, list(errs)))
