"""``repro.serve`` — multi-tenant reconstruction job serving.

The paper makes one reconstruction fit on arbitrarily small devices; this
subsystem makes *many* reconstructions share a device pool.  A
:class:`ReconJob` (geometry + data + algorithm + priority) is submitted to
a :class:`Scheduler`, which

* estimates the job's per-device footprint off the shared memoized
  execution plan (:func:`repro.core.plan.plan` — the same IR the
  executors run),
* packs several small jobs per device and routes oversized jobs through
  the out-of-core streaming executors,
* interleaves one outer iteration per job per quantum (fair share) using
  the step-wise algorithm iterators in
  :mod:`repro.core.algorithms.stepwise`,
* preempts lower-priority work for urgent arrivals — per device, evicting
  only the cheapest victim set on the one slot where eviction makes the
  arrival fit — checkpointing the evicted job's resumable state so it
  later finishes bit-identically,
* rejects jobs whose ``deadline_seconds`` cannot be met under the modeled
  completion time (observed init/step costs),
* exposes throughput / latency metrics (:class:`ServeMetrics`).

Two drivers share that scheduler core: the cooperative single-thread
``Scheduler.run()`` loop, and the threaded :class:`AsyncDriver` (one
worker per device + background admission/snapshot thread) whose durable
snapshots + :meth:`Scheduler.restore` survive process death.

Past one host group, :mod:`repro.serve.pool` runs one scheduler per
*pod* (host group, optionally derived from a ``launch.mesh`` mesh):
:class:`MultiPodScheduler` routes each submission to the pod whose
topology models the cheapest completion, and :mod:`repro.serve.steal`
lets idle pods steal parked jobs from loaded ones — the transfer rides
the durable-snapshot format, so a stolen job resumes bit-identically on
the thief.  :class:`MultiPodDriver` threads the whole fleet.

The fleet is *elastic*: pod membership is dynamic
(``add_pod``/``remove_pod``), and :class:`Autoscaler`
(:mod:`repro.serve.autoscale`) grows it from a :class:`PodSpec`
template pool under load and shrinks it by draining the least-loaded
pod (preempt -> export -> bit-identical resume on a survivor) when the
backlog stays low.  With a ``snapshot_root``, ``snapshot_fleet`` /
``drain_fleet`` persist membership + parked jobs durably and
``MultiPodScheduler.restore_fleet`` rebuilds the whole fleet after
process death.

See ``docs/serve.md`` for the full architecture guide.

Quick start::

    from repro.serve import AsyncDriver, ReconJob, Scheduler
    from repro.core.splitting import MemoryModel

    sched = Scheduler(n_devices=4, memory=MemoryModel())
    jid = sched.submit(ReconJob("cgls", geo, angles, proj, n_iter=10,
                                priority=1))
    AsyncDriver(sched).run()
    image = sched.result(jid)
"""

from .job import JobRecord, JobStatus, ReconJob
from .queue import PriorityJobQueue
from .executor import JobExecutor, clear_operator_cache
from .metrics import ServeMetrics, merge_metrics, percentile
from .scheduler import (DevicePool, DeviceSlot, JobFootprint, Scheduler,
                        estimate_job_footprint, fair_share_weight)
from .driver import AsyncDriver, MultiPodDriver
from .pool import (MultiPodScheduler, Pod, PodSpec, RetiredPodSummary,
                   modeled_job_seconds, pods_from_mesh)
from .steal import (StealPolicy, drain_pod, migrate_once, steal_once,
                    steal_pass)
from .autoscale import Autoscaler, AutoscalePolicy, ScaleEvent

__all__ = ["ReconJob", "JobRecord", "JobStatus", "PriorityJobQueue",
           "JobExecutor", "clear_operator_cache", "ServeMetrics",
           "merge_metrics", "percentile", "DevicePool", "DeviceSlot",
           "JobFootprint", "Scheduler", "estimate_job_footprint",
           "fair_share_weight", "AsyncDriver", "MultiPodDriver",
           "MultiPodScheduler", "Pod", "PodSpec", "RetiredPodSummary",
           "modeled_job_seconds",
           "pods_from_mesh", "StealPolicy", "drain_pod", "migrate_once",
           "steal_once", "steal_pass", "Autoscaler", "AutoscalePolicy",
           "ScaleEvent"]
