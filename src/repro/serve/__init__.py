"""``repro.serve`` — multi-tenant reconstruction job serving.

The paper makes one reconstruction fit on arbitrarily small devices; this
subsystem makes *many* reconstructions share a device pool.  A
:class:`ReconJob` (geometry + data + algorithm + priority) is submitted to
a :class:`Scheduler`, which

* estimates the job's per-device footprint with the paper's planners
  (``plan_forward`` / ``plan_backward``),
* packs several small jobs per device and routes oversized jobs through
  the out-of-core streaming executors,
* interleaves one outer iteration per job per quantum (fair share) using
  the step-wise algorithm iterators in
  :mod:`repro.core.algorithms.stepwise`,
* preempts lower-priority work for urgent arrivals — per device, evicting
  only the cheapest victim set on the one slot where eviction makes the
  arrival fit — checkpointing the evicted job's resumable state so it
  later finishes bit-identically,
* rejects jobs whose ``deadline_seconds`` cannot be met under the modeled
  completion time (observed init/step costs),
* exposes throughput / latency metrics (:class:`ServeMetrics`).

Two drivers share that scheduler core: the cooperative single-thread
``Scheduler.run()`` loop, and the threaded :class:`AsyncDriver` (one
worker per device + background admission/snapshot thread) whose durable
snapshots + :meth:`Scheduler.restore` survive process death.

Quick start::

    from repro.serve import AsyncDriver, ReconJob, Scheduler
    from repro.core.splitting import MemoryModel

    sched = Scheduler(n_devices=4, memory=MemoryModel())
    jid = sched.submit(ReconJob("cgls", geo, angles, proj, n_iter=10,
                                priority=1))
    AsyncDriver(sched).run()
    image = sched.result(jid)
"""

from .job import JobRecord, JobStatus, ReconJob
from .queue import PriorityJobQueue
from .executor import JobExecutor, clear_operator_cache
from .metrics import ServeMetrics, percentile
from .scheduler import (DevicePool, DeviceSlot, JobFootprint, Scheduler,
                        estimate_job_footprint, fair_share_weight)
from .driver import AsyncDriver

__all__ = ["ReconJob", "JobRecord", "JobStatus", "PriorityJobQueue",
           "JobExecutor", "clear_operator_cache", "ServeMetrics",
           "percentile", "DevicePool", "DeviceSlot", "JobFootprint",
           "Scheduler", "estimate_job_footprint", "fair_share_weight",
           "AsyncDriver"]
