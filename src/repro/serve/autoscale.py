"""Elastic fleet autoscaling: grow and shrink a pod fleet from load.

The paper's splitting strategy works "with any number of GPUs"; the
serving fleet should therefore not be *statically* sized either.  The
:class:`Autoscaler` is a control plane over
:class:`~repro.serve.pool.MultiPodScheduler`: it watches the load
signals the schedulers already expose and changes the fleet's pod
membership at runtime.

Signals (all modeled, no new instrumentation):

* **backlog** — :meth:`Scheduler.modeled_backlog_seconds` per device,
  aggregated fleet-wide on the shared unit scale
  (:func:`repro.serve.steal.fleet_units`, so a cold just-spawned pod and
  a warm pod compare in the same units);
* **queue depth** — queued jobs per live pod (optional trigger);
* **fits-nowhere** — a submission no live pod can hold
  (``fits_nowhere_bytes``) asks the autoscaler for a pod from the
  template pool *at submit time*, before the job would be failed
  (wired through ``MultiPodScheduler.submit``).

Decisions (one per :meth:`Autoscaler.step` call, made by
:class:`AutoscalePolicy`):

* **scale up** when the fleet backlog has stayed above the band's high
  watermark for ``up_window_seconds``: instantiate the
  :class:`~repro.serve.pool.PodSpec` template that fits the most
  currently-queued jobs (cycling the pool when the queue is empty) and
  :meth:`~repro.serve.pool.MultiPodScheduler.add_pod` it.  The new pod
  is cold — routing and stealing price it with the fleet's shared units
  (it borrows the warm pods' EMAs), so it is not mispriced against warm
  pods and starts taking work immediately.
* **scale down** when the backlog has stayed below the low watermark for
  ``down_window_seconds``: pick the least-loaded pod, **drain** it with
  :func:`repro.serve.steal.drain_pod` — pause its admission, preempt its
  running jobs at their step boundaries, export every parked job through
  the durable-snapshot transfer format to the surviving pods
  (bit-identical resume) — and retire it only once empty
  (:meth:`~repro.serve.pool.MultiPodScheduler.remove_pod`).  A drain
  that cannot complete (a job no survivor can hold) aborts cleanly: the
  pod resumes admission and stays.

Both directions respect ``min_pods`` / ``max_pods`` and a **cooldown**
between events; the watermark **windows** add hysteresis, so an
oscillating load trace cannot thrash the fleet (asserted in
``tests/test_serve_autoscale.py``).

The autoscaler is *passive*: it only acts when someone calls
:meth:`step` — the cooperative loop (``MultiPodScheduler.run(...,
autoscaler=...)``) and the threaded
:class:`~repro.serve.driver.MultiPodDriver` control thread both do.
``clock`` and ``load_fn`` are injectable so policy behaviour is testable
without wall-clock sleeps.

Measured payoff: ``benchmarks/bench_serve.py --bursty`` shows the
autoscaled fleet tracking a static max-size fleet's wall jobs/sec on a
bursty trace while spending a fraction of the pod-seconds, with every
drained-and-moved job verified bit-identical to an undrained rerun.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..obs import fleet_event
from ..obs.calibration import CalibrationLedger
from .pool import DuplicatePodName, MultiPodScheduler, Pod, PodSpec
from .scheduler import estimate_job_footprint
from .steal import drain_pod, fleet_units, pod_load


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """When to grow and when to shrink the fleet.

    The backlog band is in modeled seconds per device (the same units as
    :meth:`Scheduler.modeled_backlog_seconds` under the fleet's shared
    unit scale).  Hysteresis has two layers: the signal must *persist*
    for a window before either direction acts, and any scale event
    starts a cooldown during which no further event fires.
    """

    #: scale up while the fleet's per-device modeled backlog exceeds this
    scale_up_backlog_seconds: float = 1.0
    #: scale down while it is below this (must be < the high watermark)
    scale_down_backlog_seconds: float = 0.1
    #: the high signal must persist this long before a pod is added
    up_window_seconds: float = 0.0
    #: the low signal must persist this long before a pod is drained
    down_window_seconds: float = 0.5
    #: minimum spacing between *any* two scale events (thrash guard)
    cooldown_seconds: float = 1.0
    #: fleet never shrinks below / grows above these
    min_pods: int = 1
    max_pods: int = 4
    #: optional extra trigger: scale up when queued jobs per live pod
    #: exceed this (None disables)
    scale_up_queue_depth: Optional[int] = None
    #: how long a scale-down drain may take before it is aborted
    drain_timeout_seconds: float = 60.0
    #: predictive scale-up: trigger while the backlog is still *below*
    #: the high watermark when its observed growth rate projects it
    #: across within the fleet's init-EMA lead time — a new pod pays
    #: roughly one executor init before it does useful work, so by
    #: starting that early the pod is live as the band is crossed
    #: instead of an init after it.  Inactive until the fleet has
    #: observed an init (cold fleets have no lead time to hide).
    predictive_scale_up: bool = False
    #: pre-warm a scaled-up pod during its lead window: right after the
    #: pod is added (predictively or not), build the currently-queued
    #: jobs' operators + kernel dispatch entries under the new pod's
    #: memory budget into the shared executor caches, so the first job
    #: admitted there skips the operator build/JIT stall the predictive
    #: trigger paid for in lead time
    prewarm: bool = False

    def __post_init__(self):
        if self.scale_down_backlog_seconds >= self.scale_up_backlog_seconds:
            raise ValueError(
                f"backlog band inverted: low watermark "
                f"{self.scale_down_backlog_seconds} must be below high "
                f"{self.scale_up_backlog_seconds}")
        if self.min_pods < 1 or self.max_pods < self.min_pods:
            raise ValueError(f"need 1 <= min_pods <= max_pods, got "
                             f"{self.min_pods}..{self.max_pods}")


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One membership change, for the audit trail / bench report."""
    t: float              # policy clock at the decision
    direction: str        # "up" | "down"
    pod: str              # pod added or retired
    load: float           # fleet per-device backlog that triggered it
    n_pods: int           # live pods *after* the event
    predicted: bool = False   # fired by the predictive (lead-time) path


class Autoscaler:
    """Grows and shrinks a :class:`MultiPodScheduler` fleet at runtime.

    Parameters
    ----------
    mps : the fleet to control.  The autoscaler registers itself on it
        so ``submit`` can request a pod for a job that fits nowhere.
    templates : :class:`PodSpec` pool scale-ups instantiate from; each
        spawned pod gets a unique ``<template>-as<N>`` name.  A
        backlog-triggered scale-up picks the template that *fits the
        most currently-queued jobs* (ties broken toward the smallest
        pod, so a giant template is not burned on small work); with an
        empty queue it falls back to cycling the pool in order, which
        keeps heterogeneous "big-memory pods first, small ones after"
        orderings meaningful.
    policy : see :class:`AutoscalePolicy`.
    clock : time source (injectable for tests; defaults to
        ``time.monotonic``).
    load_fn : override of the fleet load signal, called with the live
        pod snapshot (injectable for tests).
    guard : optional :class:`~repro.checkpoint.preemption.PreemptionGuard`
        attached to every spawned pod's scheduler — without it, a fleet
        whose original (guarded) pods have all been retired would no
        longer see the host's SIGTERM.

    Templates must be *simulated* pods (no ``jax_devices`` pins): the
    template is instantiated repeatedly, and two live pods cloned from
    one pinned template would double-book the same physical devices
    with no shared memory accounting.  Pin real devices by building the
    Pod yourself and calling :meth:`MultiPodScheduler.add_pod`.
    """

    def __init__(self, mps: MultiPodScheduler,
                 templates: Sequence[PodSpec],
                 policy: AutoscalePolicy = AutoscalePolicy(),
                 clock: Callable[[], float] = time.monotonic,
                 load_fn: Optional[Callable[[Sequence[Pod]], float]] = None,
                 guard=None):
        if not templates:
            raise ValueError("Autoscaler needs at least one PodSpec "
                             "template to scale up from")
        pinned = [t.name for t in templates if t.jax_devices is not None]
        if pinned:
            raise ValueError(
                f"Autoscaler templates must be simulated pods; {pinned} "
                f"pin jax_devices, and repeated scale-ups would "
                f"double-book those physical devices (build the Pod "
                f"yourself and use MultiPodScheduler.add_pod instead)")
        self.mps = mps
        self.templates = list(templates)
        self.guard = guard
        self.policy = policy
        self.clock = clock
        self._load_fn = load_fn
        self._spawned = itertools.count()
        self._above_since: Optional[float] = None
        self._below_since: Optional[float] = None
        self._last_event: Optional[float] = None
        # previous (clock, load) observation: the predictive scale-up's
        # slope estimate (None until step() has observed once)
        self._last_obs: Optional[Tuple[float, float]] = None
        self.events: List[ScaleEvent] = []
        #: every job moved off a pod by a scale-down drain (the bench
        #: re-runs each one undrained and asserts bit-identity)
        self.drained_jobs: List[str] = []
        self.aborted_scale_downs = 0
        mps.autoscaler = self

    # ---- load signal -------------------------------------------------------

    def fleet_load(self, pods: Optional[Sequence[Pod]] = None) -> float:
        """Fleet-wide modeled backlog per device on the shared unit
        scale: total owed seconds across pods over total devices."""
        pods = list(self.mps.pods_snapshot() if pods is None else pods)
        if self._load_fn is not None:
            return self._load_fn(pods)
        if not pods:
            return 0.0
        unit, init = fleet_units(pods)
        total = sum(pod_load(p.scheduler, p.n_devices,
                             unit=unit, init=init) * p.n_devices
                    for p in pods)
        return total / max(1, sum(p.n_devices for p in pods))

    def _queue_depth_per_pod(self, pods: Sequence[Pod]) -> float:
        queued = sum(len(p.scheduler.queue) for p in pods)
        return queued / max(1, len(pods))

    # ---- control step ------------------------------------------------------

    def step(self) -> Optional[ScaleEvent]:
        """One control decision: observe the load, update the hysteresis
        windows, and scale at most one pod up or down.  Returns the
        event, or None."""
        now = self.clock()
        pods = self.mps.pods_snapshot()
        load = self.fleet_load(pods)
        p = self.policy

        want_up = load > p.scale_up_backlog_seconds
        if p.scale_up_queue_depth is not None:
            want_up = want_up or (self._queue_depth_per_pod(pods)
                                  > p.scale_up_queue_depth)
        # predictive trigger: the load is still inside the band, but its
        # observed growth rate crosses the high watermark within the
        # fleet's init-EMA lead time — exactly the time a new pod needs
        # before it does useful work, so start it now and it is live as
        # the band is crossed.  Windows and cooldown still apply.
        predicted = False
        prev, self._last_obs = self._last_obs, (now, load)
        if not want_up and p.predictive_scale_up and prev is not None:
            lead = fleet_units(pods)[1]
            if lead > 0 and now > prev[0]:
                slope = (load - prev[1]) / (now - prev[0])
                if (slope > 0
                        and load + slope * lead
                        > p.scale_up_backlog_seconds):
                    want_up = predicted = True
        want_down = load < p.scale_down_backlog_seconds and not want_up

        # window state is read into locals once updated: a submit-thread
        # scale_up_for may reset the attributes to None concurrently,
        # and computing `now - None` would kill the fleet control loop.
        # (Explicit None checks throughout: a window starting at clock
        # 0.0 is falsy but set.)
        if want_up:
            above = self._above_since
            if above is None:
                above = self._above_since = now
        else:
            above = self._above_since = None
        if want_down:
            below = self._below_since
            if below is None:
                below = self._below_since = now
        else:
            below = self._below_since = None

        last = self._last_event
        if last is not None and now - last < p.cooldown_seconds:
            return None
        if (want_up and len(pods) < p.max_pods
                and now - above >= p.up_window_seconds):
            return self._scale_up(now, load, predicted=predicted)
        if (want_down and len(pods) > p.min_pods
                and now - below >= p.down_window_seconds):
            return self._scale_down(now, load, pods)
        return None

    # ---- scale up ----------------------------------------------------------

    def _pick_template(self) -> Optional[int]:
        """Index of the template whose memory budget fits the most
        currently-queued jobs (footprints via the schedulers' shared
        plan-backed :func:`estimate_job_footprint`); ties break toward
        the *smallest* usable memory so a big-memory template is kept
        for the jobs that need it.  None when nothing is queued — the
        caller then falls back to cycling the template pool."""
        jobs = []
        for p in self.mps.pods_snapshot():
            try:
                jobs.extend(r.job
                            for r in p.scheduler.queue.pending_records())
            except Exception:
                continue        # a pod mid-retire: skip its queue
        if not jobs:
            return None
        best = None
        for i, spec in enumerate(self.templates):
            fits = 0
            for job in jobs:
                try:
                    fp = estimate_job_footprint(job, spec.memory)
                except Exception:
                    continue    # unplannable under this budget: no fit
                if fp.bytes_on_device <= int(spec.memory.usable):
                    fits += 1
            key = (-fits, int(spec.memory.usable), i)
            if best is None or key < best[0]:
                best = (key, i)
        return best[1]

    def _next_pod(self, template_index: Optional[int] = None) -> Pod:
        """Instantiate the next template as a uniquely-named pod.

        Only :class:`~repro.serve.pool.DuplicatePodName` retries (a name
        collision, e.g. after a fleet restore re-seeded the counter's
        namespace, is fixed by the next counter value).  Any other error
        — a bad template the Pod constructor rejects, a scheduler init
        failure — propagates: this runs *inside the fleet lock*, and a
        blanket ``except ValueError: continue`` would spin forever
        there, wedging every submit/steal/snapshot in the process.
        The manifest write is deferred (``flush_manifest=False``)
        because the caller holds the fleet lock; the caller flushes
        after releasing it."""
        while True:
            k = next(self._spawned)
            spec = self.templates[(template_index if template_index
                                   is not None else k)
                                  % len(self.templates)]
            name = f"{spec.name}-as{k}"
            try:
                return self.mps.add_pod(
                    Pod(dataclasses.replace(spec, name=name),
                        guard=self.guard),
                    flush_manifest=False)
            except DuplicatePodName:
                continue    # name collision (e.g. after restore): next k

    def _scale_up(self, now: float, load: float,
                  template_index: Optional[int] = None,
                  predicted: bool = False) -> Optional[ScaleEvent]:
        # backlog-triggered scale-ups (no explicit template) pick by
        # queued-job footprint fit; done *before* the fleet lock — the
        # fit scan walks every pod's queue and prices footprints
        if template_index is None:
            template_index = self._pick_template()
        # the max_pods bound is re-checked *under the fleet lock*: the
        # control thread's step() and a submit thread's scale_up_for
        # both pass their own lock-free pre-checks, and without this one
        # the two adds together could exceed the cap.  The count
        # includes draining pods — a drain can still abort and return
        # its pod to service, and the cap is a hard resource bound.
        with self.mps._fleet_lock:
            if len(self.mps.pods_snapshot(live_only=False)) \
                    >= self.policy.max_pods:
                return None
            pod = self._next_pod(template_index)
        # the add above only *marked* the manifest dirty (we held the
        # fleet lock; disk I/O under it would stall the whole fleet) —
        # write it now the lock is released
        self.mps._flush_manifest()
        self.mps.record_scale_event("up")
        self._last_event = now
        self._above_since = None
        warmed = self._prewarm(pod) if self.policy.prewarm else 0
        ev = ScaleEvent(now, "up", pod.name, load,
                        len(self.mps.pods_snapshot()), predicted=predicted)
        # modeled_s: the fleet's init EMA — the modeled lead time before
        # the new pod does useful work (the quantity the predictive
        # trigger bet on); the calibration ledger folds it so scale-up
        # decisions are auditable on the same scale as admissions
        _, init = fleet_units(self.mps.pods_snapshot())
        fleet_event("scale-up", pod=pod.name, load=load, n_pods=ev.n_pods,
                    predicted=predicted, modeled_s=init, warmed=warmed)
        self.events.append(ev)
        return ev

    def _prewarm(self, pod: Pod) -> int:
        """Warm the new pod's operator path with the fleet's queued jobs.

        The executor operator cache is process-shared, so building the
        queued jobs' operators under the new pod's memory budget (its
        budget decides plain-vs-stream, hence the cache key) means the
        work the pod was spawned to absorb admits without the build/JIT
        stall.  Best-effort: a job that cannot build fails later at its
        own admission, never the scale-up."""
        from .executor import prewarm_jobs
        jobs = []
        for p in self.mps.pods_snapshot():
            try:
                jobs.extend(r.job
                            for r in p.scheduler.queue.pending_records())
            except Exception:
                continue        # a pod mid-retire: skip its queue
        if not jobs:
            return 0
        return prewarm_jobs(jobs, pod.spec.memory)

    def scale_up_for(self, job) -> Optional[Pod]:
        """Submit-time hook (``MultiPodScheduler.submit``): a job fits no
        live pod — add the first template pod that could hold it, if the
        fleet may still grow.  This is the strongest scale-up signal, so
        it bypasses both the backlog window and the cooldown (the
        cooldown guards against load-signal thrash; here the
        alternative is failing a placeable job *permanently* with the
        budget error because of an unrelated earlier event) — only
        ``max_pods`` still bounds it.  Returns the new pod, or None
        (the job then takes the canonical budget failure)."""
        now = self.clock()
        p = self.policy
        if len(self.mps.pods_snapshot(live_only=False)) >= p.max_pods:
            return None
        for i, spec in enumerate(self.templates):
            try:
                fp = estimate_job_footprint(job, spec.memory)
            except Exception:
                continue
            if fp.bytes_on_device <= int(spec.memory.usable):
                ev = self._scale_up(now, self.fleet_load(),
                                    template_index=i)
                return self.mps._pod_by(ev.pod) if ev is not None else None
        return None

    # ---- scale down --------------------------------------------------------

    def _scale_down(self, now: float, load: float,
                    pods: Sequence[Pod]) -> Optional[ScaleEvent]:
        """Drain the least-loaded pod to the survivors and retire it."""
        unit, init = fleet_units(pods)
        victim = min(pods, key=lambda q: (pod_load(q.scheduler,
                                                   q.n_devices,
                                                   unit=unit, init=init),
                                          q.name))
        survivors = [q for q in pods if q is not victim]
        victim.draining = True        # routing/stealing skip it from here
        try:
            with self.mps.transfer_guard():
                moved = drain_pod(
                    victim, survivors, self.mps.transfer_dir,
                    data_refs=self.mps.data_refs,
                    timeout=self.policy.drain_timeout_seconds)
            self.mps.remove_pod(victim)
        except Exception:
            # aborted drain (unmovable job / timeout / a pinned submit
            # that slipped in before remove_pod): the pod stays in
            # service.  drain_pod resumes admission only when *it*
            # raised, so resume here too — a pod back in service with
            # admission still paused would strand its queue forever.
            victim.scheduler.resume_admission()
            victim.draining = False
            self.aborted_scale_downs += 1
            self._last_event = now    # still a cooldown: don't retry-spin
            self._below_since = None
            return None
        self.drained_jobs.extend(moved)
        self.mps.record_scale_event("down")
        self._last_event = now
        self._below_since = None
        ev = ScaleEvent(now, "down", victim.name, load,
                        len(self.mps.pods_snapshot()))
        fleet_event("scale-down", pod=victim.name, load=load,
                    n_pods=ev.n_pods, moved=len(moved))
        self.events.append(ev)
        return ev

    # ---- reporting ---------------------------------------------------------

    def summary(self) -> Dict:
        """Control-loop audit: the scale decisions taken plus the
        calibration ledger's verdict on the cost models those decisions
        rode on (samples folded per event kind, and the pods whose
        models have EMA-drifted stale).  The ledger reads the live
        fleet event log, so this is empty unless tracing was enabled."""
        led = CalibrationLedger.from_events()
        return {
            "scale_ups": sum(1 for e in self.events
                             if e.direction == "up"),
            "scale_downs": sum(1 for e in self.events
                               if e.direction == "down"),
            "predicted_scale_ups": sum(1 for e in self.events
                                       if e.predicted),
            "aborted_scale_downs": self.aborted_scale_downs,
            "drained_jobs": len(self.drained_jobs),
            "calibration_samples_by_kind": led.samples_by_kind(),
            "calibration_events_by_kind": led.events_by_kind(),
            "stale_pods": led.stale_pods(),
        }
