"""Threaded serving driver: one worker thread per device slot.

The cooperative :meth:`Scheduler.run` loop steps every device's jobs from
a single thread, so on a real multi-accelerator host only one device
computes at a time.  The :class:`AsyncDriver` realises the paper's "each
of these instructions is executed for all available GPUs simultaneously"
at the serving layer:

* one **worker thread per** :class:`~repro.serve.scheduler.DeviceSlot`
  claims that device's resident jobs (weighted fair share via stride
  scheduling — see :meth:`Scheduler.claim_step`) and steps them with the
  scheduler lock *released*, so devices genuinely overlap;
* a background **scheduler thread** handles admission, deadline checks
  and preemption, and — when a snapshot directory is configured — writes
  periodic durable snapshots of every parked job through
  :mod:`repro.checkpoint.sharded`;
* the attached :class:`~repro.checkpoint.preemption.PreemptionGuard`
  (SIGTERM) stops the loop; :meth:`AsyncDriver.run` then drains the
  scheduler, parking + persisting every running job so a restarted
  process resumes them bit-identically via :meth:`Scheduler.restore`.

Workers synchronise with the scheduler only at step boundaries — a job
mid-step is never checkpointed (its state would be torn); preemption and
drain requests are flagged and honoured when the step returns, which the
executor guarantees is a real synchronisation point (it blocks on the
state's arrays before returning).

:class:`MultiPodDriver` lifts the same model to a pod fleet
(:class:`~repro.serve.pool.MultiPodScheduler`): one ``AsyncDriver`` per
pod plus a background work-stealing thread (:mod:`repro.serve.steal`).

Usage::

    sched = Scheduler(n_devices=4, memory=MemoryModel(...),
                      snapshot_dir="/ckpt/serve")
    for job in jobs:
        sched.submit(job)
    AsyncDriver(sched).run()            # start + wait idle + stop
    image = sched.result(job_id)
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional

from .metrics import ServeMetrics
from .scheduler import DeviceSlot, Scheduler


class AsyncDriver:
    """Drives a :class:`Scheduler` with one thread per device slot plus a
    background admission/snapshot thread.

    Parameters
    ----------
    scheduler : the (thread-safe) scheduler to drive.
    poll_seconds : idle back-off for the worker/scheduler loops.
    snapshot_dir : where periodic + drain snapshots go; defaults to
        ``scheduler.snapshot_dir`` (None disables persistence).
    snapshot_every_seconds : period of the background durable snapshots
        (0 disables; drain still persists).
    snapshot_running : include *running* jobs in the periodic snapshot
        (copy-on-checkpoint at step boundaries, see
        :meth:`Scheduler.snapshot`) so a kill -9 mid-run resumes each
        job from its last persisted completed step instead of its last
        parked state.  On by default; False restores the parked-only
        behaviour.
    """

    def __init__(self, scheduler: Scheduler, poll_seconds: float = 0.001,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every_seconds: float = 0.0,
                 snapshot_running: bool = True):
        self.scheduler = scheduler
        self.poll_seconds = poll_seconds
        self.snapshot_dir = snapshot_dir or scheduler.snapshot_dir
        self.snapshot_every_seconds = snapshot_every_seconds
        self.snapshot_running = snapshot_running
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        # first *internal* error (scheduler/snapshot machinery, not tenant
        # code — tenant failures fail their job alone); stops the driver
        # so run()/wait() surface it instead of hanging forever
        self.error: Optional[BaseException] = None

    def _die(self, err: BaseException) -> None:
        if self.error is None:
            self.error = err
        self._stop.set()

    # ---- lifecycle ---------------------------------------------------------

    @property
    def started(self) -> bool:
        return bool(self._threads)

    def start(self) -> None:
        """Spawn the scheduler thread and one worker per device slot."""
        if self.started:
            raise RuntimeError("driver already started")
        self._stop.clear()
        m = self.scheduler.metrics
        if m.wall_start is None:
            m.wall_start = time.monotonic()
        self._threads = [threading.Thread(
            target=self._scheduler_loop, name="serve-scheduler", daemon=True)]
        for slot in self.scheduler.pool.slots:
            self._threads.append(threading.Thread(
                target=self._worker_loop, args=(slot,),
                name=f"serve-worker-{slot.index}", daemon=True))
        for t in self._threads:
            t.start()

    def stop(self) -> None:
        """Stop all threads at their next step boundary and join them.
        In-flight steps finish; nothing is lost or torn."""
        self._stop.set()
        for t in self._threads:
            t.join()
        self._threads = []
        self.scheduler.metrics.wall_end = time.monotonic()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the scheduler is idle (all jobs in a terminal
        state), the guard fires, or ``timeout`` elapses.  Returns True if
        idle was reached."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self.scheduler.idle:
                return True
            if self.error is not None:
                return False
            guard = self.scheduler.guard
            if guard is not None and guard.preempted:
                return False
            if deadline is not None and time.monotonic() > deadline:
                return False
            time.sleep(self.poll_seconds)

    def run(self, timeout: Optional[float] = None) -> ServeMetrics:
        """start() + wait() + stop(), draining on guard preemption.

        The one-call equivalent of the cooperative ``Scheduler.run()``,
        with true per-device overlap.  If the guard fired (host SIGTERM),
        every running job is parked and — when a snapshot directory is
        configured — persisted durably before returning."""
        self.start()
        try:
            self.wait(timeout)
        finally:
            self.stop()
        if self.error is not None:
            raise RuntimeError(
                "AsyncDriver stopped on an internal error") from self.error
        guard = self.scheduler.guard
        if guard is not None and guard.preempted:
            self.scheduler.drain(self.snapshot_dir)
        return self.scheduler.metrics

    # ---- loops -------------------------------------------------------------

    def _scheduler_loop(self) -> None:
        sched = self.scheduler
        last_snap = time.monotonic()
        try:
            while not self._stop.is_set():
                guard = sched.guard
                if guard is not None and guard.preempted:
                    return      # run()/wait() own the drain
                sched.admit()
                if (self.snapshot_dir is not None
                        and self.snapshot_every_seconds > 0
                        and time.monotonic() - last_snap
                        >= self.snapshot_every_seconds):
                    sched.snapshot(self.snapshot_dir,
                                   include_running=self.snapshot_running)
                    last_snap = time.monotonic()
                time.sleep(self.poll_seconds)
        except BaseException as e:      # a dead loop would hang run()
            self._die(e)

    def _worker_loop(self, slot: DeviceSlot) -> None:
        sched = self.scheduler
        try:
            while not self._stop.is_set():
                run = sched.claim_step(slot)
                if run is None:
                    time.sleep(self.poll_seconds)
                    continue
                t0 = time.monotonic()
                err: Optional[Exception] = None
                try:
                    # outside the scheduler lock: where devices overlap
                    run.executor.step()
                except Exception as e:  # tenant failure, not ours
                    err = e
                sched.finish_step(run, time.monotonic() - t0, err)
        except BaseException as e:      # a dead loop would hang run()
            self._die(e)


class MultiPodDriver:
    """Threaded fleet driver: one :class:`AsyncDriver` per pod plus a
    background control thread (work stealing + autoscaling + membership
    sync).

    Every pod's workers step their own devices concurrently (pods share
    nothing but the transfer directory).  The control thread
    periodically runs :meth:`MultiPodScheduler.steal_pass` so an idle
    pod's workers find stolen jobs in their scheduler's queue at their
    next admission pass, gives the attached
    :class:`~repro.serve.autoscale.Autoscaler` (if any) one control
    decision, and *syncs membership*: a pod the autoscaler added gets
    its own ``AsyncDriver`` started, a retired pod's driver is stopped.
    Internal errors from any pod's driver (or from the steal /
    autoscale machinery) stop the whole fleet and are raised from
    :meth:`run` — a silently dead pod would strand its queue.

    ``snapshot_every_seconds`` > 0 turns on periodic durable snapshots
    on every pod driver (each pod persists parked jobs into its own
    snapshot subdirectory — see ``MultiPodScheduler.snapshot_root``), so
    a kill -9 mid-run loses at most one period of parked-state changes
    and :meth:`MultiPodScheduler.restore_fleet` rebuilds the fleet.  If
    a pod scheduler's guard fires (host SIGTERM), :meth:`run` drains the
    whole fleet into its snapshot root before returning.

    Usage::

        mps = MultiPodScheduler(pods, transfer_dir=...)
        for job in jobs:
            mps.submit(job)
        MultiPodDriver(mps).run()
        image = mps.result(job_id)
    """

    def __init__(self, mps, poll_seconds: float = 0.001,
                 steal_every_seconds: float = 0.002,
                 autoscaler=None,
                 snapshot_every_seconds: float = 0.0):
        self.mps = mps
        self.poll_seconds = poll_seconds
        self.steal_every_seconds = steal_every_seconds
        self.autoscaler = autoscaler
        self.snapshot_every_seconds = snapshot_every_seconds
        self._dlock = threading.RLock()
        self._drivers: dict = {}         # pod name -> AsyncDriver
        self._started = False
        self._stop = threading.Event()
        self._control_thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None
        for pod in mps.pods_snapshot(live_only=False):
            self.attach_pod(pod)

    @property
    def drivers(self):
        with self._dlock:
            return list(self._drivers.values())

    # ---- dynamic membership ------------------------------------------------

    def attach_pod(self, pod) -> AsyncDriver:
        """Give ``pod`` its own :class:`AsyncDriver` (started immediately
        if the fleet is already running).  The control thread calls this
        for pods the autoscaler adds; it is idempotent per pod name."""
        with self._dlock:
            d = self._drivers.get(pod.name)
            if d is not None:
                return d
            d = AsyncDriver(pod.scheduler, poll_seconds=self.poll_seconds,
                            snapshot_every_seconds=self.snapshot_every_seconds)
            self._drivers[pod.name] = d
            if self._started:
                d.start()
            return d

    def detach_pod(self, pod_name: str) -> None:
        """Stop and drop a retired pod's driver (its scheduler is empty
        by the time the autoscaler removes it from the fleet)."""
        with self._dlock:
            d = self._drivers.pop(pod_name, None)
        if d is not None and d.started:
            d.stop()

    def _sync_pods(self) -> None:
        """Reconcile the driver set with the fleet's current membership
        snapshot: attach new pods, detach retired ones."""
        live = {p.name: p
                for p in self.mps.pods_snapshot(live_only=False)}
        with self._dlock:
            known = set(self._drivers)
        for name in known - set(live):
            self.detach_pod(name)
        for name, pod in live.items():
            if name not in known:
                self.attach_pod(pod)

    # ---- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        self._stop.clear()
        self._started = True
        self._sync_pods()
        for d in self.drivers:
            if not d.started:
                d.start()
        self._control_thread = threading.Thread(
            target=self._control_loop, name="serve-fleet-control",
            daemon=True)
        self._control_thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._control_thread is not None:
            self._control_thread.join()
            self._control_thread = None
        for d in self.drivers:
            if d.started:
                d.stop()
        self._started = False

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until every pod is idle, any pod errors, a guard fires,
        or ``timeout``."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self.mps.idle:
                return True
            for d in self.drivers:
                if d.error is not None:
                    self.error = self.error or d.error
                    return False
            if self.error is not None:
                return False
            if self._guard_preempted():
                return False
            if deadline is not None and time.monotonic() > deadline:
                return False
            time.sleep(self.poll_seconds)

    def _guard_preempted(self) -> bool:
        for pod in self.mps.pods_snapshot(live_only=False):
            g = pod.scheduler.guard
            if g is not None and g.preempted:
                return True
        return False

    def run(self, timeout: Optional[float] = None) -> ServeMetrics:
        """start() + wait() + stop(); returns merged fleet metrics.  If a
        preemption guard fired (host SIGTERM) and the fleet has a
        snapshot root, every running job is parked and the whole fleet
        persisted durably (:meth:`MultiPodScheduler.drain_fleet`) before
        returning — a re-run restores with ``restore_fleet``."""
        self.start()
        try:
            self.wait(timeout)
        finally:
            self.stop()
        if self.error is not None:
            raise RuntimeError(
                "MultiPodDriver stopped on an internal error") from self.error
        if (self._guard_preempted()
                and getattr(self.mps, "snapshot_root", None) is not None):
            self.mps.drain_fleet()
        return self.mps.metrics()

    def _control_loop(self) -> None:
        try:
            while not self._stop.is_set():
                if self.mps.steal:
                    self.mps.steal_pass()
                # explicit autoscaler wins; otherwise the one that
                # registered itself on the fleet (Autoscaler.__init__
                # sets mps.autoscaler) — without the fallback a driver
                # built without `autoscaler=` would silently leave the
                # fleet half-wired (fits-nowhere hook live, backlog
                # scaling dead)
                asc = self.autoscaler or getattr(self.mps, "autoscaler",
                                                 None)
                if asc is not None:
                    asc.step()
                self._sync_pods()
                time.sleep(self.steal_every_seconds)
        except BaseException as e:      # surface, don't die silently
            self.error = e
            self._stop.set()
