"""Step-wise job executor: one placed job's operator + algorithm state.

The executor owns what the scheduler placed on a device: it builds the
:class:`~repro.core.operator.CTOperator` for the backend the placement
chose ("plain" for resident jobs packed next to other tenants, "stream"
for jobs routed through the paper's out-of-core path), instantiates the
algorithm's resumable state from the step-wise registry, and advances it
one outer iteration per call.  Between any two calls the scheduler may
checkpoint the executor (preemption) and later rebuild it from the
checkpoint — results are bit-identical to an uninterrupted run because
``init`` is deterministic and the checkpoint carries every recurrence
variable.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Dict, Optional, Sequence

import jax
import numpy as np

from .. import obs
from ..core.algorithms.stepwise import (checkpoint_state, get_algorithm,
                                        restore_state)
from ..core.operator import CTOperator
from ..core.plan import plan as plan_execution
from ..core.splitting import MemoryModel
from .job import ReconJob

# Operator cache shared across jobs: tenants with the same acquisition
# (geometry + angles + backend + weighting + budget + device) reuse one
# CTOperator and therefore its jit-compiled kernels -- the dominant cost
# of admitting a job.  Bounded LRU so a long-lived scheduler serving many
# distinct geometries cannot grow without limit.
_OP_CACHE_MAX = 32
_op_cache: "OrderedDict[tuple, CTOperator]" = OrderedDict()
_op_cache_lock = threading.Lock()   # admission may run in several schedulers


def clear_operator_cache() -> None:
    """Drop all cached operators (frees their compiled executables)."""
    with _op_cache_lock:
        _op_cache.clear()


def _get_operator(geo, angles: np.ndarray, mode: str, bp_weight: str,
                  memory: MemoryModel, devices: Optional[Sequence],
                  backend: Optional[str] = None) -> CTOperator:
    from repro.core.backend import resolve
    from repro.kernels import autotune
    backend = resolve(backend)     # "auto"/None and its target share a key
    # autotune.fingerprint(): a retuned/reloaded block table must not
    # reuse operators compiled under the previous block config
    key = (geo, angles.tobytes(), mode, bp_weight, backend,
           memory.device_bytes, memory.usable_fraction,
           tuple(getattr(d, "id", id(d)) for d in devices or ()),
           autotune.fingerprint())
    with _op_cache_lock:
        op = _op_cache.get(key)
        if op is not None:
            _op_cache.move_to_end(key)
            return op
    op = CTOperator(geo, angles, mode=mode, bp_weight=bp_weight,
                    memory=memory, devices=devices, backend=backend)
    with _op_cache_lock:
        _op_cache[key] = op
        if len(_op_cache) > _OP_CACHE_MAX:
            _op_cache.popitem(last=False)
    return op


def prewarm_jobs(jobs: Sequence[ReconJob], memory: MemoryModel,
                 devices: Optional[Sequence] = None) -> int:
    """Warm the shared operator cache for ``jobs`` ahead of admission.

    Builds (or touches) each job's :class:`CTOperator` under the same
    cache key admission will use — mode mirrors the scheduler's
    ``stream-if-it-splits`` decision, weighting the algorithm's default —
    so the first admitted job on a freshly scaled-up pod skips the
    operator build/JIT stall.  Deduplicates by key, never raises (a job
    whose geometry cannot build fails admission later, with the error
    attributed to that job); returns the number of operators warmed.
    """
    from .scheduler import estimate_job_footprint
    warmed = 0
    seen = set()
    for job in jobs:
        try:
            alg = get_algorithm(job.algorithm)
            fp = estimate_job_footprint(job, memory)
            mode = "stream" if fp.streams else "plain"
            dedup = (job.geo, job.angles.tobytes(), mode,
                     alg.default_bp_weight, job.backend)
            if dedup in seen:
                continue
            seen.add(dedup)
            op = _get_operator(job.geo, job.angles, mode,
                               alg.default_bp_weight, memory, devices,
                               backend=job.backend)
            op.warmup()
            warmed += 1
        except Exception:
            continue
    return warmed


def operator_cache_keys() -> tuple:
    """Current operator-cache keys (regression tests assert pre-warm)."""
    with _op_cache_lock:
        return tuple(_op_cache)


def _block_on_state(state) -> None:
    """Wait for every device array reachable from ``state`` to finish.

    JAX dispatch is asynchronous: ``alg.step`` returns as soon as the work
    is *enqueued*, so any wall-clock measurement taken around it would time
    the enqueue, not the compute.  Blocking on the state's arrays makes the
    step boundary a real synchronisation point — step timings, per-device
    busy clocks, and the modeled makespan all depend on it.
    """
    for leaf in jax.tree_util.tree_leaves(vars(state)):
        block = getattr(leaf, "block_until_ready", None)
        if block is not None:
            block()


class JobExecutor:
    """Runs one :class:`ReconJob` step by step on its assigned backend."""

    def __init__(self, job: ReconJob, mode: str,
                 memory: Optional[MemoryModel] = None,
                 devices: Optional[Sequence] = None,
                 labels: Optional[Dict[str, Any]] = None):
        self.job = job
        self.alg = get_algorithm(job.algorithm)
        self.mode = mode
        self.memory = memory or MemoryModel()
        self.devices = devices
        # ambient trace identity (pod name, device slot) merged into every
        # span this executor's work opens — streaming-loop spans inherit
        # it without new plumbing through the operator call signatures
        self.labels = {k: v for k, v in (labels or {}).items()
                       if v is not None}
        self._state = None
        self.init_seconds = 0.0
        # span-category seconds from the most recent start()/step(),
        # drained by the scheduler into ServeMetrics.phase_seconds
        self._phase_delta: Dict[str, float] = {}

    def take_phase_seconds(self) -> Dict[str, float]:
        out, self._phase_delta = self._phase_delta, {}
        return out

    @property
    def step_transfer_bytes(self) -> int:
        """Schedule-modeled host<->device bytes one outer iteration of a
        *streamed* job moves (0 for in-core jobs — their operands stay
        resident).  Read off the plan's CommSchedule, so chunk reuse is
        reflected; the scheduler divides the step's observed staging
        phase seconds into this to feed its measured-bandwidth EMA."""
        if self.mode != "stream":
            return 0
        try:
            p = plan_execution(self.job.geo, len(self.job.angles), 1,
                               self.memory)
        except Exception:
            return 0
        return p.comm.bytes_moved()

    @staticmethod
    def _phase_diff(after: Dict[str, float],
                    before: Dict[str, float]) -> Dict[str, float]:
        return {k: v - before.get(k, 0.0) for k, v in after.items()
                if v - before.get(k, 0.0) > 0.0}

    @property
    def total_steps(self) -> int:
        return max(1, self.job.n_iter) if self.alg.iterative else 1

    @property
    def iterations_done(self) -> int:
        return 0 if self._state is None else int(self._state.it)

    @property
    def started(self) -> bool:
        return self._state is not None

    @property
    def done(self) -> bool:
        return self.started and self.iterations_done >= self.total_steps

    def start(self, checkpoint: Optional[Dict[str, Any]] = None) -> None:
        """Resolve data, build the operator, init (or restore) the state."""
        tracer = obs.get_tracer()
        before = (tracer.thread_phase_seconds() if tracer.enabled else None)
        t0 = time.monotonic()
        with obs.context(job=self.job.job_id, **self.labels), \
                obs.span("init", "init", alg=self.job.algorithm,
                         mode=self.mode):
            proj = self.job.resolve_projections()
            op = _get_operator(self.job.geo, self.job.angles, self.mode,
                               self.alg.default_bp_weight, self.memory,
                               self.devices, backend=self.job.backend)
            kcfg = op.kernel_config()
            if kcfg:
                # calibration attrs: which (possibly autotuned) block
                # config this job's kernels compiled under
                obs.event("kernel-config", backend=op.backend_name, **kcfg)
            params = dict(self.job.params)
            if checkpoint is not None:
                # feed checkpointed scalars back through init so restore
                # does not recompute them (e.g. FISTA's power-iteration L)
                for k in self.alg.resume_params:
                    if k in checkpoint:
                        params[k] = checkpoint[k]
            state = self.alg.init(proj, self.job.geo, self.job.angles,
                                  op=op, **params)
            if checkpoint is not None:
                state = restore_state(self.alg, state, checkpoint)
            _block_on_state(state)
        self._state = state
        self.init_seconds = time.monotonic() - t0
        if before is not None:
            self._phase_delta = self._phase_diff(
                tracer.thread_phase_seconds(), before)

    def step(self) -> int:
        """Advance one outer iteration; returns iterations done so far.

        Blocks until the iteration's compute has actually finished (not
        just been dispatched), so the caller's ``dt`` around this call is
        honest compute time."""
        if self._state is None:
            raise RuntimeError(f"{self.job.job_id}: step() before start()")
        tracer = obs.get_tracer()
        if not tracer.enabled:
            self._state = self.alg.step(self._state)
            _block_on_state(self._state)
            return self.iterations_done
        # Trace path: ambient job/pod/device context tags every span the
        # operators open underneath.  Streamed jobs emit their own
        # h2d/compute/d2h leaf spans; plain (in-core) steps are wrapped in
        # one compute span so phase attribution covers them too.
        before = tracer.thread_phase_seconds()
        with obs.context(job=self.job.job_id, **self.labels):
            if self.mode == "plain":
                with obs.span("step", "compute", alg=self.job.algorithm,
                              it=self.iterations_done):
                    self._state = self.alg.step(self._state)
                    _block_on_state(self._state)
            else:
                self._state = self.alg.step(self._state)
                _block_on_state(self._state)
        self._phase_delta = self._phase_diff(
            tracer.thread_phase_seconds(), before)
        return self.iterations_done

    def checkpoint(self) -> Dict[str, Any]:
        """Host-side snapshot of the resumable state (for preemption)."""
        if self._state is None:
            raise RuntimeError(f"{self.job.job_id}: no state to checkpoint")
        return checkpoint_state(self.alg, self._state)

    def result(self) -> np.ndarray:
        return np.asarray(self.alg.finalize(self._state))

    def release(self) -> None:
        """Drop the state so device buffers can be reclaimed."""
        self._state = None
