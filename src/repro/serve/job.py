"""Job specification and lifecycle records for the serving layer.

A :class:`ReconJob` is the unit of work accepted by the scheduler: one
reconstruction (geometry + angles + projection data + algorithm + iteration
budget), annotated with a priority and an optional memory hint.  The
projection data may be given as a concrete array or as a zero-argument
callable (a *data ref*) that is resolved lazily only when the job is
admitted — queued jobs then cost no host memory.

:class:`JobRecord` is the scheduler's bookkeeping for one job: status,
timing, placement, preemption count, and (once finished) the result.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable, Dict, Optional, Union

import numpy as np

from ..core.geometry import ConeGeometry


class JobStatus(enum.Enum):
    PENDING = "pending"        # queued, not yet placed
    RUNNING = "running"        # placed on a device, being stepped
    PREEMPTED = "preempted"    # checkpointed + requeued by a higher prio job
    COMPLETED = "completed"
    FAILED = "failed"
    CANCELLED = "cancelled"
    STOLEN = "stolen"          # exported to another pod (terminal *here*)


_job_counter = itertools.count()


@dataclasses.dataclass
class ReconJob:
    """One reconstruction request.

    Parameters
    ----------
    algorithm : registry name (``repro.core.algorithms.stepwise.REGISTRY``):
        "cgls", "ossart", "sirt", "sart", "fista", "asd_pocs", "fdk", ...
    geo, angles : acquisition geometry and gantry angles.
    projections : ``(n_angles, nv, nu)`` array **or** a zero-arg callable
        returning it (lazy data ref, resolved at admission).
    n_iter : outer-iteration budget (ignored for direct algorithms).
    priority : higher values are scheduled first and may preempt lower ones.
    params : extra keyword arguments for the algorithm's ``init``.
    memory_hint_bytes : optional override of the planner's footprint
        estimate (0 = use the estimate).
    mode : force the execution mode ("plain" | "stream"); ``None`` lets
        the scheduler choose from the footprint vs. the device budget.
    backend : kernel backend for the job's operators ("ref" | "pallas");
        ``None`` = "auto" (per JAX backend — see
        :mod:`repro.core.backend`).
    deadline_seconds : SLO budget measured from submission (0 = none).  At
        admission the scheduler models the job's completion time from the
        observed init/step costs and *rejects* the job outright if the
        model says the deadline cannot be met — failing fast beats burning
        device time on a reconstruction that will be late anyway.
    """

    algorithm: str
    geo: ConeGeometry
    angles: np.ndarray
    projections: Union[np.ndarray, Callable[[], np.ndarray]]
    n_iter: int = 10
    priority: int = 0
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    memory_hint_bytes: int = 0
    mode: Optional[str] = None
    backend: Optional[str] = None
    deadline_seconds: float = 0.0
    job_id: str = ""

    def __post_init__(self):
        if not self.job_id:
            self.job_id = f"job-{next(_job_counter):05d}"
        self.angles = np.asarray(self.angles, np.float32)

    @property
    def n_angles(self) -> int:
        return len(self.angles)

    def resolve_projections(self) -> np.ndarray:
        if callable(self.projections):
            return np.asarray(self.projections())
        return np.asarray(self.projections)


@dataclasses.dataclass
class JobRecord:
    """Scheduler-side lifecycle record for one submitted job."""
    job: ReconJob
    seq: int                                  # submission order (FIFO tiebreak)
    status: JobStatus = JobStatus.PENDING
    submit_time: float = 0.0
    start_time: Optional[float] = None        # first admission
    end_time: Optional[float] = None
    iterations_done: int = 0
    preemptions: int = 0
    device: Optional[int] = None
    footprint_bytes: int = 0
    streamed: bool = False                    # routed through out-of-core path
    checkpoint: Optional[Dict[str, Any]] = None
    result: Optional[np.ndarray] = None
    error: Optional[str] = None

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-completion wall-clock seconds (None while in flight)."""
        if self.end_time is None:
            return None
        return self.end_time - self.submit_time

    @property
    def queue_wait(self) -> Optional[float]:
        if self.start_time is None:
            return None
        return self.start_time - self.submit_time

    @property
    def done(self) -> bool:
        return self.status in (JobStatus.COMPLETED, JobStatus.FAILED,
                               JobStatus.CANCELLED, JobStatus.STOLEN)
