"""Serving metrics: throughput, latency percentiles, device utilisation.

Wall-clock numbers are measured (``time.monotonic``); *modeled* numbers
additionally use the per-device busy clocks maintained by the pool, which
treat the pool's devices as executing in parallel — on a single-host CPU
test rig the devices are simulated, so the modeled makespan
(``max`` over device busy time) is the honest stand-in for real
multi-accelerator wall-clock, exactly like the paper's per-GPU timelines
(Fig 3/5).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


def percentile(xs: List[float], p: float) -> float:
    """Nearest-rank percentile (p in [0, 100]); 0.0 on empty input."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = max(0, min(len(s) - 1, int(round(p / 100.0 * (len(s) - 1)))))
    return s[k]


@dataclasses.dataclass
class ServeMetrics:
    """Counters + samples accumulated by one scheduler instance."""
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    cancelled: int = 0
    preemptions: int = 0
    steps: int = 0
    streamed_jobs: int = 0
    deadline_rejected: int = 0      # jobs refused by deadline admission
    stolen_out: int = 0             # parked jobs exported to another pod
    stolen_in: int = 0              # parked jobs imported from another pod

    # -- fleet gauges (maintained by MultiPodScheduler / Autoscaler; zero
    #    on a single-pod scheduler) --
    scale_up_events: int = 0        # pods added by the autoscaler
    scale_down_events: int = 0      # pods drained + retired
    pod_seconds: float = 0.0        # sum over pods of online wall time
    # (monotonic timestamp, live pod count) after each membership change —
    # the pods-online timeline; bounded by the number of scale events
    pods_online: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)

    step_seconds: List[float] = dataclasses.field(default_factory=list)
    latencies: List[float] = dataclasses.field(default_factory=list)
    queue_waits: List[float] = dataclasses.field(default_factory=list)

    # -- phase-attributed seconds (h2d / compute / d2h / compile / ...),
    #    fed from the obs tracer's span categories by the executor; empty
    #    unless tracing was enabled during the run (zero-overhead default)
    phase_seconds: Dict[str, float] = dataclasses.field(default_factory=dict)

    # -- cost-model calibration gauges (fed by the scheduler at the same
    #    sites that emit the modeled-vs-measured fleet events) --
    # measured host<->device bandwidth the scheduler prices transfers
    # with; None until a traced streamed step has been observed
    bandwidth_ema_bytes_per_s: Optional[float] = None
    # event kind ("admit" / "step") -> signed errors (measured - modeled
    # seconds); positive bias = the cost model is optimistic
    calibration_errors_s: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict)
    # largest single-job footprint the planner committed to a device —
    # the modeled side of the memory-margin gauge (the measured side
    # lives in the trace: repro.obs.calibration.memory_calibration)
    memory_modeled_peak_bytes: int = 0

    wall_start: Optional[float] = None
    wall_end: Optional[float] = None

    def record_step(self, seconds: float) -> None:
        self.steps += 1
        self.step_seconds.append(seconds)

    def record_phases(self, phases: Dict[str, float]) -> None:
        """Fold one step's (or init's) span-category seconds in."""
        for k, v in phases.items():
            self.phase_seconds[k] = self.phase_seconds.get(k, 0.0) + v

    def record_pods_online(self, t: float, count: int) -> None:
        self.pods_online.append((t, count))

    def record_completion(self, latency: float, queue_wait: float) -> None:
        self.completed += 1
        self.latencies.append(latency)
        self.queue_waits.append(queue_wait)

    def record_calibration(self, kind: str, modeled: Optional[float],
                           measured: Optional[float]) -> None:
        """Fold one modeled-vs-measured observation; one-sided samples
        (cold EMAs model ``None``) are skipped, matching the ledger."""
        if modeled is None or measured is None:
            return
        self.calibration_errors_s.setdefault(kind, []).append(
            measured - modeled)

    # ---- summaries ---------------------------------------------------------

    @property
    def wall_seconds(self) -> float:
        if self.wall_start is None or self.wall_end is None:
            return 0.0
        return self.wall_end - self.wall_start

    @property
    def busy_seconds(self) -> float:
        """Total compute time across all steps (serial-equivalent time)."""
        return sum(self.step_seconds)

    def summary(self, device_busy: Optional[List[float]] = None) -> Dict:
        """Aggregate view; pass the pool's per-device busy clocks to get the
        modeled (device-parallel) makespan and throughput."""
        out = {
            "submitted": self.submitted,
            "completed": self.completed,
            "failed": self.failed,
            "preemptions": self.preemptions,
            "deadline_rejected": self.deadline_rejected,
            "steps": self.steps,
            "streamed_jobs": self.streamed_jobs,
            "stolen_out": self.stolen_out,
            "stolen_in": self.stolen_in,
            "wall_seconds": self.wall_seconds,
            "busy_seconds": self.busy_seconds,
            "latency_p50": percentile(self.latencies, 50),
            "latency_p95": percentile(self.latencies, 95),
            "queue_wait_p50": percentile(self.queue_waits, 50),
            "jobs_per_sec_wall": (self.completed / self.wall_seconds
                                  if self.wall_seconds > 0 else 0.0),
            "scale_up_events": self.scale_up_events,
            "scale_down_events": self.scale_down_events,
            "pod_seconds": self.pod_seconds,
            "pods_online": list(self.pods_online),
            "pods_online_peak": (max(n for _, n in self.pods_online)
                                 if self.pods_online else 0),
            "phase_seconds": dict(self.phase_seconds),
            "bandwidth_ema_bytes_per_s": self.bandwidth_ema_bytes_per_s,
            "staging_seconds": {
                k: self.phase_seconds.get(k, 0.0)
                for k in ("h2d", "prefetch", "d2h")},
            "memory_modeled_peak_bytes": self.memory_modeled_peak_bytes,
            "calibration": {
                kind: {
                    "samples": len(errs),
                    "bias_s": sum(errs) / len(errs),
                    "abs_p95_s": percentile([abs(e) for e in errs], 95),
                }
                for kind, errs in sorted(self.calibration_errors_s.items())
                if errs},
        }
        if device_busy is not None:
            makespan = max(device_busy) if device_busy else 0.0
            out["modeled_makespan_seconds"] = makespan
            out["device_busy_seconds"] = list(device_busy)
            out["jobs_per_sec_modeled"] = (self.completed / makespan
                                           if makespan > 0 else 0.0)
        return out


def merge_metrics(parts: List["ServeMetrics"]) -> "ServeMetrics":
    """Fleet-level view over per-pod metrics: counters sum, samples
    concatenate, and the wall-clock window spans the earliest start to the
    latest end across pods.

    A stolen job is ``submitted`` on its original pod and ``completed`` on
    the thief, so summed counters stay one-per-job; ``stolen_in`` /
    ``stolen_out`` cancel out in aggregate and are reported so the
    imbalance the stealing corrected stays visible per pod."""
    out = ServeMetrics()
    for m in parts:
        out.submitted += m.submitted
        out.completed += m.completed
        out.failed += m.failed
        out.cancelled += m.cancelled
        out.preemptions += m.preemptions
        out.steps += m.steps
        out.streamed_jobs += m.streamed_jobs
        out.deadline_rejected += m.deadline_rejected
        out.stolen_out += m.stolen_out
        out.stolen_in += m.stolen_in
        out.scale_up_events += m.scale_up_events
        out.scale_down_events += m.scale_down_events
        out.pod_seconds += m.pod_seconds
        out.pods_online.extend(m.pods_online)
        out.record_phases(m.phase_seconds)
        for kind, errs in m.calibration_errors_s.items():
            out.calibration_errors_s.setdefault(kind, []).extend(errs)
        out.memory_modeled_peak_bytes = max(out.memory_modeled_peak_bytes,
                                            m.memory_modeled_peak_bytes)
        out.step_seconds.extend(m.step_seconds)
        out.latencies.extend(m.latencies)
        out.queue_waits.extend(m.queue_waits)
        if m.wall_start is not None:
            out.wall_start = (m.wall_start if out.wall_start is None
                              else min(out.wall_start, m.wall_start))
        if m.wall_end is not None:
            out.wall_end = (m.wall_end if out.wall_end is None
                            else max(out.wall_end, m.wall_end))
    # fleet view of the measured bandwidth: mean over the pods that have
    # one (each pod's EMA stays the authoritative pricing input locally)
    bws = [m.bandwidth_ema_bytes_per_s for m in parts
           if m.bandwidth_ema_bytes_per_s is not None]
    if bws:
        out.bandwidth_ema_bytes_per_s = sum(bws) / len(bws)
    out.pods_online.sort()     # one chronological fleet timeline
    return out
