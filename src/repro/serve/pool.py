"""Multi-pod device pools: one scheduler per host group, mesh-aware routing.

A single :class:`~repro.serve.scheduler.Scheduler` owns one
:class:`~repro.serve.scheduler.DevicePool` — one host's devices.  A site
with several host groups (the paper's "arbitrarily large ... on whatever
devices a site has", scaled past one machine) runs one pool *per group*:
each group keeps its own scheduler, queue and device ledger, and only two
things cross the boundary — a routing decision at submit time, and parked
jobs moved by work stealing (:mod:`repro.serve.steal`).

Topology comes from :mod:`repro.launch.mesh`: a production mesh with a
leading ``"pod"`` axis yields one :class:`Pod` per pod index
(:func:`pods_from_mesh`), while tests and single-host rigs describe
simulated pods with :class:`PodSpec` (device count + memory budget —
pods may be *heterogeneous*, e.g. one group of large-memory devices next
to many small ones).

Routing is mesh-aware in the planner sense: for every pod the job's
footprint is evaluated under *that pod's* memory model
(``plan_forward`` / ``plan_backward``), so the same volume may be
resident on a large-memory pod but need N streaming slabs on a small
one.  :meth:`MultiPodScheduler.submit` models the completion makespan on
each feasible pod — current per-device backlog plus the job's modeled
cost, where a streaming job's cost scales with its slab-pass count under
that pod's budget — and places the job on the pod that minimises it.
Oversized jobs therefore gravitate to the pod whose streaming plan is
cheapest, and small jobs to whichever pod is idlest.

Quick start (two simulated pods, second one bigger)::

    pods = [Pod(PodSpec("small", n_devices=2, memory=MemoryModel(...))),
            Pod(PodSpec("big", n_devices=1, memory=MemoryModel(...)))]
    mps = MultiPodScheduler(pods, transfer_dir="/ckpt/steal")
    jid = mps.submit(job)              # routed by modeled makespan
    mps.run()                          # cooperative; steals between rounds
    image = mps.result(jid)

For true thread-per-device execution drive the same object with
:class:`repro.serve.driver.MultiPodDriver`.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from ..core.algorithms.stepwise import get_algorithm
from ..core.splitting import MemoryModel
from .job import JobRecord, ReconJob
from .metrics import ServeMetrics, merge_metrics
from .scheduler import (DevicePool, Scheduler, estimate_job_footprint,
                        modeled_step_passes)
from .steal import (StealPolicy, effective_units, fleet_units, pod_load,
                    steal_pass)


@dataclasses.dataclass(frozen=True)
class PodSpec:
    """Description of one pod (host group) for pool construction.

    ``jax_devices`` pins the pod to real devices (one slot each;
    overrides ``n_devices``); without it the pod is simulated — slots
    with a byte budget only, which is how tests and benchmarks drive a
    "multi-host" fleet on one machine."""
    name: str
    n_devices: int = 1
    memory: MemoryModel = MemoryModel()
    jax_devices: Optional[Tuple[Any, ...]] = None
    max_jobs_per_device: Optional[int] = None
    placement: str = "spread"


class Pod:
    """One host group: a :class:`DevicePool` plus its :class:`Scheduler`."""

    def __init__(self, spec: PodSpec, guard=None,
                 snapshot_dir: Optional[str] = None):
        self.spec = spec
        self.pool = DevicePool(
            n_devices=spec.n_devices, memory=spec.memory,
            jax_devices=spec.jax_devices,
            max_jobs_per_device=spec.max_jobs_per_device,
            policy=spec.placement)
        self.scheduler = Scheduler(pool=self.pool, guard=guard,
                                   snapshot_dir=snapshot_dir)

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def n_devices(self) -> int:
        return len(self.pool.slots)

    def __repr__(self) -> str:
        return (f"Pod({self.name!r}, devices={self.n_devices}, "
                f"usable={self.pool.memory.usable}B)")


def pods_from_mesh(mesh, memory: Optional[MemoryModel] = None,
                   pod_axis: str = "pod", **spec_kwargs) -> List[Pod]:
    """One :class:`Pod` per group along the mesh's ``pod_axis`` (the whole
    mesh as a single pod if the axis is absent), each pod's pool holding
    one slot per device in its group."""
    from ..launch.mesh import pod_device_groups
    groups = pod_device_groups(mesh, pod_axis)
    return [Pod(PodSpec(name=f"pod{i}", memory=memory or MemoryModel(),
                        jax_devices=tuple(group), **spec_kwargs))
            for i, group in enumerate(groups)]


def modeled_job_seconds(job: ReconJob, pod: Pod,
                        unit: Optional[float] = None,
                        init: Optional[float] = None) -> Optional[float]:
    """Modeled cost of running ``job`` on ``pod``, or None if the job can
    never fit there (not even streamed).

    The unit cost is the pod's observed per-pass step EMA, scaled by
    :func:`repro.serve.scheduler.modeled_step_passes` — the slab-pass
    multiplier under *that pod's* budget, so a pod with more memory per
    device models (and is) cheaper for oversized volumes.  ``unit`` /
    ``init`` supply the fleet-wide fallback for a pod with no
    observations yet (see :func:`repro.serve.steal.fleet_units`); with
    no fallback either, a cold pod costs 1.0 per pass."""
    try:
        fp = estimate_job_footprint(job, pod.pool.memory)
        passes = modeled_step_passes(job, pod.pool.memory)
    except Exception:
        return None
    if fp.bytes_on_device > pod.pool.fits_nowhere_bytes:
        return None
    alg = get_algorithm(job.algorithm)
    iters = max(1, job.n_iter) if alg.iterative else 1
    unit, init = effective_units(pod.scheduler, unit, init)
    if unit is None:
        unit = 1.0
    if init is None:
        init = 0.0
    return init + iters * passes * unit


class MultiPodScheduler:
    """Routes jobs across pods and (optionally) rebalances them by work
    stealing.

    Parameters
    ----------
    pods : the pod set (see :class:`Pod`, :func:`pods_from_mesh`).
    steal : enable work stealing between cooperative rounds (and in
        :class:`~repro.serve.driver.MultiPodDriver`'s steal thread).
    transfer_dir : directory jobs move through (manifest + COMMIT, the
        durable-snapshot layout).  On a real cluster this is storage all
        host groups mount; defaults to a scratch tempdir.
    steal_policy : thresholds, see :class:`repro.serve.steal.StealPolicy`.
    data_refs : job-id -> callable map letting *lazy* (data-ref) jobs be
        re-resolved on the thief pod; lazy jobs without an entry are
        never stolen.
    """

    def __init__(self, pods: Sequence[Pod], steal: bool = True,
                 transfer_dir: Optional[str] = None,
                 steal_policy: StealPolicy = StealPolicy(),
                 data_refs: Optional[Dict[str, Callable]] = None):
        if not pods:
            raise ValueError("MultiPodScheduler needs at least one pod")
        names = [p.name for p in pods]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pod names: {names}")
        self.pods = list(pods)
        self.steal = steal and len(self.pods) > 1
        self.transfer_dir = transfer_dir or tempfile.mkdtemp(
            prefix="repro-steal-")
        for p in self.pods:
            sd = p.scheduler.snapshot_dir
            if sd is not None and (os.path.abspath(sd)
                                   == os.path.abspath(self.transfer_dir)):
                raise ValueError(
                    f"transfer_dir {self.transfer_dir!r} aliases pod "
                    f"{p.name!r}'s snapshot_dir; hand-offs and durable "
                    f"snapshots must use distinct directories")
        self.steal_policy = steal_policy
        self.data_refs = dict(data_refs or {})
        self.stolen_jobs: List[str] = []      # every job a pass moved
        self._home: Dict[str, str] = {}       # job_id -> submit-time pod
        # a job mid-transfer (exported from the victim, not yet imported
        # by the thief) is in *no* scheduler; the flag + generation
        # counter keep `idle` honest so a driver cannot observe the
        # fleet as done and stop while the last job is on the wire
        self._stealing = threading.Event()
        self._steal_gen = 0

    # ---- submission / routing ---------------------------------------------

    def _pod_by(self, pod: Union[int, str, Pod]) -> Pod:
        if isinstance(pod, Pod):
            return pod
        if isinstance(pod, int):
            return self.pods[pod]
        for p in self.pods:
            if p.name == pod:
                return p
        raise KeyError(f"no pod named {pod!r} "
                       f"(have {[p.name for p in self.pods]})")

    def route(self, job: ReconJob) -> Pod:
        """Pod with the minimal modeled completion makespan for ``job``:
        per-device backlog + the job's modeled cost under that pod's
        topology, all on the fleet-shared unit scale (a cold pod borrows
        the warm pods' EMAs, so an idle new pod is not mispriced against
        a warm loaded one; ties: fewer devices busy, then pod order).
        If no pod can ever hold the job, the largest-memory pod is
        returned so its scheduler fails the job with the canonical
        budget error."""
        unit, init = fleet_units(self.pods)
        best: Optional[Tuple[float, int, int]] = None
        chosen: Optional[Pod] = None
        for i, pod in enumerate(self.pods):
            cost = modeled_job_seconds(job, pod, unit=unit, init=init)
            if cost is None:
                continue
            backlog = pod_load(pod.scheduler, pod.n_devices,
                               unit=unit, init=init)
            busy = sum(1 for s in pod.pool.slots if s.jobs)
            score = (backlog + cost, busy, i)
            if best is None or score < best:
                best, chosen = score, pod
        if chosen is None:
            return max(self.pods, key=lambda p: p.pool.memory.usable)
        return chosen

    def submit(self, job: ReconJob,
               pod: Optional[Union[int, str, Pod]] = None) -> str:
        """Submit ``job``, routed by modeled makespan — or pinned to
        ``pod`` (index / name / object), which is how static per-pod
        partitioning (tenant affinity) is expressed."""
        target = self._pod_by(pod) if pod is not None else self.route(job)
        jid = target.scheduler.submit(job)
        self._home[jid] = target.name
        return jid

    # ---- lookups across pods ----------------------------------------------

    def owner(self, job_id: str) -> Pod:
        """Pod currently holding the job's record (stealing moves it)."""
        for pod in self.pods:
            if job_id in pod.scheduler.records:
                return pod
        raise KeyError(f"unknown job {job_id}")

    def home(self, job_id: str) -> str:
        """Name of the pod the job was *submitted* to (never changes)."""
        return self._home[job_id]

    def record(self, job_id: str) -> JobRecord:
        return self.owner(job_id).scheduler.records[job_id]

    def result(self, job_id: str):
        return self.owner(job_id).scheduler.result(job_id)

    @property
    def idle(self) -> bool:
        # valid only if no steal pass was in flight at any point during
        # the pod scan: a pass could move a job from a pod we check
        # *later* to one we checked *earlier*, making every pod look
        # idle while the job is on the wire.  The flag covers an active
        # pass; the generation counter covers a pass that started and
        # finished entirely within our scan.
        gen = self._steal_gen
        if self._stealing.is_set():
            return False
        result = all(p.scheduler.idle for p in self.pods)
        if self._stealing.is_set() or self._steal_gen != gen:
            return False
        return result

    # ---- execution ---------------------------------------------------------

    def steal_pass(self) -> List[str]:
        """One explicit rebalancing pass (the cooperative loop and the
        threaded driver both call this).  Returns moved job ids."""
        if not self.steal:
            return []
        self._stealing.set()
        self._steal_gen += 1
        try:
            moved = steal_pass(self.pods, self.transfer_dir,
                               data_refs=self.data_refs,
                               policy=self.steal_policy)
        finally:
            self._stealing.clear()
        self.stolen_jobs.extend(moved)
        return moved

    def run(self, max_rounds: Optional[int] = None) -> ServeMetrics:
        """Cooperative fleet loop: each round steps every pod's scheduler
        one quantum, then runs a steal pass so idle pods pick up other
        pods' parked surplus.  Single-threaded (one pod computes at a
        time); use :class:`repro.serve.driver.MultiPodDriver` for real
        per-device overlap.  Returns the merged fleet metrics."""
        for pod in self.pods:
            if pod.scheduler.metrics.wall_start is None:
                pod.scheduler.metrics.wall_start = time.monotonic()
        rounds = 0
        while not self.idle:
            if max_rounds is not None and rounds >= max_rounds:
                break
            for pod in self.pods:
                pod.scheduler.step_quantum()
            self.steal_pass()
            rounds += 1
        now = time.monotonic()
        for pod in self.pods:
            pod.scheduler.metrics.wall_end = now
        return self.metrics()

    # ---- reporting ---------------------------------------------------------

    def metrics(self) -> ServeMetrics:
        return merge_metrics([p.scheduler.metrics for p in self.pods])

    def summary(self) -> Dict:
        """Fleet summary (merged counters, fleet-wide makespan over every
        device busy clock) plus a per-pod breakdown."""
        busy: List[float] = []
        for pod in self.pods:
            busy.extend(pod.pool.busy_clocks())
        out = self.metrics().summary(device_busy=busy)
        out["pods"] = {p.name: p.scheduler.summary() for p in self.pods}
        out["jobs_stolen"] = len(self.stolen_jobs)
        return out
