"""Multi-pod device pools: one scheduler per host group, mesh-aware routing.

A single :class:`~repro.serve.scheduler.Scheduler` owns one
:class:`~repro.serve.scheduler.DevicePool` — one host's devices.  A site
with several host groups (the paper's "arbitrarily large ... on whatever
devices a site has", scaled past one machine) runs one pool *per group*:
each group keeps its own scheduler, queue and device ledger, and only two
things cross the boundary — a routing decision at submit time, and parked
jobs moved by work stealing (:mod:`repro.serve.steal`).

Topology comes from :mod:`repro.launch.mesh`: a production mesh with a
leading ``"pod"`` axis yields one :class:`Pod` per pod index
(:func:`pods_from_mesh`), while tests and single-host rigs describe
simulated pods with :class:`PodSpec` (device count + memory budget —
pods may be *heterogeneous*, e.g. one group of large-memory devices next
to many small ones).

Routing is mesh-aware in the planner sense: for every pod the job's
footprint is evaluated under *that pod's* memory model
(``plan_forward`` / ``plan_backward``), so the same volume may be
resident on a large-memory pod but need N streaming slabs on a small
one.  :meth:`MultiPodScheduler.submit` models the completion makespan on
each feasible pod — current per-device backlog plus the job's modeled
cost, where a streaming job's cost scales with its slab-pass count under
that pod's budget — and places the job on the pod that minimises it.
Oversized jobs therefore gravitate to the pod whose streaming plan is
cheapest, and small jobs to whichever pod is idlest.

Quick start (two simulated pods, second one bigger)::

    pods = [Pod(PodSpec("small", n_devices=2, memory=MemoryModel(...))),
            Pod(PodSpec("big", n_devices=1, memory=MemoryModel(...)))]
    mps = MultiPodScheduler(pods, transfer_dir="/ckpt/steal")
    jid = mps.submit(job)              # routed by modeled makespan
    mps.run()                          # cooperative; steals between rounds
    image = mps.result(jid)

For true thread-per-device execution drive the same object with
:class:`repro.serve.driver.MultiPodDriver`.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from ..core.algorithms.stepwise import get_algorithm
from ..core.splitting import MemoryModel
from ..obs import fleet_event
from ..obs.calibration import CalibrationLedger
from .job import JobRecord, ReconJob
from .metrics import ServeMetrics, merge_metrics
from .scheduler import (DevicePool, Scheduler, _TERMINAL,
                        _atomic_write_json, _consume_transfer_copy)
from .steal import (StealPolicy, effective_units, fleet_units, pod_load,
                    steal_pass)

#: membership manifest at the root of a fleet snapshot directory
FLEET_MANIFEST = "fleet.json"


class DuplicatePodName(ValueError):
    """A pod name is already used by a live or retired pod.

    Distinct from plain :class:`ValueError` so retry loops that probe
    for a free name (``Autoscaler._next_pod``) can catch *exactly* the
    collision and surface every other admission failure."""


@dataclasses.dataclass(frozen=True)
class PodSpec:
    """Description of one pod (host group) for pool construction.

    ``jax_devices`` pins the pod to real devices (one slot each;
    overrides ``n_devices``); without it the pod is simulated — slots
    with a byte budget only, which is how tests and benchmarks drive a
    "multi-host" fleet on one machine."""
    name: str
    n_devices: int = 1
    memory: MemoryModel = MemoryModel()
    jax_devices: Optional[Tuple[Any, ...]] = None
    max_jobs_per_device: Optional[int] = None
    placement: str = "spread"


class Pod:
    """One host group: a :class:`DevicePool` plus its :class:`Scheduler`."""

    def __init__(self, spec: PodSpec, guard=None,
                 snapshot_dir: Optional[str] = None):
        self.spec = spec
        self.pool = DevicePool(
            n_devices=spec.n_devices, memory=spec.memory,
            jax_devices=spec.jax_devices,
            max_jobs_per_device=spec.max_jobs_per_device,
            policy=spec.placement)
        self.scheduler = Scheduler(pool=self.pool, guard=guard,
                                   snapshot_dir=snapshot_dir,
                                   name=spec.name)
        # set by the autoscaler while the pod is being emptied: routing
        # and stealing skip a draining pod, so no new work lands on it
        self.draining = False

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def n_devices(self) -> int:
        return len(self.pool.slots)

    def __repr__(self) -> str:
        return (f"Pod({self.name!r}, devices={self.n_devices}, "
                f"usable={self.pool.memory.usable}B)")


@dataclasses.dataclass
class RetiredPodSummary:
    """Compact tombstone of a retired pod after its TTL expired.

    A retired :class:`Pod` keeps its whole scheduler — records with
    result arrays, executor caches — so ``owner()`` / ``result()`` stay
    answerable for jobs that completed there.  A server that scales down
    thousands of times would grow without bound, so after
    ``retired_pod_ttl_seconds`` the pod is folded into this summary:
    counters (:class:`ServeMetrics`), per-device busy clocks and each
    job's terminal status survive (fleet metrics and summaries stay
    exact); the result arrays and the scheduler are dropped.
    """
    name: str
    retired_at: float
    n_devices: int
    metrics: ServeMetrics
    device_busy: List[float]
    job_statuses: Dict[str, str]     # job_id -> terminal status value

    def summary(self) -> Dict:
        out = self.metrics.summary(device_busy=self.device_busy)
        out["compacted"] = True
        return out


def pods_from_mesh(mesh, memory: Optional[MemoryModel] = None,
                   pod_axis: str = "pod", **spec_kwargs) -> List[Pod]:
    """One :class:`Pod` per group along the mesh's ``pod_axis`` (the whole
    mesh as a single pod if the axis is absent), each pod's pool holding
    one slot per device in its group."""
    from ..launch.mesh import pod_device_groups
    groups = pod_device_groups(mesh, pod_axis)
    return [Pod(PodSpec(name=f"pod{i}", memory=memory or MemoryModel(),
                        jax_devices=tuple(group), **spec_kwargs))
            for i, group in enumerate(groups)]


def modeled_job_seconds(job: ReconJob, pod: Pod,
                        unit: Optional[float] = None,
                        init: Optional[float] = None) -> Optional[float]:
    """Modeled cost of running ``job`` on ``pod``, or None if the job can
    never fit there (not even streamed).

    The unit cost is the pod's observed per-pass step EMA, scaled by the
    job's slab-pass multiplier under *that pod's* budget, so a pod with
    more memory per device models (and is) cheaper for oversized
    volumes.  Footprint and multiplier are read off the scheduler's
    memoized plan (:meth:`Scheduler.job_footprint` /
    :meth:`Scheduler.job_passes`, both backed by the shared
    :func:`repro.core.plan.plan` memo) — routing a submission across N
    pods re-prices, never re-plans.  ``unit`` / ``init`` supply the
    fleet-wide fallback for a pod with no observations yet (see
    :func:`repro.serve.steal.fleet_units`); with no fallback either, a
    cold pod costs 1.0 per pass."""
    try:
        fp = pod.scheduler.job_footprint(job)
    except Exception:
        return None
    passes = pod.scheduler.job_passes(job)
    if fp.bytes_on_device > pod.pool.fits_nowhere_bytes:
        return None
    alg = get_algorithm(job.algorithm)
    iters = max(1, job.n_iter) if alg.iterative else 1
    unit, init = effective_units(pod.scheduler, unit, init)
    if unit is None:
        unit = 1.0
    if init is None:
        init = 0.0
    # streamed jobs also pay the schedule-priced staging time per
    # iteration once the pod has measured a bandwidth (0.0 before)
    return init + iters * (passes * unit
                           + pod.scheduler.modeled_transfer_seconds(job))


class MultiPodScheduler:
    """Routes jobs across pods and (optionally) rebalances them by work
    stealing.  Membership is *dynamic*: pods can be added and retired at
    runtime (:meth:`add_pod` / :meth:`remove_pod`, driven by
    :class:`repro.serve.autoscale.Autoscaler`), and every routing /
    stealing / reporting pass iterates a snapshot of the pod list taken
    under the fleet lock.

    Parameters
    ----------
    pods : the initial pod set (see :class:`Pod`, :func:`pods_from_mesh`).
    steal : enable work stealing between cooperative rounds (and in
        :class:`~repro.serve.driver.MultiPodDriver`'s steal thread).
    transfer_dir : directory jobs move through (manifest + COMMIT, the
        durable-snapshot layout).  On a real cluster this is storage all
        host groups mount; defaults to a scratch tempdir.
    steal_policy : thresholds, see :class:`repro.serve.steal.StealPolicy`.
    data_refs : job-id -> callable map letting *lazy* (data-ref) jobs be
        re-resolved on the thief pod; lazy jobs without an entry are
        never stolen.
    snapshot_root : fleet-level durable snapshot directory.  Each pod
        gets its own subdirectory (``<root>/pods/<pod_name>``) as its
        scheduler's ``snapshot_dir``, and a ``fleet.json`` membership
        manifest is kept at the root — :meth:`snapshot_fleet` /
        :meth:`drain_fleet` persist the whole fleet and
        :meth:`restore_fleet` rebuilds it (membership *and* parked jobs)
        after process death.
    retired_pod_ttl_seconds : fold a retired pod's full records into a
        compact :class:`RetiredPodSummary` once it has been retired this
        long (``None`` = keep forever).  Counters, busy clocks and job
        statuses survive compaction; result arrays do not — a long-lived
        autoscaled server stays bounded no matter how often it scales
        down.  Compaction runs opportunistically on every
        :meth:`remove_pod` / :meth:`metrics` / :meth:`summary` call (or
        explicitly via :meth:`compact_retired`).
    """

    def __init__(self, pods: Sequence[Pod], steal: bool = True,
                 transfer_dir: Optional[str] = None,
                 steal_policy: StealPolicy = StealPolicy(),
                 data_refs: Optional[Dict[str, Callable]] = None,
                 snapshot_root: Optional[str] = None,
                 retired_pod_ttl_seconds: Optional[float] = None):
        if not pods:
            raise ValueError("MultiPodScheduler needs at least one pod")
        names = [p.name for p in pods]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pod names: {names}")
        self.steal = steal
        self.transfer_dir = transfer_dir or tempfile.mkdtemp(
            prefix="repro-steal-")
        self.snapshot_root = snapshot_root
        self.steal_policy = steal_policy
        self.data_refs = dict(data_refs or {})
        self.stolen_jobs: List[str] = []      # every job a pass moved
        self.restored_jobs: List[str] = []    # filled by restore_fleet
        self.recovered_jobs: List[str] = []   # filled by recover_transfers
        self._home: Dict[str, str] = {}       # job_id -> submit-time pod
        # fleet lock: guards pod membership (add/remove), the retired
        # list, and the pod-seconds ledger.  Every reader takes a
        # snapshot (`pods_snapshot`) instead of iterating `self.pods`
        # while another thread mutates it.
        self._fleet_lock = threading.RLock()
        # manifest writes run *outside* the fleet lock (disk I/O must
        # not serialize submissions); the generation counter makes the
        # race benign — a writer that captured older membership than
        # what already landed skips its write
        self._manifest_lock = threading.Lock()
        self._manifest_gen = 0        # bumped under the fleet lock
        self._manifest_written = 0    # guarded by the manifest lock
        # latest captured-but-unwritten (gen, spec); guarded by the
        # manifest lock.  Paths that mutate membership while already
        # holding the fleet lock re-entrantly (autoscaler scale-up from
        # submit) only *mark* and leave the flush to their outermost
        # caller, so the disk write never runs with the fleet lock held.
        self._pending_manifest: Optional[Tuple[int, Dict]] = None
        self.pods: List[Pod] = []
        self.retired_pods: List[Pod] = []
        self.retired_pod_ttl_seconds = retired_pod_ttl_seconds
        self.retired_summaries: List[RetiredPodSummary] = []
        self._retired_at: Dict[str, float] = {}
        # fleet gauges: scale events + pods-online timeline + the
        # *retired* pods' accumulated pod-seconds (live pods' seconds are
        # added on the fly in `metrics()`)
        self.fleet_metrics = ServeMetrics()
        self._pod_started: Dict[str, float] = {}
        # set by Autoscaler so `submit` can grow the fleet for a job that
        # fits no live pod (the `fits_nowhere_bytes` signal)
        self.autoscaler = None
        # a job mid-transfer (exported from the victim, not yet imported
        # by the thief) is in *no* scheduler; the flag + generation
        # counter keep `idle` honest so a driver cannot observe the
        # fleet as done and stop while the last job is on the wire.
        # Scale-down drains move jobs the same way and share the guard.
        self._stealing = threading.Event()
        self._steal_gen = 0
        now = time.monotonic()
        for p in pods:
            self._admit_pod(p, now)
        self.fleet_metrics.record_pods_online(now, len(self.pods))
        self._write_fleet_manifest()

    # ---- dynamic membership ------------------------------------------------

    def _admit_pod(self, pod: Pod, now: float) -> None:
        """Register one pod (fleet lock held by the caller where it
        matters): wire its snapshot subdirectory, check transfer-dir
        aliasing, start its pod-seconds clock."""
        if self.snapshot_root is not None and \
                pod.scheduler.snapshot_dir is None:
            pod.scheduler.snapshot_dir = os.path.join(
                self.snapshot_root, "pods", pod.name)
        sd = pod.scheduler.snapshot_dir
        if sd is not None and (os.path.abspath(sd)
                               == os.path.abspath(self.transfer_dir)):
            raise ValueError(
                f"transfer_dir {self.transfer_dir!r} aliases pod "
                f"{pod.name!r}'s snapshot_dir; hand-offs and durable "
                f"snapshots must use distinct directories")
        self.pods.append(pod)
        self._pod_started[pod.name] = now

    def pods_snapshot(self, live_only: bool = True) -> List[Pod]:
        """Membership snapshot under the fleet lock — the list every
        routing / stealing / reporting pass iterates.  With
        ``live_only`` (default) draining pods are excluded: no new work
        may land on a pod that is being emptied."""
        with self._fleet_lock:
            if live_only:
                return [p for p in self.pods if not p.draining]
            return list(self.pods)

    def add_pod(self, pod: Pod, flush_manifest: bool = True) -> Pod:
        """Grow the fleet at runtime (the autoscaler's scale-up).  The
        new pod is immediately visible to routing and stealing; a
        threaded fleet driver picks it up on its next membership sync.
        Names must be unique across live *and* retired pods (retired
        pods keep their completed-job records and their slice of the
        pod-seconds ledger) — collisions raise :class:`DuplicatePodName`.

        ``flush_manifest=False`` defers the manifest disk write to a
        later :meth:`_flush_manifest` — callers already holding the
        (re-entrant) fleet lock, like the autoscaler's scale-up, pass
        this so the I/O never runs with the lock held."""
        with self._fleet_lock:
            taken = {p.name for p in self.pods}
            taken.update(p.name for p in self.retired_pods)
            taken.update(s.name for s in self.retired_summaries)
            if pod.name in taken:
                raise DuplicatePodName(
                    f"pod name {pod.name!r} already used")
            self._admit_pod(pod, time.monotonic())
            self.fleet_metrics.record_pods_online(time.monotonic(),
                                                  len(self.pods))
            fleet_event("pod-add", pod=pod.name, n_pods=len(self.pods))
            self._mark_manifest_dirty()
        # manifest I/O outside the lock: scale_up_for runs add_pod from
        # inside `submit`, and a disk write under the fleet lock would
        # serialize every tenant's submission behind it
        if flush_manifest:
            self._flush_manifest()
        return pod

    def remove_pod(self, pod: Union[str, Pod]) -> Pod:
        """Retire an *empty* pod (the autoscaler's scale-down calls this
        after the drain moved every job to survivors).  The pod keeps
        its scheduler (completed-job records stay queryable through
        :meth:`owner` / :meth:`result`) but leaves the routing set, and
        its online time is folded into the pod-seconds ledger."""
        with self._fleet_lock:
            target = pod if isinstance(pod, Pod) else self._pod_by(pod)
            if not target.scheduler.idle:
                raise ValueError(
                    f"remove_pod: pod {target.name!r} still holds work "
                    f"(drain it first)")
            self.pods.remove(target)
            self.retired_pods.append(target)
            now = time.monotonic()
            self._retired_at[target.name] = now
            started = self._pod_started.pop(target.name, now)
            self.fleet_metrics.pod_seconds += now - started
            if target.scheduler.metrics.wall_end is None:
                target.scheduler.metrics.wall_end = now
            self.fleet_metrics.record_pods_online(now, len(self.pods))
            fleet_event("pod-remove", pod=target.name,
                        n_pods=len(self.pods))
            self._mark_manifest_dirty()
        self.compact_retired()
        self._flush_manifest()         # I/O outside the lock (see add_pod)
        return target

    def compact_retired(self, now: Optional[float] = None) -> int:
        """Fold retired pods whose TTL has expired into
        :class:`RetiredPodSummary` tombstones (see
        ``retired_pod_ttl_seconds``); returns how many pods were folded.
        After compaction a pod's job *results* are gone — :meth:`owner` /
        :meth:`result` raise a KeyError naming the compaction — but its
        counters, busy clocks and job statuses stay in the fleet
        metrics/summary forever."""
        if self.retired_pod_ttl_seconds is None:
            return 0
        now = time.monotonic() if now is None else now
        cutoff = now - self.retired_pod_ttl_seconds
        with self._fleet_lock:
            fold = [p for p in self.retired_pods
                    if self._retired_at.get(p.name, now) <= cutoff]
            for pod in fold:
                self.retired_pods.remove(pod)
                self.retired_summaries.append(RetiredPodSummary(
                    name=pod.name,
                    retired_at=self._retired_at.pop(pod.name, now),
                    n_devices=pod.n_devices,
                    metrics=pod.scheduler.metrics,
                    device_busy=list(pod.pool.busy_clocks()),
                    job_statuses={
                        jid: rec.status.value
                        for jid, rec in pod.scheduler.records.items()}))
        return len(fold)

    def record_scale_event(self, direction: str) -> None:
        with self._fleet_lock:
            if direction == "up":
                self.fleet_metrics.scale_up_events += 1
            elif direction == "down":
                self.fleet_metrics.scale_down_events += 1
            else:
                raise ValueError(f"unknown scale direction {direction!r}")

    @contextlib.contextmanager
    def transfer_guard(self):
        """Mark a job hand-off (steal or drain) in flight so
        :attr:`idle` cannot report "all done" while a job is on the wire
        between two schedulers."""
        self._stealing.set()
        self._steal_gen += 1
        try:
            yield
        finally:
            self._stealing.clear()

    # ---- submission / routing ---------------------------------------------

    def _pod_by(self, pod: Union[int, str, Pod]) -> Pod:
        if isinstance(pod, Pod):
            return pod
        if isinstance(pod, int):
            return self.pods[pod]
        for p in self.pods:
            if p.name == pod:
                return p
        raise KeyError(f"no pod named {pod!r} "
                       f"(have {[p.name for p in self.pods]})")

    def route(self, job: ReconJob) -> Optional[Pod]:
        """Pod with the minimal modeled completion makespan for ``job``:
        per-device backlog + the job's modeled cost under that pod's
        topology, all on the fleet-shared unit scale (a cold pod borrows
        the warm pods' EMAs, so an idle new pod is not mispriced against
        a warm loaded one; ties: fewer devices busy, then pod order).
        Draining pods are never candidates.  Returns None when no live
        pod can ever hold the job."""
        pods = self.pods_snapshot()
        unit, init = fleet_units(pods)
        best: Optional[Tuple[float, int, int]] = None
        chosen: Optional[Pod] = None
        for i, pod in enumerate(pods):
            cost = modeled_job_seconds(job, pod, unit=unit, init=init)
            if cost is None:
                continue
            backlog = pod_load(pod.scheduler, pod.n_devices,
                               unit=unit, init=init)
            busy = sum(1 for s in pod.pool.slots if s.jobs)
            score = (backlog + cost, busy, i)
            if best is None or score < best:
                best, chosen = score, pod
        return chosen

    def submit(self, job: ReconJob,
               pod: Optional[Union[int, str, Pod]] = None) -> str:
        """Submit ``job``, routed by modeled makespan — or pinned to
        ``pod`` (index / name / object), which is how static per-pod
        partitioning (tenant affinity) is expressed.

        Runs under the fleet lock so routing and membership changes
        cannot interleave (a job can never be routed onto a pod that is
        concurrently retired).  If no live pod can hold the job and an
        :class:`~repro.serve.autoscale.Autoscaler` is attached, the
        autoscaler is asked to grow the fleet from its template pool
        (the ``fits_nowhere_bytes`` signal); failing that, the job goes
        to the largest-memory pod so its scheduler fails it with the
        canonical budget error."""
        with self._fleet_lock:
            if pod is not None:
                target = self._pod_by(pod)
            else:
                target = self.route(job)
                if target is None and self.autoscaler is not None:
                    target = self.autoscaler.scale_up_for(job)
                if target is None:
                    target = max(self.pods_snapshot() or self.pods,
                                 key=lambda p: p.pool.memory.usable)
            jid = target.scheduler.submit(job)
            self._home[jid] = target.name
        # an autoscaler scale-up above only *marked* the fleet manifest
        # dirty (we held the fleet lock); write it now the lock is free
        self._flush_manifest()
        return jid

    # ---- lookups across pods ----------------------------------------------

    def owner(self, job_id: str) -> Pod:
        """Pod currently holding the job's record (stealing moves it;
        retired pods keep the records of jobs that completed on them,
        until compaction — see :meth:`compact_retired`)."""
        with self._fleet_lock:
            pods = list(self.pods) + list(self.retired_pods)
            summaries = list(self.retired_summaries)
        for pod in pods:
            if job_id in pod.scheduler.records:
                return pod
        for s in summaries:
            if job_id in s.job_statuses:
                raise KeyError(
                    f"job {job_id} ({s.job_statuses[job_id]}) ran on "
                    f"retired pod {s.name!r}, whose records were "
                    f"compacted after the retired-pod TTL; its result is "
                    f"no longer held")
        raise KeyError(f"unknown job {job_id}")

    def home(self, job_id: str) -> str:
        """Name of the pod the job was *submitted* to (never changes)."""
        return self._home[job_id]

    def record(self, job_id: str) -> JobRecord:
        return self.owner(job_id).scheduler.records[job_id]

    def result(self, job_id: str):
        return self.owner(job_id).scheduler.result(job_id)

    @property
    def idle(self) -> bool:
        # valid only if no steal pass / scale-down drain was in flight at
        # any point during the pod scan: a hand-off could move a job from
        # a pod we check *later* to one we checked *earlier*, making
        # every pod look idle while the job is on the wire.  The flag
        # covers an active pass; the generation counter covers a pass
        # that started and finished entirely within our scan.
        gen = self._steal_gen
        if self._stealing.is_set():
            return False
        result = all(p.scheduler.idle
                     for p in self.pods_snapshot(live_only=False))
        if self._stealing.is_set() or self._steal_gen != gen:
            return False
        return result

    # ---- execution ---------------------------------------------------------

    def steal_pass(self) -> List[str]:
        """One explicit rebalancing pass (the cooperative loop and the
        threaded driver both call this).  Operates on the live
        (non-draining) membership snapshot.  Returns moved job ids."""
        if not self.steal:
            return []
        with self.transfer_guard():
            moved = steal_pass(self.pods_snapshot(), self.transfer_dir,
                               data_refs=self.data_refs,
                               policy=self.steal_policy)
        self.stolen_jobs.extend(moved)
        return moved

    def run(self, max_rounds: Optional[int] = None,
            autoscaler=None) -> ServeMetrics:
        """Cooperative fleet loop: each round steps every pod's scheduler
        one quantum, runs a steal pass so idle pods pick up other pods'
        parked surplus, then gives the autoscaler (the ``autoscaler``
        argument, or the one registered on this fleet) one control
        decision.  Single-threaded (one pod computes at a time); use
        :class:`repro.serve.driver.MultiPodDriver` for real per-device
        overlap.  Returns the merged fleet metrics."""
        autoscaler = autoscaler if autoscaler is not None \
            else self.autoscaler
        rounds = 0
        while True:
            now = time.monotonic()
            for pod in self.pods_snapshot(live_only=False):
                if pod.scheduler.metrics.wall_start is None:
                    pod.scheduler.metrics.wall_start = now
            if self.idle:
                break
            if max_rounds is not None and rounds >= max_rounds:
                break
            for pod in self.pods_snapshot(live_only=False):
                pod.scheduler.step_quantum()
            self.steal_pass()
            if autoscaler is not None:
                autoscaler.step()
            rounds += 1
        now = time.monotonic()
        for pod in self.pods_snapshot(live_only=False):
            pod.scheduler.metrics.wall_end = now
        return self.metrics()

    # ---- reporting ---------------------------------------------------------

    def _gauge_metrics(self) -> ServeMetrics:
        """Snapshot of the fleet gauges with the *live* pods' online time
        added to the retired pods' accumulated pod-seconds."""
        with self._fleet_lock:
            g = ServeMetrics(
                scale_up_events=self.fleet_metrics.scale_up_events,
                scale_down_events=self.fleet_metrics.scale_down_events,
                pod_seconds=self.fleet_metrics.pod_seconds,
                pods_online=list(self.fleet_metrics.pods_online))
            now = time.monotonic()
            g.pod_seconds += sum(now - t0
                                 for t0 in self._pod_started.values())
        return g

    def metrics(self) -> ServeMetrics:
        """Merged fleet metrics over live and retired pods — compacted
        tombstones included, so scaling down (and compacting) never loses
        counters — plus the fleet gauges (scale events, pods-online
        timeline, pod-seconds)."""
        self.compact_retired()
        with self._fleet_lock:
            parts = [p.scheduler.metrics
                     for p in self.pods + self.retired_pods]
            parts += [s.metrics for s in self.retired_summaries]
        return merge_metrics(parts + [self._gauge_metrics()])

    def summary(self) -> Dict:
        """Fleet summary (merged counters, fleet-wide makespan over every
        device busy clock — retired pods included) plus a per-pod
        breakdown."""
        self.compact_retired()
        with self._fleet_lock:
            live = list(self.pods)
            retired = list(self.retired_pods)
            summaries = list(self.retired_summaries)
        busy: List[float] = []
        for pod in live + retired:
            busy.extend(pod.pool.busy_clocks())
        for s in summaries:
            busy.extend(s.device_busy)
        out = self.metrics().summary(device_busy=busy)
        out["pods"] = {p.name: p.scheduler.summary() for p in live}
        out["retired_pods"] = {p.name: p.scheduler.summary()
                               for p in retired}
        out["retired_pods"].update({s.name: s.summary() for s in summaries})
        out["jobs_stolen"] = len(self.stolen_jobs)
        # the fleet event log's calibration verdict: samples folded per
        # event kind and the pods whose cost models have EMA-drifted
        # stale (empty unless tracing was enabled during the run)
        led = CalibrationLedger.from_events()
        out["calibration_samples_by_kind"] = led.samples_by_kind()
        out["stale_pods"] = led.stale_pods()
        return out

    # ---- fleet-level durable snapshots -------------------------------------
    #
    # Layout under `snapshot_root`:
    #
    #   <root>/fleet.json            # membership manifest (atomic replace):
    #                                #   {"pods": [{name, n_devices, ...}],
    #                                #    "homes": {job_id: pod_name}}
    #   <root>/pods/<pod_name>/      # that pod scheduler's snapshot_dir
    #     jobs/<job_id>/...          #   (spec.json + manifest+COMMIT steps,
    #                                #    see scheduler.py)
    #
    # The manifest is rewritten on every membership change (ctor,
    # add_pod, remove_pod), so a kill -9 at any moment leaves a manifest
    # that matches the per-pod job directories next to it.  `jax_devices`
    # pins cannot be persisted (device handles are process-local): the
    # manifest records *budgets* only, and restore_fleet re-derives the
    # pins from a mesh passed at restore time (``mesh=`` / ``pod_axis=``,
    # validated group-by-group against the recorded device counts);
    # without a mesh, restored pods come back simulated.

    def _mark_manifest_dirty(self) -> None:
        """Capture the current membership as the pending manifest.

        Called with the fleet lock held (cheap: no I/O).  The lock order
        is fleet -> manifest only; :meth:`_flush_manifest` never takes
        the fleet lock, so there is no deadlock against a concurrent
        writer."""
        if self.snapshot_root is None:
            return
        self._manifest_gen += 1
        spec = {
            "pods": [{
                "name": p.name,
                "n_devices": p.n_devices,
                "device_bytes": p.pool.memory.device_bytes,
                "usable_fraction": p.pool.memory.usable_fraction,
                "max_jobs_per_device": p.spec.max_jobs_per_device,
                "placement": p.spec.placement,
            } for p in self.pods],
            "homes": dict(self._home),
        }
        with self._manifest_lock:
            self._pending_manifest = (self._manifest_gen, spec)

    def _flush_manifest(self) -> None:
        """Write the pending manifest (if any) to disk.

        Must be called with the fleet lock *released* — every scale-up
        path (public ``add_pod``, ``Autoscaler.step``, ``submit`` via
        ``scale_up_for``) reaches here only after its last fleet-lock
        exit, so the disk write never serializes membership or
        submissions.  Generation-ordered: a flush that lost the race to
        a newer membership write skips (no stale overwrite)."""
        if self.snapshot_root is None:
            return
        with self._manifest_lock:
            pending = self._pending_manifest
            self._pending_manifest = None
            if pending is None:
                return
            gen, spec = pending
            if gen < self._manifest_written:
                return        # a newer membership already landed on disk
            self._manifest_written = gen
            os.makedirs(self.snapshot_root, exist_ok=True)
            _atomic_write_json(
                os.path.join(self.snapshot_root, FLEET_MANIFEST), spec)

    def _write_fleet_manifest(self) -> None:
        with self._fleet_lock:
            self._mark_manifest_dirty()
        self._flush_manifest()

    def snapshot_fleet(self, root: Optional[str] = None) -> int:
        """Persist the fleet durably: membership manifest + every pod's
        parked *and running* jobs (copy-on-checkpoint, see
        :meth:`Scheduler.snapshot`) under its own snapshot subdirectory.
        Returns the number of jobs persisted across pods."""
        root = root or self.snapshot_root
        if root is None:
            raise ValueError("snapshot_fleet: no snapshot_root configured")
        self._write_fleet_manifest()
        persisted = 0
        for pod in self.pods_snapshot(live_only=False):
            pod_dir = pod.scheduler.snapshot_dir or os.path.join(
                root, "pods", pod.name)
            persisted += pod.scheduler.snapshot(pod_dir)
        return persisted

    def drain_fleet(self, root: Optional[str] = None,
                    timeout: float = 60.0) -> int:
        """Park + persist every running job on every pod (the fleet-wide
        SIGTERM path): each pod's scheduler drains into its own snapshot
        subdirectory, and the membership manifest is rewritten.  Returns
        the number of jobs parked."""
        root = root or self.snapshot_root
        if root is None:
            raise ValueError("drain_fleet: no snapshot_root configured")
        self._write_fleet_manifest()
        parked = 0
        for pod in self.pods_snapshot(live_only=False):
            pod_dir = pod.scheduler.snapshot_dir or os.path.join(
                root, "pods", pod.name)
            parked += pod.scheduler.drain(pod_dir, timeout=timeout)
        return parked

    @classmethod
    def restore_fleet(cls, snapshot_root: str,
                      data_refs: Optional[Dict[str, Callable]] = None,
                      steal: bool = True,
                      transfer_dir: Optional[str] = None,
                      steal_policy: StealPolicy = StealPolicy(),
                      guard=None, mesh=None,
                      pod_axis: str = "pod") -> "MultiPodScheduler":
        """Rebuild a whole fleet — membership *and* parked jobs — from a
        fleet snapshot directory after process death.  Every pod named in
        ``fleet.json`` is reconstructed (device count, budget, placement
        policy) and its scheduler restored from its snapshot
        subdirectory; jobs resume bit-identically to an uninterrupted
        run.  The restored job ids are exposed as ``restored_jobs``.

        The manifest records *budgets* only — device handles are
        process-local and cannot be persisted.  Pass ``mesh`` (with the
        pod axis named by ``pod_axis``) to restore onto **real
        devices**: the mesh's pod groups are re-derived exactly as
        :func:`pods_from_mesh` does and matched, in manifest order,
        against the recorded pods — group count and per-group device
        count must agree with the manifest, or the restore refuses
        loudly rather than silently re-pinning jobs onto a different
        topology.  Without a mesh, pods come back simulated (budget-only
        slots), the historical behaviour.

        If ``transfer_dir`` names the fleet's shared hand-off directory,
        :meth:`recover_transfers` runs after the per-pod restores: a
        crash between a steal's export and import leaves the job only in
        the transfer directory, and recovery re-adopts it (the ids land
        in ``recovered_jobs``).

        ``data_refs`` supplies projection callables for lazy-data jobs
        (refs cannot be persisted); ``guard`` is attached to every
        restored pod's scheduler.  Restore failures are loud (see
        :meth:`Scheduler.restore`)."""
        manifest_path = os.path.join(snapshot_root, FLEET_MANIFEST)
        if not os.path.isfile(manifest_path):
            raise FileNotFoundError(
                f"restore_fleet: no {FLEET_MANIFEST} under "
                f"{snapshot_root!r} (not a fleet snapshot?)")
        with open(manifest_path) as f:
            manifest = json.load(f)
        if not manifest.get("pods"):
            raise ValueError(f"restore_fleet: {manifest_path} lists no pods")
        groups = None
        if mesh is not None:
            from ..launch.mesh import pod_device_groups
            groups = pod_device_groups(mesh, pod_axis)
            if len(groups) != len(manifest["pods"]):
                raise ValueError(
                    f"restore_fleet: mesh yields {len(groups)} pod "
                    f"groups but {FLEET_MANIFEST} records "
                    f"{len(manifest['pods'])} pods — the restore mesh "
                    f"must match the snapshotted fleet shape")
            for group, p in zip(groups, manifest["pods"]):
                if len(group) != p["n_devices"]:
                    raise ValueError(
                        f"restore_fleet: mesh group for pod "
                        f"{p['name']!r} has {len(group)} devices but "
                        f"the manifest records {p['n_devices']}")
        pods = [Pod(PodSpec(
                    name=p["name"], n_devices=p["n_devices"],
                    memory=MemoryModel(
                        device_bytes=p["device_bytes"],
                        usable_fraction=p["usable_fraction"]),
                    jax_devices=(tuple(groups[i]) if groups is not None
                                 else None),
                    max_jobs_per_device=p["max_jobs_per_device"],
                    placement=p["placement"]),
                    guard=guard)
                for i, p in enumerate(manifest["pods"])]
        mps = cls(pods, steal=steal, transfer_dir=transfer_dir,
                  steal_policy=steal_policy, data_refs=data_refs,
                  snapshot_root=snapshot_root)
        homes = manifest.get("homes", {})
        # the ctor rewrote fleet.json while _home was still empty: put
        # the homes back (memory + disk) *before* the per-pod restores,
        # whose documented failure mode (e.g. a lazy job missing its
        # data_refs entry) is loud-and-retryable — a retry must not find
        # the homes metadata destroyed by the failed attempt
        with mps._fleet_lock:
            mps._home.update(homes)
        mps._write_fleet_manifest()
        restored: List[str] = []
        for pod in mps.pods:
            before = set(pod.scheduler.records)
            pod.scheduler.restore(pod.scheduler.snapshot_dir,
                                  data_refs=data_refs)
            for jid in set(pod.scheduler.records) - before:
                restored.append(jid)
                # manifest homes win (submit-time pod); a job missing
                # there (submitted after the last manifest rewrite)
                # falls back to the pod it was restored from
                if jid not in homes:
                    mps._home[jid] = pod.name
        mps.restored_jobs = sorted(restored)
        mps._write_fleet_manifest()   # persist any fallback homes
        if transfer_dir is not None:
            mps.recover_transfers()
        return mps

    def recover_transfers(self, transfer_dir: Optional[str] = None
                          ) -> Dict[str, List[str]]:
        """Re-adopt jobs stranded mid-hand-off by a crash.

        A steal / drain / migration moves a job through the shared
        transfer directory in two acts: the victim exports (job on disk,
        forgotten locally) and the thief imports (job adopted, copy
        consumed).  A kill between the two leaves the job owned by *no*
        scheduler — only the transfer copy survives.  This pass scans
        ``transfer_dir/jobs/*`` and sorts each copy into one of:

        * **torn export** (no ``spec.json``): the victim crashed before
          the spec landed, so it never forgot the job — its own snapshot
          still owns it.  Left alone.
        * **half-consumed import** (spec status terminal): the thief
          adopted it and crashed between the ``stolen`` spec flip and
          the directory delete.  Finished consuming, reported in
          ``dropped``.
        * **already owned** (job id present in some pod's records): a
          restore resurrected the victim's copy, or the import completed
          before persisting the tombstone.  The transfer copy is the
          duplicate — consumed, reported in ``dropped``.
        * **orphan** (live spec, committed step, owned by nobody): the
          crash hit the export/import gap.  Imported onto the first live
          pod that accepts it (resumes bit-identically from the
          travelling checkpoint); a fleet where *no* pod can adopt it
          raises rather than silently stranding the job.

        Returns ``{"imported": [...], "dropped": [...]}`` and appends
        the imported ids to ``recovered_jobs``.  Called automatically by
        :meth:`restore_fleet` when it was given a ``transfer_dir``."""
        tdir = transfer_dir or self.transfer_dir
        jobs_root = os.path.join(tdir, "jobs")
        imported: List[str] = []
        dropped: List[str] = []
        if not os.path.isdir(jobs_root):
            return {"imported": imported, "dropped": dropped}
        known = set()
        for pod in self.pods_snapshot(live_only=False):
            known.update(pod.scheduler.records)
        for jid in sorted(os.listdir(jobs_root)):
            job_dir = os.path.join(jobs_root, jid)
            spec_path = os.path.join(job_dir, "spec.json")
            if not os.path.isfile(spec_path):
                continue                      # torn export: victim owns it
            with open(spec_path) as f:
                status = json.load(f)["status"]
            if status in _TERMINAL or jid in known:
                _consume_transfer_copy(job_dir)
                dropped.append(jid)
                continue
            errors = []
            for pod in self.pods_snapshot():
                try:
                    pod.scheduler.import_job(tdir, jid,
                                             data_refs=self.data_refs)
                except Exception as exc:
                    errors.append(f"{pod.name}: {exc}")
                    continue
                with self._fleet_lock:
                    self._home.setdefault(jid, pod.name)
                imported.append(jid)
                break
            else:
                raise RuntimeError(
                    f"recover_transfers: job {jid} is stranded in "
                    f"{tdir!r} (exported by a crashed pod, imported by "
                    f"none) and no live pod could adopt it: "
                    f"{'; '.join(errors) or 'no live pods'}")
        if imported:
            self.recovered_jobs = sorted(set(self.recovered_jobs)
                                         | set(imported))
            self._write_fleet_manifest()      # persist the new homes
        return {"imported": imported, "dropped": dropped}
