"""Priority admission queue for reconstruction jobs.

Ordering: higher ``priority`` first; within a priority level, submission
order (FIFO).  A preempted job re-enters the queue with its *original*
submission sequence number, so it goes back ahead of later arrivals of the
same priority instead of losing its place.

The queue is thread-safe (a single lock around the heap) so that client
threads can submit while a scheduler thread drains.
"""

from __future__ import annotations

import heapq
import threading
from typing import Dict, List, Optional, Tuple

from .job import JobRecord, JobStatus


class PriorityJobQueue:
    """Max-priority / FIFO-tiebreak job queue with lazy cancellation."""

    def __init__(self):
        self._heap: List[Tuple[int, int, str]] = []   # (-prio, seq, job_id)
        self._records: Dict[str, JobRecord] = {}
        self._lock = threading.Lock()

    def push(self, record: JobRecord) -> None:
        with self._lock:
            self._records[record.job.job_id] = record
            heapq.heappush(self._heap,
                           (-record.job.priority, record.seq,
                            record.job.job_id))

    def pop(self) -> Optional[JobRecord]:
        """Highest-priority pending record, or None if empty."""
        with self._lock:
            while self._heap:
                _, _, job_id = heapq.heappop(self._heap)
                rec = self._records.pop(job_id, None)
                if rec is not None and rec.status != JobStatus.CANCELLED:
                    return rec
            return None

    def peek_priority(self) -> Optional[int]:
        """Priority of the next job that ``pop`` would return."""
        with self._lock:
            while self._heap:
                neg_prio, _, job_id = self._heap[0]
                rec = self._records.get(job_id)
                if rec is not None and rec.status != JobStatus.CANCELLED:
                    return -neg_prio
                heapq.heappop(self._heap)   # drop cancelled/stale entry
            return None

    def pending_records(self) -> List[JobRecord]:
        """Thread-safe snapshot of the queued (non-cancelled) records in
        pop order — the scheduler persists exactly these on a snapshot."""
        with self._lock:
            live = [(entry, self._records[entry[2]])
                    for entry in self._heap
                    if entry[2] in self._records
                    and self._records[entry[2]].status != JobStatus.CANCELLED]
            return [rec for _, rec in sorted(live, key=lambda t: t[0])]

    def remove(self, job_id: str) -> Optional[JobRecord]:
        """Take a queued record out *without* cancelling it (the work
        stealing path: the record moves to another pod's queue intact).
        The heap entry goes stale and is dropped lazily on pop/peek.
        Returns the record, or None if the job is not queued here."""
        with self._lock:
            return self._records.pop(job_id, None)

    def cancel(self, job_id: str) -> bool:
        """Mark a queued job cancelled (lazily removed on pop)."""
        with self._lock:
            rec = self._records.pop(job_id, None)
            if rec is None:
                return False
            rec.status = JobStatus.CANCELLED
            return True

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def __bool__(self) -> bool:
        return len(self) > 0
