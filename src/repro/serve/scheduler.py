"""Multi-tenant job scheduler: placement, fair-share interleaving, preemption.

This is the serving layer the paper's planners make possible: because
the execution plan (:func:`repro.core.plan.plan` — the same memoized IR
the executors run) can *predict* a reconstruction's
per-device footprint before any array is allocated, the scheduler can pack
several small jobs onto one device, route oversized jobs through the
out-of-core streaming path (whose working set is bounded by the device
budget no matter how large the volume), and know ahead of time that a
placement fits.

Execution model
---------------
Jobs advance in *quanta* of outer iterations.  Under the cooperative
:meth:`Scheduler.run` loop one thread steps every running job in turn;
under the threaded :class:`~repro.serve.driver.AsyncDriver` one worker
thread per device claims and steps that device's resident jobs
concurrently (the paper's "executed for all available GPUs
simultaneously").  Either way the share is *weighted*: a job receives step
quanta proportional to ``1 + priority``, so a long low-priority
reconstruction cannot starve short jobs that land next to it, and urgent
work drains faster even when nothing needs evicting.

Priorities also order admission.  A high-priority arrival that does not
fit preempts strictly-lower-priority running work — but only on the single
device where evicting the cheapest victim set actually makes the arrival
fit (freed bytes on *different* devices never combine, so pool-wide
eviction would kill jobs to no effect).  A victim's resumable state (see
``repro.core.algorithms.stepwise``) is checkpointed to host memory, its
device reservation is released, and it re-enters the queue with its
original position, resuming later with bit-identical results.

Deadline admission: a job may carry ``deadline_seconds``; at admission the
scheduler models its completion time from the observed init/step costs
(EMAs over previous jobs) and rejects it outright if the model says the
deadline cannot be met.

A :class:`~repro.checkpoint.preemption.PreemptionGuard` can be attached;
when the guard fires (SIGTERM on a cloud host), the scheduler drains at
the next step boundary: all running jobs are checkpointed and requeued,
and — when a snapshot directory is configured — every parked job is
persisted through :mod:`repro.checkpoint.sharded` (manifest + COMMIT
marker, one directory per job), so a *restarted process* rebuilds the
queue with :meth:`Scheduler.restore` and resumes bit-identically.

The device pool is either real (one slot per JAX device) or simulated
(slots with a byte budget only) — placement logic is identical, which is
how the tests drive a "multi-GPU" pool on a CPU host.

All public methods are thread-safe: one re-entrant lock guards every
mutation of the pool / records / running set (the job queue carries its
own lock); executor steps themselves run *outside* the lock so device
compute genuinely overlaps across worker threads.  Executor *init*
(data-ref resolution + operator build/JIT) also runs outside the lock:
admission reserves the slot's bytes under the lock, initialises
unlocked, then commits (or rolls back) the reservation — a first-seen
geometry's compile never stalls claims on other slots.  Jobs mid-init
are tracked by an in-flight counter so ``idle`` and ``drain`` cannot
observe them as "gone".

Admission can be paused (:meth:`Scheduler.pause_admission`): running
jobs keep stepping but parked jobs stay parked, which is how a
scale-down drain (``repro.serve.autoscale``) keeps the jobs it preempts
from being re-placed on the pod it is about to retire.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..checkpoint.sharded import (latest_step, manifest_target,
                                  restore_checkpoint, save_checkpoint)
from ..core.algorithms.stepwise import get_algorithm
from ..obs import fleet_event
from ..core.geometry import ConeGeometry
from ..core.plan import plan as plan_execution
from ..core.splitting import MemoryModel
from .executor import JobExecutor
from .job import JobRecord, JobStatus, ReconJob
from .metrics import ServeMetrics
from .queue import PriorityJobQueue

F32 = 4


def fair_share_weight(priority: int) -> int:
    """Step quanta awarded per scheduling round: proportional to priority
    (floor 1 so zero/negative priorities still make progress)."""
    return max(1, 1 + priority)

# Peak live arrays per algorithm: (volume-sized, projection-set-sized).
# Used for the *resident* footprint of in-core jobs; streaming jobs are
# bounded by the planner's slab + buffer working set instead.
_ALG_WORKSPACE = {
    "cgls": (3, 3),        # x, p, s  /  b, r, q
    "fista": (3, 2),       # x, y, z  /  b, A(y)
    "fista_tv": (3, 2),
    "ossart": (3, 3),      # x, upd, V / proj, resid, W
    "sirt": (3, 3),
    "sart": (3, 3),
    "asd_pocs": (4, 3),    # ossart set + x_prev
    "fdk": (2, 2),         # vol, acc / proj, filtered
}
_DEFAULT_WORKSPACE = (4, 3)


@dataclasses.dataclass(frozen=True)
class JobFootprint:
    """Planner-derived placement requirements for one job."""
    bytes_on_device: int
    streams: bool           # must run through the out-of-core executor


def estimate_job_footprint(job: ReconJob,
                           memory: MemoryModel) -> JobFootprint:
    """Per-device bytes the job needs under ``memory``, and whether it must
    stream.  Mirrors the paper's "check GPU memory / split" decision
    (Alg 1-2): if the plan would split the volume, the job cannot be held
    resident and is routed out-of-core.  All structure comes off the
    shared memoized :func:`repro.core.plan.plan` — the same IR the
    executors run — so the scheduler prices exactly what would execute."""
    geo, n_angles = job.geo, job.n_angles
    p = plan_execution(geo, n_angles, 1, memory)
    streams = p.streams
    if job.mode == "plain":
        streams = False
    elif job.mode == "stream":
        streams = True

    if streams:
        bytes_needed = p.stream_bytes_on_device
    else:
        nz, ny, nx = geo.n_voxel
        nv, nu = geo.n_detector
        n_vol, n_proj = _ALG_WORKSPACE.get(job.algorithm,
                                           _DEFAULT_WORKSPACE)
        bytes_needed = (n_vol * nz * ny * nx * F32
                        + n_proj * n_angles * nv * nu * F32)
    if job.memory_hint_bytes:
        bytes_needed = job.memory_hint_bytes
    return JobFootprint(bytes_needed, streams)


@dataclasses.dataclass
class DeviceSlot:
    """One device's capacity ledger (real JAX device or simulated)."""
    index: int
    memory: MemoryModel
    jax_device: Optional[Any] = None
    committed_bytes: int = 0
    busy_seconds: float = 0.0           # virtual per-device clock
    jobs: Set[str] = dataclasses.field(default_factory=set)

    @property
    def free_bytes(self) -> int:
        return self.memory.usable - self.committed_bytes


class DevicePool:
    """Homogeneous pool of device slots.

    ``policy`` selects the placement heuristic among the slots that fit:

    * ``"spread"`` (default): least-loaded first (fewest resident jobs,
      then most free bytes) — maximises device parallelism, the serving
      throughput choice.
    * ``"pack"``: tightest fit first — minimises fragmentation, keeps
      large holes open for large jobs.
    """

    def __init__(self, n_devices: int = 1,
                 memory: Optional[MemoryModel] = None,
                 jax_devices: Optional[Sequence] = None,
                 max_jobs_per_device: Optional[int] = None,
                 policy: str = "spread"):
        if policy not in ("spread", "pack"):
            raise ValueError(f"unknown placement policy {policy!r}")
        self.memory = memory or MemoryModel()
        if jax_devices is not None:
            n_devices = len(jax_devices)
        self.slots = [
            DeviceSlot(i, self.memory,
                       jax_devices[i] if jax_devices is not None else None)
            for i in range(n_devices)]
        self.max_jobs_per_device = max_jobs_per_device
        self.policy = policy

    def best_fit(self, bytes_needed: int) -> Optional[DeviceSlot]:
        """Pick a slot that fits ``bytes_needed`` under the pool policy."""
        candidates = [
            s for s in self.slots
            if s.free_bytes >= bytes_needed
            and (self.max_jobs_per_device is None
                 or len(s.jobs) < self.max_jobs_per_device)]
        if not candidates:
            return None
        if self.policy == "pack":
            return min(candidates, key=lambda s: (s.free_bytes, s.index))
        return min(candidates,
                   key=lambda s: (len(s.jobs), -s.free_bytes, s.index))

    def commit(self, slot: DeviceSlot, job_id: str, nbytes: int) -> None:
        slot.committed_bytes += nbytes
        slot.jobs.add(job_id)

    def release(self, slot: DeviceSlot, job_id: str, nbytes: int) -> None:
        slot.committed_bytes -= nbytes
        slot.jobs.discard(job_id)

    def busy_clocks(self) -> List[float]:
        return [s.busy_seconds for s in self.slots]

    @property
    def fits_nowhere_bytes(self) -> int:
        """A job above this can never be placed, even on an empty pool."""
        return self.memory.usable


def modeled_step_passes(job: ReconJob, memory: MemoryModel) -> float:
    """Relative cost of one outer iteration of ``job`` under ``memory``,
    in units of an in-core iteration (= 1.0): the memoized
    :attr:`~repro.core.plan.ExecutionPlan.step_passes` of the job's plan
    — the slab counts are exactly what the paper's Alg 1-2 choose for
    that budget, so a pod with more memory per device models (and is)
    cheaper for oversized volumes.  This is the one cost model shared by
    multi-pod routing and the work-stealing benefit check; raises if the
    job is unplannable under ``memory``."""
    fp = estimate_job_footprint(job, memory)
    if not fp.streams:     # honours a forced job.mode="plain"
        return 1.0
    return plan_execution(job.geo, job.n_angles, 1, memory).step_passes


@dataclasses.dataclass
class _Running:
    record: JobRecord
    executor: JobExecutor
    slot: DeviceSlot
    # -- async-driver bookkeeping (all mutated under the scheduler lock) --
    claimed: bool = False             # a worker thread is mid-step
    preempt_requested: bool = False   # park at the next step boundary
    vtime: float = 0.0                # stride-scheduling virtual time
    passes: float = 1.0               # slab-pass multiplier of one step
    # -- copy-on-checkpoint live snapshots (see Scheduler.snapshot): a
    # periodic snapshot that finds this job mid-step asks the worker to
    # capture the committed state at its next boundary instead of
    # waiting the step out under the lock
    snapshot_requested: bool = False
    boundary_checkpoint: Optional[Dict[str, Any]] = None
    boundary_iterations: int = -1     # iterations_done of that capture


class Scheduler:
    """Accepts :class:`ReconJob` submissions and drives them to completion.

    Usage::

        sched = Scheduler(n_devices=4, memory=MemoryModel(...))
        sched.submit(job_a); sched.submit(job_b)
        sched.run()
        rec = sched.records[job_a.job_id].result
    """

    def __init__(self, pool: Optional[DevicePool] = None,
                 n_devices: int = 1,
                 memory: Optional[MemoryModel] = None,
                 metrics: Optional[ServeMetrics] = None,
                 guard=None,
                 snapshot_dir: Optional[str] = None,
                 name: str = ""):
        self.pool = pool or DevicePool(n_devices, memory)
        # trace identity: the pod name in fleet event logs / span tracks
        # ("" for a standalone scheduler; Pod sets its spec name)
        self.name = name
        self.queue = PriorityJobQueue()
        self.records: Dict[str, JobRecord] = {}
        self.running: Dict[str, _Running] = {}
        self.metrics = metrics or ServeMetrics()
        self.guard = guard
        self.snapshot_dir = snapshot_dir
        self._seq = itertools.count()
        self._lock = threading.RLock()
        # in-flight admissions (slot reserved, executor init running
        # outside the lock); jobs in this window are in neither the queue
        # nor `running`, so idle/drain consult the counter and the load
        # model (`modeled_backlog_seconds`) still prices the records —
        # an invisible mid-admission job would make the pod look idle to
        # fleet routing/stealing and cause ping-pong moves
        self._admitting = 0
        self._admitting_recs: Dict[str, JobRecord] = {}
        self._admission_paused = False
        # admission-model cost estimates (EMAs over observed jobs)
        self._step_ema: Optional[float] = None
        self._init_ema: Optional[float] = None
        self._ema_alpha = 0.3
        # measured host<->device bandwidth (bytes/s): the CommSchedule's
        # modeled bytes per step divided by the staging phase seconds the
        # tracer attributed to it.  None until a traced streamed step has
        # been observed (phase spans only exist when tracing is on), in
        # which case transfer pricing is inactive and the unit EMA keeps
        # its historical all-inclusive meaning
        self._bandwidth_ema: Optional[float] = None
        # per-job progress fingerprint at last snapshot (dedups the
        # periodic snapshot's disk writes for unchanged parked jobs)
        self._snapshotted: Dict[str, tuple] = {}
        # job_id -> slab-pass multiplier / footprint under this pool's
        # fixed budget (memos for the oft-polled load signals).  Bounded:
        # fleet routing prices every submission on every pod, so without
        # a cap these would grow by one entry per job ever *considered*
        # here, not just per job run here; eviction is cheap because the
        # heavy planning underneath is memoized per geometry in
        # repro.core.plan anyway
        self._passes_cache: Dict[str, float] = {}
        self._footprint_cache: Dict[str, JobFootprint] = {}

    # ---- client API --------------------------------------------------------

    def _cal_attrs(self, job: ReconJob) -> Dict[str, str]:
        """Cost-model identity attrs stamped on admit/step/reject/complete
        events so the calibration ledger (repro.obs.calibration) can
        group modeled-vs-measured errors per
        (geometry, algorithm, backend, pod)."""
        nz, ny, nx = job.geo.n_voxel
        return {"geo": f"{nz}x{ny}x{nx}", "alg": job.algorithm,
                "backend": job.backend or "auto"}

    def submit(self, job: ReconJob) -> str:
        get_algorithm(job.algorithm)   # fail fast on unknown algorithms
        with self._lock:
            rec = JobRecord(job=job, seq=next(self._seq),
                            submit_time=time.monotonic())
            self.records[job.job_id] = rec
            self.queue.push(rec)
            self.metrics.submitted += 1
            fleet_event("submit", job=job.job_id, pod=self.name,
                        priority=job.priority)
        return job.job_id

    def cancel(self, job_id: str) -> bool:
        """Cancel a queued (not yet running) job."""
        with self._lock:
            ok = self.queue.cancel(job_id)
            if ok:
                self.metrics.cancelled += 1
                rec = self.records.get(job_id)
                if rec is not None:
                    # a snapshot may have persisted this job while parked;
                    # stale it out so restore() cannot resurrect it
                    self._mark_terminal_on_disk(rec)
            return ok

    def result(self, job_id: str):
        rec = self.records[job_id]
        if rec.status is not JobStatus.COMPLETED:
            raise RuntimeError(f"{job_id} is {rec.status.value}"
                               + (f": {rec.error}" if rec.error else ""))
        return rec.result

    @property
    def idle(self) -> bool:
        # a job mid-admission (slot reserved, init running outside the
        # lock) is in neither the queue nor `running`; the in-flight
        # counter keeps a concurrent waiter from observing "all done"
        # while an executor is still compiling
        with self._lock:
            return (not self.queue and not self.running
                    and self._admitting == 0)

    def pause_admission(self) -> None:
        """Stop placing queued jobs (running jobs keep stepping).  The
        scale-down drain pauses a pod so the jobs it parks stay parked
        until they are exported to a surviving pod instead of being
        re-placed on the pod about to retire."""
        with self._lock:
            self._admission_paused = True

    def resume_admission(self) -> None:
        with self._lock:
            self._admission_paused = False

    @property
    def admission_paused(self) -> bool:
        return self._admission_paused

    # ---- placement ---------------------------------------------------------

    def _fail(self, rec: JobRecord, msg: str) -> None:
        rec.status = JobStatus.FAILED
        rec.error = msg
        rec.end_time = time.monotonic()
        self.metrics.failed += 1
        fleet_event("fail", job=rec.job.job_id, pod=self.name, error=msg)
        self._mark_terminal_on_disk(rec)

    def _mark_terminal_on_disk(self, rec: JobRecord) -> None:
        """Flip a previously-snapshotted job's spec to its terminal status
        so a later :meth:`restore` does not resurrect stale parked state
        for work that already finished, and delete the job's step
        directories — the bulk of the payload (full projections arrays)
        has no reader once the spec is terminal, and a long-lived server
        would otherwise leak one checkpoint per job ever parked.  The
        terminal spec stays behind as a tombstone."""
        if self.snapshot_dir is None:
            return
        _stale_job_dir(os.path.join(self.snapshot_dir, "jobs",
                                    rec.job.job_id),
                       rec.status.value)

    def _reserve_next(self) -> Optional[Tuple[JobRecord, DeviceSlot,
                                              JobFootprint]]:
        """Under the lock: pop queued jobs in priority order until one
        gets a slot *reservation* (its bytes committed, executor not yet
        built) or the head job cannot be placed (strict priority order —
        no backfilling past the head; returns None).  Jobs consumed
        without a reservation (deadline rejection, unplannable,
        oversized) are failed in place."""
        if self._admission_paused:
            return None
        while True:
            if self.queue.peek_priority() is None:
                return None
            rec = self.queue.pop()
            if rec is None:
                return None
            if self._reject_for_deadline(rec):
                continue
            try:
                fp = estimate_job_footprint(rec.job, self.pool.memory)
            except Exception as e:   # bad geometry/budget: tenant's fault
                self._fail(rec, f"unplannable under device budget: {e!r}")
                continue
            if fp.bytes_on_device > self.pool.fits_nowhere_bytes:
                self._fail(rec, f"footprint {fp.bytes_on_device} B exceeds "
                                f"the device budget "
                                f"{self.pool.fits_nowhere_bytes} B "
                                f"even on an empty device")
                continue
            slot = self.pool.best_fit(fp.bytes_on_device)
            if slot is None and self._evict_for(rec, fp.bytes_on_device):
                slot = self.pool.best_fit(fp.bytes_on_device)
            if slot is None:
                # head job cannot be placed now: put it back and stop
                # admitting (deferred evictions land at step boundaries
                # and a later admission pass retries)
                self.queue.push(rec)
                return None
            # reserve the bytes *before* init: concurrent admissions and
            # eviction planning see the slot as taken while the executor
            # compiles outside the lock
            self.pool.commit(slot, rec.job.job_id, fp.bytes_on_device)
            self.metrics.memory_modeled_peak_bytes = max(
                self.metrics.memory_modeled_peak_bytes,
                fp.bytes_on_device)
            self._admitting += 1
            self._admitting_recs[rec.job.job_id] = rec
            fleet_event("place", job=rec.job.job_id, pod=self.name,
                        device=slot.index, bytes=fp.bytes_on_device,
                        streams=fp.streams)
            return rec, slot, fp

    def _commit_admission(self, rec: JobRecord, slot: DeviceSlot,
                          fp: JobFootprint,
                          executor: Optional[JobExecutor],
                          err: Optional[Exception]) -> None:
        """Under the lock: turn a reservation into a running job, or roll
        the reservation back if init failed."""
        self._admitting -= 1
        self._admitting_recs.pop(rec.job.job_id, None)
        if err is not None:
            self.pool.release(slot, rec.job.job_id, fp.bytes_on_device)
            self._fail(rec, f"init failed: {err!r}")
            return
        fleet_event("admit", job=rec.job.job_id, pod=self.name,
                    device=slot.index, measured_s=executor.init_seconds,
                    modeled_s=self._init_ema, **self._cal_attrs(rec.job))
        self.metrics.record_calibration("admit", self._init_ema,
                                        executor.init_seconds)
        self.metrics.record_phases(executor.take_phase_seconds())
        self._init_ema = (executor.init_seconds if self._init_ema is None
                          else self._ema_alpha * executor.init_seconds
                          + (1 - self._ema_alpha) * self._init_ema)
        rec.checkpoint = None
        rec.status = JobStatus.RUNNING
        rec.device = slot.index
        rec.footprint_bytes = fp.bytes_on_device
        rec.streamed = fp.streams
        if fp.streams:
            self.metrics.streamed_jobs += 1
        if rec.start_time is None:
            rec.start_time = time.monotonic()
        slot.busy_seconds += executor.init_seconds
        # join stride scheduling at the slot's current virtual time: a
        # newcomer starting at vtime 0 would monopolize the device until
        # it "caught up" with long-resident jobs
        peers = [r.vtime for r in self.running.values() if r.slot is slot]
        self.running[rec.job.job_id] = _Running(
            rec, executor, slot, vtime=min(peers, default=0.0),
            passes=self.job_passes(rec.job))

    def admit(self) -> None:
        """Thread-safe admission pass (the driver's scheduler loop calls
        this; the cooperative loop calls it at each quantum).

        Executor init (data-ref resolution + operator build/JIT) runs
        *outside* the scheduler lock: the critical section only reserves
        the slot's bytes, so a first-seen geometry's compile never stalls
        step claims on other slots; the reservation is committed or
        rolled back under the lock once init returns."""
        while True:
            with self._lock:
                reserved = self._reserve_next()
            if reserved is None:
                return
            rec, slot, fp = reserved
            executor: Optional[JobExecutor] = None
            err: Optional[Exception] = None
            try:
                # one tenant's bad geometry / data ref / algorithm params
                # must fail that job alone, never the scheduler serving
                # the others
                executor = JobExecutor(
                    rec.job, mode="stream" if fp.streams else "plain",
                    memory=self.pool.memory,
                    devices=([slot.jax_device] if slot.jax_device is not None
                             else None),
                    labels={"pod": self.name or None,
                            "device": slot.index})
                executor.start(checkpoint=rec.checkpoint)
            except Exception as e:
                if executor is not None:
                    # start() may have built device state before raising --
                    # drop it so the buffers are reclaimed
                    executor.release()
                executor, err = None, e
            with self._lock:
                self._commit_admission(rec, slot, fp, executor, err)

    # ---- deadline admission ------------------------------------------------

    def modeled_transfer_seconds(self, job: ReconJob) -> float:
        """Schedule-priced host<->device staging seconds one outer
        iteration of ``job`` costs at the measured-bandwidth EMA: the
        plan's :meth:`~repro.core.plan.CommSchedule.transfer_seconds`
        evaluated at the bandwidth observed from staging phase spans.
        0.0 for in-core jobs (operands stay resident) and until a
        bandwidth has been measured (untraced runs never measure one, so
        pricing degrades to the historical all-inclusive unit EMA)."""
        bw = self._bandwidth_ema
        if bw is None or bw <= 0.0:
            return 0.0
        try:
            if not self.job_footprint(job).streams:
                return 0.0
            p = plan_execution(job.geo, job.n_angles, 1, self.pool.memory)
        except Exception:
            return 0.0
        return p.comm.transfer_seconds(bw)

    def modeled_completion_seconds(self, rec: JobRecord) -> Optional[float]:
        """Modeled submit-to-completion time if ``rec`` were admitted now:
        elapsed queue wait + modeled (re)init + remaining iterations at
        the observed per-pass unit cost scaled by *this job's* slab-pass
        multiplier (:func:`modeled_step_passes` — the shared cost model),
        so a small in-core job is not priced at the cost of the streamed
        giants the EMA was observed on, plus the per-iteration transfer
        term for streamed jobs (:meth:`modeled_transfer_seconds`).
        ``None`` until a step has been observed."""
        if self._step_ema is None:
            return None
        elapsed = time.monotonic() - rec.submit_time
        return (elapsed + (self._init_ema or 0.0)
                + self._remaining_iters(rec)
                * (self._step_ema * self.job_passes(rec.job)
                   + self.modeled_transfer_seconds(rec.job)))

    def _reject_for_deadline(self, rec: JobRecord) -> bool:
        """True if the record was consumed by deadline admission control."""
        if rec.job.deadline_seconds <= 0:
            return False
        est = self.modeled_completion_seconds(rec)
        if est is not None and est > rec.job.deadline_seconds:
            self.metrics.deadline_rejected += 1
            # the refusal's full evidence goes on the event: the modeled
            # completion seconds that condemned the job, the deadline it
            # missed, and the cost-model identity — a deadline refusal
            # is auditable from the event log alone
            fleet_event("reject", job=rec.job.job_id, pod=self.name,
                        modeled_s=est,
                        deadline_s=rec.job.deadline_seconds,
                        priority=rec.job.priority,
                        queue_wait_s=time.monotonic() - rec.submit_time,
                        **self._cal_attrs(rec.job))
            self._fail(rec, f"deadline {rec.job.deadline_seconds:.3f}s "
                            f"unmeetable: modeled completion {est:.3f}s")
            return True
        return False

    # ---- preemption --------------------------------------------------------

    def _slot_eviction_plan(self, slot: DeviceSlot, rec: JobRecord,
                            needed: int) -> Optional[List[_Running]]:
        """Cheapest set of strictly-lower-priority victims on ``slot``
        whose eviction makes ``rec`` fit there, or None if no set does.
        Victims already flagged for preemption count as free-in-flight
        (their bytes will return at the next step boundary) and are never
        evicted twice."""
        free = slot.free_bytes
        n_jobs = len(slot.jobs)
        candidates = []
        for run in self.running.values():
            if run.slot is not slot:
                continue
            if run.preempt_requested:
                free += run.record.footprint_bytes
                n_jobs -= 1
            elif run.record.job.priority < rec.job.priority:
                candidates.append(run)
        # cheapest first: lowest priority, then latest arrival
        candidates.sort(key=lambda r: (r.record.job.priority,
                                       -r.record.seq))
        cap = self.pool.max_jobs_per_device

        def fits():
            return free >= needed and (cap is None or n_jobs < cap)

        victims: List[_Running] = []
        while not fits() and candidates:
            run = candidates.pop(0)
            victims.append(run)
            free += run.record.footprint_bytes
            n_jobs -= 1
        return victims if fits() else None

    def _evict_for(self, rec: JobRecord, needed: int) -> bool:
        """Per-device preemption: pick the slot where evicting the
        cheapest set of strictly-lower-priority victims makes ``rec``
        fit, and evict only those.  Jobs on devices that could never make
        room keep running.  Returns True when the evictions freed the
        bytes synchronously (the caller's ``best_fit`` retry will
        succeed); False when nothing can move now — either no slot has a
        viable victim set, or the only viable victims are mid-step (they
        are flagged, park at their step boundary, and a later admission
        pass retries the arrival)."""
        best: Optional[Tuple[tuple, DeviceSlot, List[_Running]]] = None
        for slot in self.pool.slots:
            victims = self._slot_eviction_plan(slot, rec, needed)
            if victims is None:
                continue
            if not victims:
                # fits once in-flight preemptions land: just wait
                return False
            score = (len(victims),
                     max(v.record.job.priority for v in victims),
                     slot.index)
            if best is None or score < best[0]:
                best = (score, slot, victims)
        if best is None:
            return False
        _, _, victims = best
        deferred = False
        for run in victims:
            if run.claimed:
                run.preempt_requested = True   # park at the step boundary
                deferred = True
            else:
                self._preempt(run)
        return not deferred

    def _preempt(self, run: _Running) -> None:
        rec = run.record
        rec.checkpoint = run.executor.checkpoint()
        rec.status = JobStatus.PREEMPTED
        rec.preemptions += 1
        self.metrics.preemptions += 1
        fleet_event("park", job=rec.job.job_id, pod=self.name,
                    device=run.slot.index, it=rec.iterations_done)
        run.executor.release()
        self.pool.release(run.slot, rec.job.job_id, rec.footprint_bytes)
        del self.running[rec.job.job_id]
        self.queue.push(rec)   # original seq: regains its queue position

    # ---- execution ---------------------------------------------------------

    def _complete(self, run: _Running) -> None:
        rec = run.record
        rec.result = run.executor.result()
        rec.status = JobStatus.COMPLETED
        rec.end_time = time.monotonic()
        self._mark_terminal_on_disk(rec)
        self.metrics.record_completion(rec.latency, rec.queue_wait)
        fleet_event("complete", job=rec.job.job_id, pod=self.name,
                    device=run.slot.index, measured_s=rec.latency,
                    it=rec.iterations_done,
                    queue_wait_s=rec.queue_wait,
                    priority=rec.job.priority,
                    deadline_s=rec.job.deadline_seconds,
                    **self._cal_attrs(rec.job))
        run.executor.release()
        self.pool.release(run.slot, rec.job.job_id, rec.footprint_bytes)
        del self.running[rec.job.job_id]

    def _observe_step(self, run: _Running, dt: float) -> None:
        run.slot.busy_seconds += dt
        self.metrics.record_step(dt)
        phases = run.executor.take_phase_seconds()
        self.metrics.record_phases(phases)
        modeled = (None if self._step_ema is None
                   else self._step_ema * max(run.passes, 1e-9)
                   + self.modeled_transfer_seconds(run.record.job))
        fleet_event("step", job=run.record.job.job_id, pod=self.name,
                    device=run.slot.index, measured_s=dt,
                    modeled_s=modeled,
                    **self._cal_attrs(run.record.job))
        self.metrics.record_calibration("step", modeled, dt)
        # measured-bandwidth feedback: the staging span seconds the obs
        # layer attributed to this step (critical-path h2d, lookahead
        # prefetch, d2h) against the CommSchedule's modeled bytes give an
        # effective bandwidth.  Once it exists, the staging time is
        # carved out of the unit EMA — the transfer term prices it
        # separately, and double-counting would overstate backlogs
        staging = sum(phases.get(k, 0.0) for k in ("h2d", "prefetch", "d2h"))
        nbytes = run.executor.step_transfer_bytes
        if staging > 0.0 and nbytes > 0:
            bw = nbytes / staging
            self._bandwidth_ema = (bw if self._bandwidth_ema is None
                                   else self._ema_alpha * bw
                                   + (1 - self._ema_alpha)
                                   * self._bandwidth_ema)
            self.metrics.bandwidth_ema_bytes_per_s = self._bandwidth_ema
            dt = max(dt - staging, 0.0)
        # the EMA tracks the *per-pass* unit cost: a streamed step's wall
        # time is divided by its slab-pass multiplier, so steps observed
        # on oversized jobs don't inflate the modeled cost of small ones
        # (deadline admission would otherwise reject in-core jobs whose
        # real steps are orders of magnitude cheaper than the mixed EMA)
        unit = dt / max(run.passes, 1e-9)
        self._step_ema = (unit if self._step_ema is None
                          else self._ema_alpha * unit
                          + (1 - self._ema_alpha) * self._step_ema)

    def _fail_running(self, run: _Running, err: Exception) -> None:
        rec = run.record
        self._fail(rec, f"step failed: {err!r}")
        run.executor.release()
        self.pool.release(run.slot, rec.job.job_id, rec.footprint_bytes)
        del self.running[rec.job.job_id]

    def step_quantum(self) -> int:
        """One cooperative scheduling quantum: admit (executor init runs
        outside the lock, see :meth:`admit`), then advance every running
        job by its fair share of outer iterations — step quanta
        proportional to ``1 + priority``.  Returns the number of iteration
        steps executed."""
        self.admit()
        with self._lock:
            executed = 0
            # deterministic order: device index, then submission order
            for run in sorted(self.running.values(),
                              key=lambda r: (r.slot.index, r.record.seq)):
                if run.record.job.job_id not in self.running:
                    continue   # evicted mid-quantum (defensive)
                rec = run.record
                for _ in range(fair_share_weight(rec.job.priority)):
                    if run.executor.done:
                        break
                    t0 = time.monotonic()
                    try:
                        rec.iterations_done = run.executor.step()
                    except Exception as e:
                        self._fail_running(run, e)
                        break
                    self._observe_step(run, time.monotonic() - t0)
                    executed += 1
                if rec.job.job_id in self.running and run.executor.done:
                    try:
                        self._complete(run)
                    except Exception as e:   # tenant finalize() failure
                        self._fail_running(run, e)
            return executed

    # ---- async-driver execution API ---------------------------------------

    def claim_step(self, slot: DeviceSlot) -> Optional[_Running]:
        """Claim the next job to step on ``slot`` for a worker thread.

        Weighted fair share via stride scheduling: each claim advances the
        job's virtual time by ``1 / weight(priority)``, and the runnable
        job with the smallest virtual time wins — so over any window a
        job's share of the device is proportional to its weight.  Returns
        None when nothing on the slot is runnable.  The caller MUST pair
        every claim with :meth:`finish_step`.
        """
        with self._lock:
            runnable = [r for r in self.running.values()
                        if r.slot is slot and not r.claimed
                        and not r.preempt_requested
                        and not r.executor.done]
            if not runnable:
                return None
            run = min(runnable, key=lambda r: (r.vtime, r.record.seq))
            run.claimed = True
            run.vtime += 1.0 / fair_share_weight(run.record.job.priority)
            return run

    def finish_step(self, run: _Running, dt: float,
                    err: Optional[Exception] = None) -> None:
        """Account for a completed worker step (taken *outside* the lock)
        and resolve any state transition that queued up during it:
        failure, deferred preemption, or completion."""
        with self._lock:
            run.claimed = False
            rec = run.record
            if rec.job.job_id not in self.running:     # defensive
                return
            if err is not None:
                self._fail_running(run, err)
                return
            rec.iterations_done = run.executor.iterations_done
            self._observe_step(run, dt)
            try:
                if run.executor.done:
                    # done wins over a pending preempt flag: parking a
                    # finished job would persist it as resumable work and
                    # pay a full re-init just to mark it done later
                    run.preempt_requested = False
                    self._complete(run)
                elif run.preempt_requested:
                    run.preempt_requested = False
                    self._preempt(run)
                elif run.snapshot_requested:
                    # copy-on-checkpoint: a periodic snapshot found this
                    # job mid-step and deferred to this boundary.  The
                    # state objects are replaced (never mutated) by
                    # step(), so the host copy taken here is exactly the
                    # committed iteration the job would resume from.
                    run.snapshot_requested = False
                    run.boundary_checkpoint = run.executor.checkpoint()
                    run.boundary_iterations = rec.iterations_done
            except Exception as e:
                # a tenant's finalize()/checkpoint() must fail that job
                # alone, never kill the worker thread servicing the slot
                if rec.job.job_id in self.running:
                    self._fail_running(run, e)

    # ---- cooperative loop / drain -----------------------------------------

    def run(self, max_quanta: Optional[int] = None) -> ServeMetrics:
        """Drive the system to completion on the calling thread (or until
        the guard fires / ``max_quanta``).  Safe to call again to resume.
        For true per-device overlap use
        :class:`repro.serve.driver.AsyncDriver` instead."""
        if self.metrics.wall_start is None:
            self.metrics.wall_start = time.monotonic()
        quanta = 0
        while not self.idle:
            if self.guard is not None and self.guard.preempted:
                self.drain(self.snapshot_dir)
                break
            if max_quanta is not None and quanta >= max_quanta:
                break
            self.step_quantum()
            quanta += 1
        self.metrics.wall_end = time.monotonic()
        return self.metrics

    def park_job(self, job_id: str, timeout: float = 30.0) -> bool:
        """Preempt one *running* job at its next step boundary and leave
        it parked in the queue (checkpoint captured, status PREEMPTED) —
        the single-job analogue of :meth:`drain`, and the building block
        of live migration (:func:`repro.serve.steal.migrate_once`).
        Every other job on the pod keeps running.

        Under the async driver a mid-step job is flagged and parks when
        its in-flight step completes; this call waits up to ``timeout``
        for that.  Returns True once the job is parked, False when it is
        not running here (already parked, terminal, or unknown — the
        caller re-checks what it actually wants) or the timeout expired
        with the step still in flight.  Callers that must keep the job
        parked (export it) pause admission first, or the admission loop
        may re-place it immediately."""
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                run = self.running.get(job_id)
                if run is None:
                    rec = self.records.get(job_id)
                    return (rec is not None
                            and rec.status is JobStatus.PREEMPTED)
                if not run.claimed:
                    self._preempt(run)
                    return True
                run.preempt_requested = True
            if time.monotonic() > deadline:
                return False
            time.sleep(0.001)

    def drain(self, ckpt_dir: Optional[str] = None,
              timeout: float = 60.0) -> int:
        """Checkpoint + requeue every running job (host preemption path).

        Jobs mid-step under the async driver are flagged and park at
        their step boundary; this call waits (up to ``timeout``) for the
        running set to empty.  If ``ckpt_dir`` is given, every parked job
        is then persisted there (see :meth:`snapshot`), making the drain
        durable across process death.  Returns how many jobs were parked.
        """
        deadline = time.monotonic() + timeout
        before: Optional[Set[str]] = None
        while True:
            with self._lock:
                if before is None:
                    before = set(self.running)
                for run in list(self.running.values()):
                    if run.claimed:
                        run.preempt_requested = True
                    else:
                        self._preempt(run)
                # also wait out in-flight admissions: a job mid-init is in
                # neither the queue nor `running`, and draining past it
                # would lose it from the snapshot
                if not self.running and self._admitting == 0:
                    break
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"drain: {len(self.running)} jobs still mid-step (and "
                    f"{self._admitting} mid-admission) after {timeout}s")
            time.sleep(0.001)
        with self._lock:
            parked = sum(
                1 for jid in before
                if self.records[jid].status is JobStatus.PREEMPTED)
            fleet_event("drain", pod=self.name, parked=parked)
            if ckpt_dir is not None:
                self.snapshot(ckpt_dir)
        return parked

    # ---- durable snapshots / restore --------------------------------------

    def snapshot(self, ckpt_dir: str, include_running: bool = True) -> int:
        """Persist every *parked* job (queued, with or without a step-wise
        checkpoint) — and, by default, every *running* job's last
        committed step — under ``ckpt_dir``: one directory per job, each
        write going through :func:`repro.checkpoint.sharded.
        save_checkpoint` (manifest + COMMIT marker, atomic rename), so a
        crash mid-snapshot can never corrupt an earlier snapshot of the
        same job.

        Running jobs are snapshotted **without parking them**
        (copy-on-checkpoint): a job at its step boundary (not claimed by
        a worker) has its state copied to host on the spot; a job
        mid-step is flagged and the worker captures the copy at its next
        boundary (``finish_step``), which the next periodic snapshot
        persists.  Algorithm states are replaced — never mutated — by
        ``step()``, so the copy is exactly the committed iteration the
        job would resume from; a kill -9 then replays nothing the last
        snapshot already saw.  The spec keeps its live ``running``
        status (non-terminal), which :func:`_load_job` restores as
        resumable preempted work.

        Only the payload *capture* holds the scheduler lock; the disk
        writes happen outside it, so worker threads keep stepping while a
        periodic snapshot streams arrays to disk.  A job whose persisted
        progress hasn't changed since the last snapshot from this
        scheduler is skipped (a parked job would otherwise rewrite its
        full projections array every period).  Returns the number of jobs
        persisted."""
        with self._lock:
            payloads = []
            for rec in self.queue.pending_records():
                fingerprint = (rec.iterations_done, rec.status.value,
                               rec.preemptions)
                if self._snapshotted.get(rec.job.job_id) == fingerprint:
                    continue
                payloads.append(_job_payload(rec) + (fingerprint, False))
            if include_running:
                for run in self.running.values():
                    rec = run.record
                    if not run.claimed and run.executor.started:
                        ckpt = run.executor.checkpoint()
                        iters = run.executor.iterations_done
                    elif run.boundary_checkpoint is not None:
                        ckpt = run.boundary_checkpoint
                        iters = run.boundary_iterations
                        # one-shot: drop the capture and re-request, so
                        # the next period persists fresh progress
                        # instead of re-offering this copy forever
                        run.boundary_checkpoint = None
                        run.boundary_iterations = -1
                        run.snapshot_requested = True
                    else:
                        # mid-step: ask the worker to capture at its
                        # boundary; the next periodic pass persists it
                        run.snapshot_requested = True
                        continue
                    fingerprint = (iters, rec.status.value,
                                   rec.preemptions)
                    if self._snapshotted.get(rec.job.job_id) \
                            == fingerprint:
                        continue
                    payloads.append(
                        _job_payload(rec, checkpoint=ckpt,
                                     iterations=iters)
                        + (fingerprint, True))
        for job_id, spec, tree, step, fingerprint, was_running in payloads:
            _write_job(ckpt_dir, job_id, spec, tree, step)
            with self._lock:
                self._snapshotted[job_id] = fingerprint
                # the write ran outside the lock: the job may have gone
                # terminal meanwhile (cancel / completion / export to
                # another pod, whose own stale-out no-opped because this
                # spec did not exist yet).  Re-stale it now, or a restart
                # would resurrect — and double-execute — finished work.
                rec = self.records.get(job_id)
                stale_status = None
                if rec is None:
                    stale_status = JobStatus.STOLEN.value   # exported
                elif rec.done:
                    stale_status = rec.status.value
            if stale_status is not None:
                _stale_job_dir(os.path.join(ckpt_dir, "jobs", job_id),
                               stale_status)
            elif was_running:
                fleet_event("live-snapshot", job=job_id, pod=self.name,
                            it=step)
        if payloads:
            fleet_event("snapshot", pod=self.name, jobs=len(payloads))
        return len(payloads)

    def restore(self, ckpt_dir: str,
                data_refs: Optional[Dict[str, Callable]] = None) -> int:
        """Rebuild queue + records from a snapshot directory after process
        death.  Each restored job re-enters the queue with its original
        sequence number and its persisted step-wise checkpoint, so it
        resumes bit-identically to an uninterrupted run.

        ``data_refs`` supplies projection callables for jobs whose data
        was a lazy ref at snapshot time (refs cannot be persisted).

        Failure is loud: a lazy job without a ``data_refs`` entry, a
        truncated job directory (spec.json but no committed step), or a
        job id this scheduler already knows all raise.  Jobs whose spec
        records a terminal status (completed / failed / cancelled /
        stolen) are skipped — they are finished or owned elsewhere, not
        resumable work.

        Two-phase: every job directory is loaded and validated before the
        scheduler is touched, so a validation failure (which raises)
        leaves it unchanged and the call can simply be retried.  Returns
        the number of jobs restored."""
        jobs_root = os.path.join(ckpt_dir, "jobs")
        if not os.path.isdir(jobs_root):
            return 0
        loaded = []
        for job_id in sorted(os.listdir(jobs_root)):
            rec = _load_job(os.path.join(jobs_root, job_id), data_refs or {})
            if rec is not None:
                loaded.append(rec)
        with self._lock:
            dupes = [r.job.job_id for r in loaded
                     if r.job.job_id in self.records]
            if dupes:
                raise ValueError(
                    f"restore: jobs already known to this scheduler: "
                    f"{dupes}")
            for rec in loaded:
                self.records[rec.job.job_id] = rec
                self.queue.push(rec)
                self.metrics.submitted += 1
            if loaded:
                current = next(self._seq)
                self._seq = itertools.count(
                    max(current, max(r.seq for r in loaded) + 1))
        return len(loaded)

    def summary(self) -> Dict:
        return self.metrics.summary(device_busy=self.pool.busy_clocks())

    # ---- multi-pod: load signals + job hand-off (work stealing) ------------

    @property
    def step_seconds_ema(self) -> Optional[float]:
        """Observed *per-pass* unit step cost (EMA; a streamed step's
        wall time is normalised by its slab-pass multiplier before it
        enters the average).  None before any step."""
        return self._step_ema

    @property
    def init_seconds_ema(self) -> Optional[float]:
        """Observed executor init cost (EMA), None before any admission."""
        return self._init_ema

    @property
    def bandwidth_ema(self) -> Optional[float]:
        """Measured host<->device bandwidth (bytes/s) from staging phase
        spans vs the CommSchedule's modeled bytes; None until a traced
        streamed step has been observed."""
        return self._bandwidth_ema

    def modeled_backlog_seconds(self, unit: Optional[float] = None,
                                init: Optional[float] = None) -> float:
        """Modeled seconds of work this scheduler still owes: remaining
        iterations of every queued + running job at the per-pass unit
        cost scaled by each job's slab-pass multiplier, plus a modeled
        (re)init per queued job.  This is the load signal multi-pod
        routing and work stealing balance against.

        ``unit`` / ``init`` override the local EMAs — fleet callers pass
        a *shared* unit so a cold pod (no observations, local fallback
        1.0) and a warm pod (real seconds) compare on the same scale;
        mixing the two would invert victim/thief decisions."""
        with self._lock:
            if unit is None:
                unit = self._step_ema if self._step_ema is not None else 1.0
            if init is None:
                init = self._init_ema or 0.0
            total = 0.0
            for rec in self.queue.pending_records():
                total += init + self._remaining_iters(rec) * (
                    unit * self.job_passes(rec.job)
                    + self.modeled_transfer_seconds(rec.job))
            # mid-admission records (init running outside the lock) are
            # in neither set but still owed work: leaving them out would
            # make the pod look idle to fleet routing/stealing for the
            # whole compile and invite ping-pong moves
            for rec in self._admitting_recs.values():
                total += init + self._remaining_iters(rec) * (
                    unit * self.job_passes(rec.job)
                    + self.modeled_transfer_seconds(rec.job))
            for run in self.running.values():
                total += self._remaining_iters(run.record) * (
                    unit * run.passes
                    + self.modeled_transfer_seconds(run.record.job))
            return total

    #: per-scheduler pricing-memo bound (entries are tiny; the cap only
    #: guards a long-lived server that prices millions of submissions)
    _PRICING_CACHE_MAX = 4096

    @staticmethod
    def _cache_put(cache: Dict, key: str, value) -> None:
        """Insert with FIFO eviction at the bound (python dicts preserve
        insertion order, so the oldest — coldest — entry goes first)."""
        if len(cache) >= Scheduler._PRICING_CACHE_MAX:
            cache.pop(next(iter(cache)))
        cache[key] = value

    def job_passes(self, job: ReconJob) -> float:
        """This job's slab-pass multiplier under the pool's budget (1.0
        when unplannable — the placement path reports that failure).
        Memoised per job id: the budget is fixed for this scheduler's
        lifetime and the load signal is polled often (the fleet steal
        thread), so the pure-python planners must not re-run per poll."""
        cached = self._passes_cache.get(job.job_id)
        if cached is not None:
            return cached
        try:
            passes = modeled_step_passes(job, self.pool.memory)
        except Exception:
            passes = 1.0
        self._cache_put(self._passes_cache, job.job_id, passes)
        return passes

    def job_footprint(self, job: ReconJob) -> JobFootprint:
        """Memoised :func:`estimate_job_footprint` under this pool's
        budget (same rationale as :meth:`job_passes`; raises for an
        unplannable job)."""
        fp = self._footprint_cache.get(job.job_id)
        if fp is None:
            fp = estimate_job_footprint(job, self.pool.memory)
            self._cache_put(self._footprint_cache, job.job_id, fp)
        return fp

    @staticmethod
    def _remaining_iters(rec: JobRecord) -> int:
        alg = get_algorithm(rec.job.algorithm)
        total = max(1, rec.job.n_iter) if alg.iterative else 1
        return max(0, total - rec.iterations_done)

    def steal_candidates(self) -> List[JobRecord]:
        """Parked records another pod could take, cheapest-to-steal last:
        the stealer works from the *tail* (lowest priority, latest
        arrival), so this pod's head-of-line work keeps its position."""
        with self._lock:
            return list(self.queue.pending_records())

    def export_job(self, job_id: str, transfer_dir: str) -> bool:
        """Hand a *parked* (queued or preempted-parked) job off to another
        pod: persist it under ``transfer_dir/jobs/<job_id>`` through
        :func:`repro.checkpoint.sharded.save_checkpoint` (the same
        manifest + COMMIT layout snapshots use — on a real cluster this
        directory is the shared filesystem between hosts), then forget it
        locally.  Running and terminal jobs are never exported; neither
        are jobs whose projections are an unpersistable lazy ref (the
        importer may still supply ``data_refs``, so the *stealer* decides
        whether a lazy job is transferable).  Returns True if the job was
        exported.

        ``transfer_dir`` must not alias this scheduler's own
        ``snapshot_dir``: the periodic snapshot's stale-out pass treats
        any on-disk copy of a job it no longer owns as a stale snapshot,
        and would destroy a live hand-off written to the same path."""
        if (self.snapshot_dir is not None
                and os.path.abspath(self.snapshot_dir)
                == os.path.abspath(transfer_dir)):
            raise ValueError(
                f"export_job: transfer_dir {transfer_dir!r} aliases this "
                f"scheduler's snapshot_dir; hand-offs and durable "
                f"snapshots must use distinct directories")
        with self._lock:
            rec = self.queue.remove(job_id)
            if rec is None:
                return False
            payload = _job_payload(rec)
            del self.records[job_id]
            self._snapshotted.pop(job_id, None)
        try:
            _write_job(transfer_dir, *payload)
        except BaseException:
            with self._lock:      # failed hand-off: the job stays ours
                self.records[job_id] = rec
                self.queue.push(rec)
            raise
        with self._lock:
            self.metrics.stolen_out += 1
        fleet_event("export", job=job_id, pod=self.name,
                    it=rec.iterations_done)
        # a periodic snapshot may also have persisted this job under our
        # own snapshot_dir (distinct from transfer_dir, checked above);
        # flip that copy to "stolen" so a restart of *this* pod cannot
        # resurrect (and double-execute) it
        rec.status = JobStatus.STOLEN
        self._mark_terminal_on_disk(rec)
        return True

    def import_job(self, transfer_dir: str, job_id: str,
                   data_refs: Optional[Dict[str, Callable]] = None) -> str:
        """Adopt a job another pod exported with :meth:`export_job`: load
        its spec + latest committed step from ``transfer_dir`` and enqueue
        it here.  The step-wise checkpoint travels with it, so the job
        resumes on this pod bit-identically to never having moved.

        On success the transfer copy is *consumed*: its spec is flipped
        to ``stolen`` first (atomic replace — a crash before the delete
        cannot leave a resumable duplicate for a later restore over the
        transfer dir to double-execute) and the directory is then
        removed, so a long-lived fleet does not leak one full checkpoint
        per steal on the shared mount.  Failed imports (missing data
        ref, duplicate id) leave the copy intact for a retry.

        A scheduler with a ``snapshot_dir`` persists the adopted job
        there *before* consuming the transfer copy: the victim's own
        snapshot of the job is already a ``stolen`` tombstone, so
        without this a kill -9 after the steal (job admitted on the
        thief, never parked again) would lose the job from every
        snapshot on disk."""
        job_dir = os.path.join(transfer_dir, "jobs", job_id)
        rec = _load_job(job_dir, data_refs or {})
        if rec is None:
            raise ValueError(f"import_job: no resumable job at "
                             f"{transfer_dir}/jobs/{job_id}")
        with self._lock:
            if rec.job.job_id in self.records:
                raise ValueError(f"import_job: {rec.job.job_id} already "
                                 f"known to this scheduler")
            self.records[rec.job.job_id] = rec
            self.queue.push(rec)
            self.metrics.stolen_in += 1
            fleet_event("import", job=rec.job.job_id, pod=self.name,
                        it=rec.iterations_done)
            current = next(self._seq)
            self._seq = itertools.count(max(current, rec.seq + 1))
            snapshot_dir = self.snapshot_dir
            payload = _job_payload(rec) if snapshot_dir else None
            fingerprint = (rec.iterations_done, rec.status.value,
                           rec.preemptions)
        if payload is not None:
            _write_job(snapshot_dir, *payload)
            with self._lock:
                self._snapshotted[rec.job.job_id] = fingerprint
                # the write ran outside the lock: a fast job may have
                # been admitted and finished meanwhile, and its own
                # terminal stale-out no-opped (no spec on disk yet).
                # Re-stale now or a restart would re-execute it (same
                # discipline as snapshot()).
                stale_status = rec.status.value if rec.done else None
            if stale_status is not None:
                _stale_job_dir(os.path.join(snapshot_dir, "jobs",
                                            rec.job.job_id), stale_status)
        _consume_transfer_copy(job_dir)
        return rec.job.job_id

    def reclaim_export(self, transfer_dir: str, job_id: str,
                       data_refs: Optional[Dict[str, Callable]] = None
                       ) -> str:
        """Undo an :meth:`export_job` whose import on the thief failed:
        re-adopt the (intact) transfer copy ourselves and cancel the
        steal accounting, so the job is never stranded in no scheduler.
        The stealer calls this when the thief raises mid-transfer."""
        jid = self.import_job(transfer_dir, job_id, data_refs=data_refs)
        with self._lock:
            self.metrics.stolen_in -= 1
            self.metrics.stolen_out -= 1
        return jid


# --------------------------------------------------------------------------
# durable job persistence (one directory per job under <ckpt_dir>/jobs/)
#
#   jobs/<job_id>/
#     spec.json              # job spec + record metadata (atomic replace)
#     step_XXXXXXXX/         # save_checkpoint output: manifest + COMMIT
#       manifest.json        # {"step": N, "leaves": {key: file/shape/dtype}}
#       leaf_*.npy           # angles, projections, state.<field> leaves
#       COMMIT               # written last: the step's crash-safe marker
#
# The step directory is exactly what repro.checkpoint.sharded writes: the
# manifest maps each flat tree key ("['angles']", "['projections']",
# "['state.x']", ...) to its leaf file, shape and dtype, and COMMIT is
# created only after every leaf + the manifest are on disk.  Restore
# trusts *only* committed steps: manifest_target() rebuilds the flat
# {name: zeros} tree from the manifest alone (a restarted process has no
# in-memory structure to validate against) and restore_checkpoint() then
# fills it, re-checking every leaf's shape.  State leaves carry a
# "state." prefix to keep them apart from the job's input data; python
# scalars among them record their type in spec.json ("scalar_types") so
# disk restore hands back exactly what the in-memory preemption path
# produces (np.save would widen an int into a 0-d int64 array).
#
# The step number is the job's completed iteration count, so repeated
# snapshots of a progressing job accumulate (GC keeps the latest two) and
# latest_step() always names the most advanced committed state.
#
# The same layout moves jobs *between* pods: export_job() writes one
# jobs/<job_id> directory under a transfer dir, import_job() reads it.
# --------------------------------------------------------------------------

_STATE_PREFIX = "state."
_TERMINAL = ("completed", "failed", "cancelled", "stolen")


def _scalar_tag(v) -> str:
    """Python-type tag for a checkpoint field, so disk restore hands back
    exactly the types the in-memory preemption path produces (np.save
    would otherwise widen e.g. a python int into a 0-d int64 array)."""
    if v is None:
        return "none"
    if isinstance(v, bool):
        return "bool"
    if isinstance(v, int):
        return "int"
    if isinstance(v, float):
        return "float"
    return "array"


def _job_payload(rec: JobRecord,
                 checkpoint: Optional[Dict[str, Any]] = None,
                 iterations: Optional[int] = None
                 ) -> Tuple[str, Dict, Dict[str, Any], int]:
    """Capture everything :func:`_write_job` needs, under the scheduler
    lock: a shallow copy of the checkpoint dict (the arrays themselves are
    never mutated, only replaced) so a concurrent re-admission clearing
    ``rec.checkpoint`` cannot race the disk write.

    ``checkpoint`` / ``iterations`` override the record's own parked
    state: a *running* job has ``rec.checkpoint is None`` (cleared at
    admission), so live snapshots pass the executor's step-boundary copy
    and its committed iteration count explicitly."""
    job = rec.job
    ckpt = rec.checkpoint if checkpoint is None else checkpoint
    iters = rec.iterations_done if iterations is None else iterations
    tree: Dict[str, Any] = {"angles": np.asarray(job.angles, np.float32)}
    projections_persisted = not callable(job.projections)
    if projections_persisted:
        tree["projections"] = np.asarray(job.projections)
    scalar_types: Dict[str, str] = {}
    if ckpt is not None:
        for k, v in ckpt.items():
            tag = _scalar_tag(v)
            scalar_types[k] = tag
            if tag != "none":      # None fields rebuilt from the tag alone
                tree[_STATE_PREFIX + k] = v
    spec = {
        "job_id": job.job_id,
        "algorithm": job.algorithm,
        "geo": dataclasses.asdict(job.geo),
        "n_iter": job.n_iter,
        "priority": job.priority,
        "params": job.params,
        "memory_hint_bytes": job.memory_hint_bytes,
        "mode": job.mode,
        "backend": job.backend,
        "deadline_seconds": job.deadline_seconds,
        "seq": rec.seq,
        "status": rec.status.value,
        "iterations_done": iters,
        "preemptions": rec.preemptions,
        "has_state": ckpt is not None,
        "scalar_types": scalar_types,
        "projections_persisted": projections_persisted,
    }
    return job.job_id, spec, tree, iters


def _write_job(ckpt_dir: str, job_id: str, spec: Dict,
               tree: Dict[str, Any], step: int) -> None:
    job_dir = os.path.join(ckpt_dir, "jobs", job_id)
    os.makedirs(job_dir, exist_ok=True)
    # step data commits before the spec: a crash in between leaves an old
    # spec next to a newer committed step (harmless — _load_job trusts the
    # committed step for progress), never a new spec pointing at state
    # that was never committed
    save_checkpoint(job_dir, step=step, tree=tree, keep=2)
    _atomic_write_json(os.path.join(job_dir, "spec.json"), spec)


def _atomic_write_json(path: str, obj: Dict) -> None:
    """Write ``obj`` as json via a temp file + atomic rename, so readers
    only ever see a complete document (the one spec-write discipline
    shared by snapshot, stale-out and transfer consumption)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1)
    os.replace(tmp, path)


def _set_spec_status(job_dir: str, status: str) -> bool:
    """Atomically rewrite ``job_dir/spec.json`` with ``status``; False if
    there is no (readable) spec to rewrite."""
    spec_path = os.path.join(job_dir, "spec.json")
    if not os.path.isfile(spec_path):
        return False
    try:
        with open(spec_path) as f:
            spec = json.load(f)
        spec["status"] = status
        _atomic_write_json(spec_path, spec)
        return True
    except (OSError, ValueError):
        # dir vanished or spec corrupt: nothing trustworthy to rewrite
        return False


def _stale_job_dir(job_dir: str, status: str) -> None:
    """Best-effort retirement of a persisted job: terminal spec first
    (atomic — the moment it lands, no restore will resurrect the job),
    then reclaim the step directories' bytes.  Spec-less step data is
    ignored by :func:`_load_job`, so a crash between the two leaves
    nothing resumable either way."""
    if not _set_spec_status(job_dir, status):
        return
    try:
        for d in os.listdir(job_dir):
            if d.startswith("step_"):
                shutil.rmtree(os.path.join(job_dir, d), ignore_errors=True)
    except OSError:
        pass


def _consume_transfer_copy(job_dir: str) -> None:
    """Retire a successfully-imported transfer directory: mark the spec
    ``stolen`` (atomic), then delete the directory.  Best-effort — a
    shared-mount hiccup must not fail the import that already
    succeeded, and the terminal spec alone is enough to keep any later
    restore from resurrecting the copy."""
    if _set_spec_status(job_dir, "stolen"):
        shutil.rmtree(job_dir, ignore_errors=True)


def _geo_from_spec(d: Dict) -> ConeGeometry:
    return ConeGeometry(**{k: tuple(v) if isinstance(v, list) else v
                           for k, v in d.items()})


def _load_job(job_dir: str,
              data_refs: Dict[str, Callable]) -> Optional[JobRecord]:
    spec_path = os.path.join(job_dir, "spec.json")
    if not os.path.isfile(spec_path):
        return None
    with open(spec_path) as f:
        spec = json.load(f)
    if spec["status"] in _TERMINAL:
        return None
    step = latest_step(job_dir)
    if step is None:
        # the writer commits step data *before* the spec, so a live spec
        # with no committed step means the snapshot was truncated or
        # tampered with -- refuse loudly instead of silently dropping a
        # job the operator believes is parked safely on disk
        raise ValueError(
            f"restore: job {spec['job_id']} has spec.json but no committed "
            f"step directory under {job_dir} (missing/removed COMMIT?); "
            f"snapshot is truncated -- refusing to resume silently")
    tree = restore_checkpoint(job_dir, step, manifest_target(job_dir, step))
    angles = np.asarray(tree.pop("angles"), np.float32)
    if spec["projections_persisted"]:
        projections: Any = np.asarray(tree.pop("projections"))
    else:
        projections = data_refs.get(spec["job_id"])
        if projections is None:
            raise ValueError(
                f"restore: job {spec['job_id']} was submitted with a lazy "
                f"data ref, which cannot be persisted; pass "
                f"data_refs={{{spec['job_id']!r}: <callable>}}")
    ckpt: Optional[Dict[str, Any]] = None
    if spec["has_state"]:
        ckpt = {}
        for name, tag in spec["scalar_types"].items():
            if tag == "none":
                ckpt[name] = None
            elif tag == "bool":
                ckpt[name] = bool(tree[_STATE_PREFIX + name])
            elif tag == "int":
                ckpt[name] = int(tree[_STATE_PREFIX + name])
            elif tag == "float":
                ckpt[name] = float(tree[_STATE_PREFIX + name])
            else:
                ckpt[name] = np.asarray(tree[_STATE_PREFIX + name])
    job = ReconJob(spec["algorithm"], _geo_from_spec(spec["geo"]), angles,
                   projections, n_iter=spec["n_iter"],
                   priority=spec["priority"], params=spec["params"],
                   memory_hint_bytes=spec["memory_hint_bytes"],
                   mode=spec["mode"],
                   # absent in pre-backend snapshots: None = auto-resolve
                   backend=spec.get("backend"),
                   deadline_seconds=spec["deadline_seconds"],
                   job_id=spec["job_id"])
    return JobRecord(
        job=job, seq=spec["seq"],
        status=JobStatus.PREEMPTED if ckpt is not None else JobStatus.PENDING,
        submit_time=time.monotonic(),
        # progress comes from the *committed* step, not the spec: the two
        # can disagree only across a crash window, and the step directory
        # is what the job will actually resume from
        iterations_done=step,
        preemptions=spec["preemptions"],
        checkpoint=ckpt)
