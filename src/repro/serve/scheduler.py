"""Multi-tenant job scheduler: placement, fair-share interleaving, preemption.

This is the serving layer the paper's planners make possible: because
``plan_forward`` / ``plan_backward`` can *predict* a reconstruction's
per-device footprint before any array is allocated, the scheduler can pack
several small jobs onto one device, route oversized jobs through the
out-of-core streaming path (whose working set is bounded by the device
budget no matter how large the volume), and know ahead of time that a
placement fits.

Execution model
---------------
Jobs advance in *quanta*: each quantum, every running job is stepped by one
outer iteration of its algorithm (fair-share round-robin), so a long
low-priority reconstruction cannot starve short jobs that land next to it.
Priorities order admission, and a high-priority arrival that does not fit
preempts the lowest-priority running job: its resumable state (see
``repro.core.algorithms.stepwise``) is checkpointed to host memory, its
device reservation is released, and it re-enters the queue with its
original position, resuming later with bit-identical results.

A :class:`~repro.checkpoint.preemption.PreemptionGuard` can be attached;
when the guard fires (SIGTERM on a cloud host), the scheduler drains at the
next quantum boundary: all running jobs are checkpointed and requeued, so a
restarted scheduler resumes them without losing completed iterations.

The device pool is either real (one slot per JAX device) or simulated
(slots with a byte budget only) — placement logic is identical, which is
how the tests drive a "multi-GPU" pool on a CPU host.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence, Set

from ..core.algorithms.stepwise import get_algorithm
from ..core.splitting import MemoryModel, plan_backward, plan_forward
from .executor import JobExecutor
from .job import JobRecord, JobStatus, ReconJob
from .metrics import ServeMetrics
from .queue import PriorityJobQueue

F32 = 4

# Peak live arrays per algorithm: (volume-sized, projection-set-sized).
# Used for the *resident* footprint of in-core jobs; streaming jobs are
# bounded by the planner's slab + buffer working set instead.
_ALG_WORKSPACE = {
    "cgls": (3, 3),        # x, p, s  /  b, r, q
    "fista": (3, 2),       # x, y, z  /  b, A(y)
    "fista_tv": (3, 2),
    "ossart": (3, 3),      # x, upd, V / proj, resid, W
    "sirt": (3, 3),
    "sart": (3, 3),
    "asd_pocs": (4, 3),    # ossart set + x_prev
    "fdk": (2, 2),         # vol, acc / proj, filtered
}
_DEFAULT_WORKSPACE = (4, 3)


@dataclasses.dataclass(frozen=True)
class JobFootprint:
    """Planner-derived placement requirements for one job."""
    bytes_on_device: int
    streams: bool           # must run through the out-of-core executor


def estimate_job_footprint(job: ReconJob,
                           memory: MemoryModel) -> JobFootprint:
    """Per-device bytes the job needs under ``memory``, and whether it must
    stream.  Mirrors the paper's "check GPU memory / split" decision
    (Alg 1-2): if the planners would split the volume, the job cannot be
    held resident and is routed out-of-core."""
    geo, n_angles = job.geo, job.n_angles
    plan_f = plan_forward(geo, n_angles, 1, memory)
    plan_b = plan_backward(geo, n_angles, 1, memory)
    streams = plan_f.n_slabs > 1 or plan_b.n_slabs > 1
    if job.mode == "plain":
        streams = False
    elif job.mode == "stream":
        streams = True

    if streams:
        bytes_needed = max(
            plan_f.bytes_image_slab + plan_f.bytes_proj_buffers,
            plan_b.bytes_image_slab + plan_b.bytes_proj_buffers)
    else:
        nz, ny, nx = geo.n_voxel
        nv, nu = geo.n_detector
        n_vol, n_proj = _ALG_WORKSPACE.get(job.algorithm,
                                           _DEFAULT_WORKSPACE)
        bytes_needed = (n_vol * nz * ny * nx * F32
                        + n_proj * n_angles * nv * nu * F32)
    if job.memory_hint_bytes:
        bytes_needed = job.memory_hint_bytes
    return JobFootprint(bytes_needed, streams)


@dataclasses.dataclass
class DeviceSlot:
    """One device's capacity ledger (real JAX device or simulated)."""
    index: int
    memory: MemoryModel
    jax_device: Optional[Any] = None
    committed_bytes: int = 0
    busy_seconds: float = 0.0           # virtual per-device clock
    jobs: Set[str] = dataclasses.field(default_factory=set)

    @property
    def free_bytes(self) -> int:
        return self.memory.usable - self.committed_bytes


class DevicePool:
    """Homogeneous pool of device slots.

    ``policy`` selects the placement heuristic among the slots that fit:

    * ``"spread"`` (default): least-loaded first (fewest resident jobs,
      then most free bytes) — maximises device parallelism, the serving
      throughput choice.
    * ``"pack"``: tightest fit first — minimises fragmentation, keeps
      large holes open for large jobs.
    """

    def __init__(self, n_devices: int = 1,
                 memory: Optional[MemoryModel] = None,
                 jax_devices: Optional[Sequence] = None,
                 max_jobs_per_device: Optional[int] = None,
                 policy: str = "spread"):
        if policy not in ("spread", "pack"):
            raise ValueError(f"unknown placement policy {policy!r}")
        self.memory = memory or MemoryModel()
        if jax_devices is not None:
            n_devices = len(jax_devices)
        self.slots = [
            DeviceSlot(i, self.memory,
                       jax_devices[i] if jax_devices is not None else None)
            for i in range(n_devices)]
        self.max_jobs_per_device = max_jobs_per_device
        self.policy = policy

    def best_fit(self, bytes_needed: int) -> Optional[DeviceSlot]:
        """Pick a slot that fits ``bytes_needed`` under the pool policy."""
        candidates = [
            s for s in self.slots
            if s.free_bytes >= bytes_needed
            and (self.max_jobs_per_device is None
                 or len(s.jobs) < self.max_jobs_per_device)]
        if not candidates:
            return None
        if self.policy == "pack":
            return min(candidates, key=lambda s: (s.free_bytes, s.index))
        return min(candidates,
                   key=lambda s: (len(s.jobs), -s.free_bytes, s.index))

    def commit(self, slot: DeviceSlot, job_id: str, nbytes: int) -> None:
        slot.committed_bytes += nbytes
        slot.jobs.add(job_id)

    def release(self, slot: DeviceSlot, job_id: str, nbytes: int) -> None:
        slot.committed_bytes -= nbytes
        slot.jobs.discard(job_id)

    def busy_clocks(self) -> List[float]:
        return [s.busy_seconds for s in self.slots]

    @property
    def fits_nowhere_bytes(self) -> int:
        """A job above this can never be placed, even on an empty pool."""
        return self.memory.usable


@dataclasses.dataclass
class _Running:
    record: JobRecord
    executor: JobExecutor
    slot: DeviceSlot


class Scheduler:
    """Accepts :class:`ReconJob` submissions and drives them to completion.

    Usage::

        sched = Scheduler(n_devices=4, memory=MemoryModel(...))
        sched.submit(job_a); sched.submit(job_b)
        sched.run()
        rec = sched.records[job_a.job_id].result
    """

    def __init__(self, pool: Optional[DevicePool] = None,
                 n_devices: int = 1,
                 memory: Optional[MemoryModel] = None,
                 metrics: Optional[ServeMetrics] = None,
                 guard=None):
        self.pool = pool or DevicePool(n_devices, memory)
        self.queue = PriorityJobQueue()
        self.records: Dict[str, JobRecord] = {}
        self.running: Dict[str, _Running] = {}
        self.metrics = metrics or ServeMetrics()
        self.guard = guard
        self._seq = itertools.count()

    # ---- client API --------------------------------------------------------

    def submit(self, job: ReconJob) -> str:
        get_algorithm(job.algorithm)   # fail fast on unknown algorithms
        rec = JobRecord(job=job, seq=next(self._seq),
                        submit_time=time.monotonic())
        self.records[job.job_id] = rec
        self.queue.push(rec)
        self.metrics.submitted += 1
        return job.job_id

    def cancel(self, job_id: str) -> bool:
        """Cancel a queued (not yet running) job."""
        ok = self.queue.cancel(job_id)
        if ok:
            self.metrics.cancelled += 1
        return ok

    def result(self, job_id: str):
        rec = self.records[job_id]
        if rec.status is not JobStatus.COMPLETED:
            raise RuntimeError(f"{job_id} is {rec.status.value}"
                               + (f": {rec.error}" if rec.error else ""))
        return rec.result

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running

    # ---- placement ---------------------------------------------------------

    def _fail(self, rec: JobRecord, msg: str) -> None:
        rec.status = JobStatus.FAILED
        rec.error = msg
        rec.end_time = time.monotonic()
        self.metrics.failed += 1

    def _place(self, rec: JobRecord) -> bool:
        """Try to admit one record onto the pool.  Returns True if the
        record was consumed (placed, completed trivially, or failed)."""
        try:
            fp = estimate_job_footprint(rec.job, self.pool.memory)
        except MemoryError as e:
            self._fail(rec, f"unplannable under device budget: {e}")
            return True
        if fp.bytes_on_device > self.pool.fits_nowhere_bytes:
            self._fail(rec, f"footprint {fp.bytes_on_device} B exceeds the "
                            f"device budget {self.pool.fits_nowhere_bytes} B "
                            f"even on an empty device")
            return True
        slot = self.pool.best_fit(fp.bytes_on_device)
        if slot is None:
            return False

        try:
            # one tenant's bad geometry / data ref / algorithm params must
            # fail that job alone, never the scheduler serving the others
            executor = JobExecutor(
                rec.job, mode="stream" if fp.streams else "plain",
                memory=self.pool.memory,
                devices=([slot.jax_device] if slot.jax_device is not None
                         else None))
            executor.start(checkpoint=rec.checkpoint)
        except Exception as e:
            self._fail(rec, f"init failed: {e!r}")
            return True
        rec.checkpoint = None
        rec.status = JobStatus.RUNNING
        rec.device = slot.index
        rec.footprint_bytes = fp.bytes_on_device
        rec.streamed = fp.streams
        if fp.streams:
            self.metrics.streamed_jobs += 1
        if rec.start_time is None:
            rec.start_time = time.monotonic()
        slot.busy_seconds += executor.init_seconds
        self.pool.commit(slot, rec.job.job_id, fp.bytes_on_device)
        self.running[rec.job.job_id] = _Running(rec, executor, slot)
        return True

    def _try_admit(self) -> None:
        """Admit queued jobs in priority order; on a full pool, preempt
        strictly-lower-priority running work for the head job."""
        while True:
            if self.queue.peek_priority() is None:
                return
            rec = self.queue.pop()
            if rec is None:
                return
            if self._place(rec):
                continue
            if self._preempt_for(rec):
                continue
            # head job cannot be placed: put it back and stop admitting
            # (strict priority order -- no backfilling past the head).
            self.queue.push(rec)
            return

    def _preempt_for(self, rec: JobRecord) -> bool:
        """Evict lowest-priority running jobs (strictly below ``rec``'s
        priority) until ``rec`` fits; undo nothing if it never fits."""
        while True:
            victims = [r for r in self.running.values()
                       if r.record.job.priority < rec.job.priority]
            if not victims:
                return False
            victim = min(victims,
                         key=lambda r: (r.record.job.priority,
                                        -r.record.seq))
            self._preempt(victim)
            if self._place(rec):
                return True

    def _preempt(self, run: _Running) -> None:
        rec = run.record
        rec.checkpoint = run.executor.checkpoint()
        rec.status = JobStatus.PREEMPTED
        rec.preemptions += 1
        self.metrics.preemptions += 1
        run.executor.release()
        self.pool.release(run.slot, rec.job.job_id, rec.footprint_bytes)
        del self.running[rec.job.job_id]
        self.queue.push(rec)   # original seq: regains its queue position

    # ---- execution ---------------------------------------------------------

    def _complete(self, run: _Running) -> None:
        rec = run.record
        rec.result = run.executor.result()
        rec.status = JobStatus.COMPLETED
        rec.end_time = time.monotonic()
        self.metrics.record_completion(rec.latency, rec.queue_wait)
        run.executor.release()
        self.pool.release(run.slot, rec.job.job_id, rec.footprint_bytes)
        del self.running[rec.job.job_id]

    def step_quantum(self) -> int:
        """One scheduling quantum: admit, then advance every running job by
        one outer iteration (fair-share round-robin).  Returns the number
        of iteration steps executed."""
        self._try_admit()
        executed = 0
        # deterministic order: device index, then submission order
        for run in sorted(self.running.values(),
                          key=lambda r: (r.slot.index, r.record.seq)):
            if run.record.job.job_id not in self.running:
                continue   # evicted mid-quantum (defensive)
            rec = run.record
            if not run.executor.done:
                t0 = time.monotonic()
                try:
                    rec.iterations_done = run.executor.step()
                except Exception as e:
                    self._fail(rec, f"step failed: {e!r}")
                    run.executor.release()
                    self.pool.release(run.slot, rec.job.job_id,
                                      rec.footprint_bytes)
                    del self.running[rec.job.job_id]
                    continue
                dt = time.monotonic() - t0
                run.slot.busy_seconds += dt
                self.metrics.record_step(dt)
                executed += 1
            if run.executor.done:
                self._complete(run)
        return executed

    def run(self, max_quanta: Optional[int] = None) -> ServeMetrics:
        """Drive the system until all work is done (or the guard fires, or
        ``max_quanta`` is reached).  Safe to call again to resume."""
        if self.metrics.wall_start is None:
            self.metrics.wall_start = time.monotonic()
        quanta = 0
        while not self.idle:
            if self.guard is not None and self.guard.preempted:
                self.drain()
                break
            if max_quanta is not None and quanta >= max_quanta:
                break
            self.step_quantum()
            quanta += 1
        self.metrics.wall_end = time.monotonic()
        return self.metrics

    def drain(self) -> int:
        """Checkpoint + requeue every running job (host preemption path).
        Returns how many jobs were parked."""
        parked = 0
        for run in list(self.running.values()):
            self._preempt(run)
            parked += 1
        return parked

    def summary(self) -> Dict:
        return self.metrics.summary(device_busy=self.pool.busy_clocks())
