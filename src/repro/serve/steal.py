"""Work stealing between pods: idle pods take parked jobs from loaded ones.

Static per-pod partitioning (each tenant pinned to "their" pod) strands
capacity the moment arrivals are imbalanced: one pod builds a backlog
while another sits idle.  The stealing protocol here closes that gap
without any central queue:

* each pod's :class:`~repro.serve.scheduler.Scheduler` exposes a load
  signal (:meth:`Scheduler.modeled_backlog_seconds` — remaining iterations
  of queued + running work at the observed step cost, normalised per
  device) and a list of *parked* records a thief could take
  (:meth:`Scheduler.steal_candidates`);
* a :func:`steal_pass` ranks pods by that signal and moves jobs from the
  most loaded pod to the least loaded one while the imbalance exceeds
  :class:`StealPolicy` thresholds;
* the transfer is the *same* on-disk format durable snapshots use
  (:mod:`repro.checkpoint.sharded` manifest + COMMIT, one directory per
  job under ``transfer_dir/jobs/``): the victim's
  :meth:`Scheduler.export_job` persists spec + latest step-wise
  checkpoint and forgets the job; the thief's
  :meth:`Scheduler.import_job` loads and enqueues it.  Because the
  checkpoint carries every recurrence variable and ``init`` is
  deterministic, the stolen job finishes **bit-identically** to never
  having moved (asserted in tests and ``benchmarks/bench_serve.py``).

Steal victims are taken from the *tail* of the victim's queue (lowest
priority, latest arrival) — the classic deque discipline — so the
victim's head-of-line work keeps its position and only surplus moves.

Lazy data refs (callables) cannot be serialised; a lazy job is stolen
only when the stealer's ``data_refs`` can re-resolve it on the thief
(think: an object-store URI both hosts can read), otherwise it is
skipped.

On a real cluster ``transfer_dir`` is a filesystem both host groups
mount; on a single host it is just a scratch directory.  Either way the
COMMIT marker means a crash mid-transfer can never lose the job: the
victim forgets it only after the write commits, and an uncommitted
transfer directory is invisible to :meth:`Scheduler.import_job`.

The same transfer machinery also empties a whole pod:
:func:`drain_pod` is the autoscaler's scale-down path — pause the
pod's admission, preempt its running jobs at their step boundaries,
then export *everything* to the surviving pods (see
:mod:`repro.serve.autoscale`).

For *extreme* imbalance the parked-only discipline is not enough: a
victim whose surplus is entirely running work has nothing parked to
steal.  :func:`migrate_once` generalizes the drain machinery to a
single job — preempt it at its step boundary, export, import on the
thief — gated by ``StealPolicy.migrate_min_imbalance_seconds`` and a
benefit check that also prices the one-off copy against the measured
bandwidth EMA.  The checkpoint travels, so a migrated job, too,
finishes bit-identically to never having moved.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..obs import fleet_event
from .scheduler import Scheduler


@dataclasses.dataclass(frozen=True)
class StealPolicy:
    """Thresholds that keep stealing from thrashing.

    A steal moves real bytes (checkpoint + projections) between pods, so
    it must only happen when the imbalance is worth the copy.
    """

    #: victim's per-device modeled backlog must exceed the thief's by this
    #: many modeled seconds before anything moves
    min_imbalance_seconds: float = 0.0
    #: victim must still have at least this many parked jobs *after* the
    #: steal (never steal a pod's last queued job out from under a device
    #: that is about to free up) — 0 allows draining the queue entirely
    min_victim_queue_after: int = 0
    #: at most this many jobs move per :func:`steal_pass` call.  The
    #: benefit check (a move must not invert the imbalance) is what
    #: stops a pass, so the default is generous: under CPU contention
    #: the stealing thread may get scheduled rarely, and the first pass
    #: must be allowed to balance the fleet in one go.
    max_jobs_per_pass: int = 16
    #: live-migration trigger (:func:`migrate_once`): when the pass's
    #: pinned (victim, thief) imbalance exceeds this many modeled
    #: seconds and no parked job moved, one *running* victim job is
    #: preempted at its step boundary and moved live.  None disables
    #: live migration — parked-only stealing, the historical behaviour.
    migrate_min_imbalance_seconds: Optional[float] = None


def fleet_units(pods: Sequence) -> Tuple[float, float]:
    """Fleet-wide fallback (per-pass unit cost, init cost) for pods with
    no local observations: the mean of the warm pods' EMAs, or (1.0, 0)
    on an entirely cold fleet.  Comparing a cold pod's constant-unit
    backlog against a warm pod's real-seconds backlog would invert
    victim/thief (and routing) decisions — e.g. ship work *to* the
    overloaded warm pod because its tiny EMA makes its backlog look
    smaller — so every fleet-level comparison shares these units."""
    emas = [p.scheduler.step_seconds_ema for p in pods
            if p.scheduler.step_seconds_ema is not None]
    inits = [p.scheduler.init_seconds_ema for p in pods
             if p.scheduler.init_seconds_ema is not None]
    unit = sum(emas) / len(emas) if emas else 1.0
    init = sum(inits) / len(inits) if inits else 0.0
    return unit, init


def effective_units(scheduler: Scheduler, default_unit: Optional[float],
                    default_init: Optional[float]
                    ) -> Tuple[Optional[float], Optional[float]]:
    """Resolve one pod's (unit, init): its own observed EMAs where it has
    them, the fleet-wide fallbacks otherwise.  The single place the
    warm-beats-fallback rule lives — every fleet comparison (backlog
    ranking, steal cost, routing) must resolve units through here or the
    shared-scale guarantee silently breaks."""
    unit = scheduler.step_seconds_ema
    init = scheduler.init_seconds_ema
    return (default_unit if unit is None else unit,
            default_init if init is None else init)


def pod_load(scheduler: Scheduler, n_devices: int,
             unit: Optional[float] = None,
             init: Optional[float] = None) -> float:
    """Per-device modeled backlog: the signal pods are ranked by.  Pass
    the :func:`fleet_units` fallbacks when comparing across pods; the
    pod's own EMAs still win where it has them."""
    unit, init = effective_units(scheduler, unit, init)
    return (scheduler.modeled_backlog_seconds(unit=unit, init=init)
            / max(1, n_devices))


def _stealable(rec, thief, data_refs: Dict[str, Callable]) -> bool:
    """Can this parked record run on the thief pod at all?"""
    if callable(rec.job.projections) and rec.job.job_id not in data_refs:
        return False               # lazy ref the thief cannot re-resolve
    try:
        fp = thief.scheduler.job_footprint(rec.job)   # memoised
    except Exception:
        return False               # unplannable under the thief's budget
    return fp.bytes_on_device <= thief.pool.fits_nowhere_bytes


def steal_once(victim, thief, transfer_dir: str,
               data_refs: Optional[Dict[str, Callable]] = None,
               policy: StealPolicy = StealPolicy(),
               exclude: Sequence[str] = (),
               units: Optional[Tuple[float, float]] = None) -> Optional[str]:
    """Move one parked job from the ``victim`` pod to the ``thief`` pod
    (each exposing ``.scheduler``, ``.pool``, ``.n_devices``) through
    ``transfer_dir``.  Scans the victim's queue from the tail for the
    first record the thief can hold, exports it (manifest + COMMIT) and
    imports it on the thief.  Returns the stolen job id, or None if
    nothing moved.

    A candidate is skipped when adopting it would load the thief past
    the victim's *current* load — a steal that inverts the imbalance
    would just be stolen back (ping-pong), moving bytes for nothing.
    ``exclude`` lists jobs a caller has already moved this pass;
    ``units`` is the :func:`fleet_units` pair (computed over this pod
    pair when not given) keeping cold/warm pods on one scale.

    If the thief's import fails after a successful export (transient
    shared-mount error, validation failure), the victim *reclaims* the
    intact transfer copy — a submitted job must never end up in no
    scheduler — and the original error propagates only if the reclaim
    itself also fails."""
    data_refs = data_refs or {}
    candidates = victim.scheduler.steal_candidates()
    if len(candidates) <= policy.min_victim_queue_after:
        return None
    default_unit, default_init = units or fleet_units((victim, thief))
    victim_load = pod_load(victim.scheduler, victim.n_devices,
                           unit=default_unit, init=default_init)
    thief_load = pod_load(thief.scheduler, thief.n_devices,
                          unit=default_unit, init=default_init)
    unit, init = effective_units(thief.scheduler, default_unit,
                                 default_init)
    for rec in reversed(candidates):       # tail first: surplus work
        jid = rec.job.job_id
        if jid in exclude:
            continue
        if not _stealable(rec, thief, data_refs):
            continue
        # the job's cost *on the thief*: remaining iterations scaled by
        # the slab-pass multiplier under the thief's budget (the same
        # memoised model routing uses — a job that is resident on the
        # victim may stream expensively on a smaller-memory thief) plus
        # a re-init
        passes = thief.scheduler.job_passes(rec.job)
        cost = init + Scheduler._remaining_iters(rec) * passes * unit
        if thief_load + cost / max(1, thief.n_devices) > victim_load:
            continue                       # would invert the imbalance
        # export can race a concurrent admission popping the record; a
        # False return just means the victim got to it first
        if not victim.scheduler.export_job(jid, transfer_dir):
            continue
        try:
            return thief.scheduler.import_job(transfer_dir, jid,
                                              data_refs=data_refs)
        except Exception:
            victim.scheduler.reclaim_export(transfer_dir, jid,
                                            data_refs=data_refs)
            return None
    return None


def migrate_once(victim, thief, transfer_dir: str,
                 data_refs: Optional[Dict[str, Callable]] = None,
                 policy: StealPolicy = StealPolicy(),
                 units: Optional[Tuple[float, float]] = None,
                 timeout: float = 30.0) -> Optional[str]:
    """Live migration: preempt one *running* job on the ``victim`` pod at
    its step boundary (:meth:`Scheduler.park_job` — the same machinery
    :func:`drain_pod` uses to empty a pod, applied to a single job while
    everything else keeps running) and move it to the ``thief`` through
    ``transfer_dir``.  Returns the migrated job id, or None.

    This is the extreme-imbalance escape hatch: ordinary stealing only
    moves *parked* work, so a victim whose whole backlog is already
    running (long jobs, deep queues drained) can never shed load even
    when the thief sits idle.  Candidates are tried lowest priority /
    latest arrival first, mirroring the queue-tail steal discipline.

    The anti-ping-pong benefit check prices the job on the thief via
    :func:`~repro.serve.scheduler.modeled_step_passes` (remaining
    iterations x slab-pass multiplier under the *thief's* budget, plus
    the schedule-priced per-step staging time) **plus** the one-off
    migration copy itself — the job's device footprint over the
    measured bandwidth EMA (0 while no bandwidth has been observed): a
    move that would invert the imbalance, or whose copy costs more than
    it saves, is skipped.

    The victim's admission is paused for the park->export window (or the
    admission loop would immediately re-place the job it just parked);
    every other job on the victim keeps stepping throughout.  A failed
    import is reclaimed by the victim, exactly as in
    :func:`steal_once`."""
    data_refs = data_refs or {}
    vsched = victim.scheduler
    with vsched._lock:
        candidates = sorted((r.record for r in vsched.running.values()),
                            key=lambda r: (r.job.priority, -r.seq))
    if not candidates:
        return None
    default_unit, default_init = units or fleet_units((victim, thief))
    victim_load = pod_load(vsched, victim.n_devices,
                           unit=default_unit, init=default_init)
    thief_load = pod_load(thief.scheduler, thief.n_devices,
                          unit=default_unit, init=default_init)
    unit, init = effective_units(thief.scheduler, default_unit,
                                 default_init)
    bw = thief.scheduler.bandwidth_ema or vsched.bandwidth_ema
    for rec in candidates:
        jid = rec.job.job_id
        if not _stealable(rec, thief, data_refs):
            continue
        passes = thief.scheduler.job_passes(rec.job)
        cost = init + Scheduler._remaining_iters(rec) * (
            passes * unit
            + thief.scheduler.modeled_transfer_seconds(rec.job))
        move_cost = 0.0
        if bw is not None and bw > 0:
            try:
                move_cost = (vsched.job_footprint(rec.job).bytes_on_device
                             / bw)
            except Exception:
                move_cost = 0.0
        if (thief_load + (cost + move_cost) / max(1, thief.n_devices)
                > victim_load):
            continue                       # would invert the imbalance
        vsched.pause_admission()
        try:
            if not vsched.park_job(jid, timeout=timeout):
                continue   # finished (or failed) before it could park
            # park_job left the job queued; export can still race a
            # terminal transition, in which case there is nothing to move
            if not vsched.export_job(jid, transfer_dir):
                continue
            try:
                out = thief.scheduler.import_job(transfer_dir, jid,
                                                 data_refs=data_refs)
            except Exception:
                vsched.reclaim_export(transfer_dir, jid,
                                      data_refs=data_refs)
                return None
            fleet_event("migrate", job=jid, src=victim.name,
                        dst=thief.name, it=rec.iterations_done)
            return out
        finally:
            vsched.resume_admission()
    return None


def _best_survivor(rec, survivors: Sequence,
                   data_refs: Dict[str, Callable],
                   units: Tuple[float, float]):
    """Least-loaded survivor that can hold ``rec`` — load plus the job's
    modeled cost under that survivor's budget (the same slab-pass model
    routing and stealing use), all on the fleet unit scale.  None when no
    survivor can take the job."""
    default_unit, default_init = units
    best: Optional[float] = None
    chosen = None
    for s in survivors:
        if not _stealable(rec, s, data_refs):
            continue
        unit, init = effective_units(s.scheduler, default_unit,
                                     default_init)
        passes = s.scheduler.job_passes(rec.job)
        cost = init + Scheduler._remaining_iters(rec) * passes * unit
        load = pod_load(s.scheduler, s.n_devices,
                        unit=default_unit, init=default_init)
        score = load + cost / max(1, s.n_devices)
        if best is None or score < best:
            best, chosen = score, s
    return chosen


def drain_pod(pod, survivors: Sequence, transfer_dir: str,
              data_refs: Optional[Dict[str, Callable]] = None,
              timeout: float = 60.0) -> List[str]:
    """Empty one pod for retirement (the autoscaler's scale-down):

    1. **pause** the pod's admission, so jobs it parks stay parked
       instead of being re-placed on the pod about to go away;
    2. **preempt** every running job — each parks at its next step
       boundary with a step-wise checkpoint;
    3. **export** every parked job through ``transfer_dir`` (the durable
       manifest + COMMIT format) and import it on the least-loaded
       survivor that can hold it — the checkpoint travels, so each moved
       job resumes on its survivor *bit-identically* to never having
       been drained.

    The park/export loop repeats until the pod is empty, so a
    submission or steal that raced the drain is moved too.  If any job
    cannot move (a lazy-data job with no ``data_refs`` resolver, or a
    job no survivor can hold), the pod is returned to service
    (admission resumed, ``draining`` cleared) and ``RuntimeError``
    raised — it still owns every unmoved job and the caller must abort
    the scale-down.

    On success the pod is left **ready for retirement**: empty,
    ``draining`` set (fleet routing/stealing skip it) and admission
    still paused.  Pass it to ``MultiPodScheduler.remove_pod`` — or, to
    return it to service instead, clear ``draining`` and call
    ``resume_admission()``.  Returns the moved job ids."""
    data_refs = data_refs or {}
    sched = pod.scheduler
    had_draining = getattr(pod, "draining", None)
    if had_draining is not None:
        pod.draining = True       # no new work routed here from now on
    sched.pause_admission()
    moved: List[str] = []
    deadline = time.monotonic() + timeout
    try:
        while True:
            # park running (and mid-admission) work at step boundaries
            sched.drain(None, timeout=max(0.001,
                                          deadline - time.monotonic()))
            candidates = sched.steal_candidates()
            if not candidates:
                if sched.idle:
                    return moved
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"drain_pod: pod {pod.name!r} not empty after "
                        f"{timeout}s")
                continue
            units = fleet_units(list(survivors) + [pod])
            for rec in candidates:
                jid = rec.job.job_id
                target = _best_survivor(rec, survivors, data_refs, units)
                if target is None:
                    raise RuntimeError(
                        f"drain_pod: job {jid} cannot move to any "
                        f"survivor (lazy data ref without a resolver, or "
                        f"no surviving pod can hold it)")
                # export can race a terminal transition; False just means
                # there is nothing left to move for this id
                if not sched.export_job(jid, transfer_dir):
                    continue
                try:
                    target.scheduler.import_job(transfer_dir, jid,
                                                data_refs=data_refs)
                except Exception:
                    # failed hand-off: the job must never be stranded in
                    # no scheduler — the draining pod re-adopts it
                    sched.reclaim_export(transfer_dir, jid,
                                         data_refs=data_refs)
                    raise
                moved.append(jid)
    except BaseException:
        # aborted drain: the pod returns to service with whatever it holds
        sched.resume_admission()
        if had_draining is not None:
            pod.draining = False
        raise


def steal_pass(pods: Sequence, transfer_dir: str,
               data_refs: Optional[Dict[str, Callable]] = None,
               policy: StealPolicy = StealPolicy()) -> List[str]:
    """One rebalancing pass over a pod set (each pod exposing
    ``.scheduler``, ``.pool`` and ``.n_devices``): pair the most loaded
    pod with the least loaded one and move tail jobs from victim to
    thief while the modeled imbalance exceeds
    ``policy.min_imbalance_seconds``.  Jobs already moved this pass are
    never moved again.  Returns the ids of every job moved (possibly
    empty).

    The fleet units and the (victim, thief) pairing are computed
    **once** and pinned for the whole pass.  Re-ranking after every
    move would let a single steal flip the ordering — the former thief
    now tops the ranking by a hair and a job bounces straight back
    toward the pod it just left (under unit skew the bounce can even
    favor the warmer pod systematically).  Per-move load *levels*
    still update inside :func:`steal_once` (its benefit check prices
    each candidate against the live loads), so a pinned pair cannot
    overshoot; when the pinned pair has no more profitable moves the
    pass ends, and the caller's next pass re-ranks from scratch."""
    moved: List[str] = []
    if len(pods) < 2:
        return moved
    units = fleet_units(pods)
    unit, init = units
    ranked: List[Tuple[float, object]] = sorted(
        ((pod_load(p.scheduler, p.n_devices, unit=unit, init=init), p)
         for p in pods),
        key=lambda t: t[0])
    (lo, thief), (hi, victim) = ranked[0], ranked[-1]
    if victim is thief or hi - lo <= policy.min_imbalance_seconds:
        return moved
    for _ in range(policy.max_jobs_per_pass):
        jid = steal_once(victim, thief, transfer_dir,
                         data_refs=data_refs, policy=policy,
                         exclude=moved, units=units)
        if jid is None:
            break
        moved.append(jid)
    # extreme imbalance with nothing parked left to move: the victim's
    # surplus is all *running* — migrate one job live.  Gated on "no
    # parked job moved this pass" so cheap steals always win over a
    # preempt-and-copy, and on the (stricter) migrate threshold so
    # ordinary imbalance never pays a preemption
    if (not moved and policy.migrate_min_imbalance_seconds is not None
            and hi - lo > policy.migrate_min_imbalance_seconds):
        jid = migrate_once(victim, thief, transfer_dir,
                           data_refs=data_refs, policy=policy,
                           units=units)
        if jid is not None:
            moved.append(jid)
    return moved
