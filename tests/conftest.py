"""Test harness config.

8 host platform devices (NOT the dry-run's 512 -- that flag stays local to
repro.launch.dryrun): the distributed/sharding tests need a real multi-
device mesh, and 8 keeps single-device smoke tests fast.  Must be set
before the first jax import in the test process.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

# NOTE: do NOT enable JAX's persistent compilation cache here
# (JAX_COMPILATION_CACHE_DIR): on jax 0.4.x CPU, executables loaded from
# the disk cache were observed to produce slightly different numerics than
# freshly-compiled ones, breaking the exact-resume guarantee asserted by
# tests/test_fault_tolerance.py (cold cache passes, warm cache fails).

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running large-geometry cases, excluded from the tier-1 "
        "run (select with -m slow)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("-m"):
        return  # explicit marker expression wins
    skip_slow = pytest.mark.skip(reason="slow: run with -m slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture(scope="session")
def host_mesh():
    from repro.core.compat import make_mesh
    return make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="session")
def mesh82():
    from repro.core.compat import make_mesh
    return make_mesh((2, 4), ("data", "model"))
