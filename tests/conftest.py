"""Test harness config.

8 host platform devices (NOT the dry-run's 512 -- that flag stays local to
repro.launch.dryrun): the distributed/sharding tests need a real multi-
device mesh, and 8 keeps single-device smoke tests fast.  Must be set
before the first jax import in the test process.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def host_mesh():
    import jax
    from jax.sharding import AxisType
    return jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


@pytest.fixture(scope="session")
def mesh82():
    import jax
    from jax.sharding import AxisType
    return jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
