"""Crash-point injection registry for the durable serving layer.

The zero-loss guarantees (snapshot / steal / drain / restore) all reduce
to a handful of *write seams* — the functions that put job state on
disk.  Each seam is a module-level attribute precisely so a test can
replace it; :func:`kill_at` arms one seam to raise :class:`SimulatedKill`
either *before* its first invocation (the write never starts) or *after*
it (the write landed, everything downstream of it did not).  A
``kill -9`` can land at any instruction, but every observable on-disk
state it can produce is one of these seam states — the write sequences
are linear and each seam is atomic (tmp + rename) on its own.

:class:`SimulatedKill` derives from ``BaseException`` on purpose: the
serving layer's ``except Exception`` error handling must not absorb it,
exactly as a real kill signal is not absorbable.  (``export_job``'s
``except BaseException`` re-push is memory-only and irrelevant here —
the crash matrix discards the live objects and restores from disk.)

Registered seams:

``save-checkpoint``
    ``repro.serve.scheduler.save_checkpoint`` — the whole step-directory
    write (leaves + manifest + COMMIT + publish) as ``_write_job`` calls
    it.  *before* = job dir exists but no new step; *after* = step
    committed, spec not yet (re)written.
``step-commit``
    ``repro.checkpoint.sharded._write_commit`` — the COMMIT marker
    inside the still-unpublished ``.tmp`` step directory.  *before* =
    leaves + manifest on disk, no marker: the step must stay invisible.
``step-publish``
    ``repro.checkpoint.sharded._publish`` — the atomic rename of the
    committed ``.tmp`` directory to its final name.  *before* = a fully
    committed step that readers must still ignore (it is ``.tmp``).
``spec-write``
    ``repro.serve.scheduler._atomic_write_json`` — every spec.json
    write (snapshot, import persistence, stale-out rewrite).
``spec-stale``
    ``repro.serve.scheduler._set_spec_status`` — the terminal flip that
    retires a disk copy (export tombstone, transfer consumption).

Use::

    point = FaultPoint("step-commit", "before")
    with kill_at(point) as armed:
        with pytest.raises(SimulatedKill):
            sched.snapshot(snap)
    assert armed.fired
"""

from __future__ import annotations

import contextlib
import dataclasses
import importlib
from typing import Iterator, List

#: seam name -> (module holding the attribute, attribute name).  The
#: module matters: ``scheduler.py`` binds ``save_checkpoint`` into its
#: own namespace at import, so the scheduler-visible name is the one to
#: patch, while ``_write_commit`` / ``_publish`` are resolved as
#: ``sharded`` module globals at call time.
SEAMS = {
    "save-checkpoint": ("repro.serve.scheduler", "save_checkpoint"),
    "step-commit": ("repro.checkpoint.sharded", "_write_commit"),
    "step-publish": ("repro.checkpoint.sharded", "_publish"),
    "spec-write": ("repro.serve.scheduler", "_atomic_write_json"),
    "spec-stale": ("repro.serve.scheduler", "_set_spec_status"),
}

WHENS = ("before", "after")


class SimulatedKill(BaseException):
    """A crash injected at a registered fault point.

    ``BaseException`` so no ``except Exception`` recovery path in the
    code under test can swallow it — the process is "dead"."""


@dataclasses.dataclass(frozen=True)
class FaultPoint:
    """One (seam, when) crash site."""
    seam: str
    when: str          # "before" | "after" the seam's first invocation

    def __post_init__(self):
        if self.seam not in SEAMS:
            raise KeyError(f"unknown seam {self.seam!r}; registered: "
                           f"{sorted(SEAMS)}")
        if self.when not in WHENS:
            raise ValueError(f"when must be one of {WHENS}")

    @property
    def name(self) -> str:
        return f"{self.seam}:{self.when}"


def all_points() -> List[FaultPoint]:
    """Every registered crash site — the matrix axis."""
    return [FaultPoint(seam, when) for seam in SEAMS for when in WHENS]


class _Armed:
    """Handle yielded by :func:`kill_at`: records whether the point
    actually fired during the armed region (a seam an operation never
    reaches cannot kill it — the operation then completed, which is the
    crash-free row of the same matrix)."""

    def __init__(self, point: FaultPoint):
        self.point = point
        self.fired = False


@contextlib.contextmanager
def kill_at(point: FaultPoint) -> Iterator[_Armed]:
    """Arm ``point``: the seam's first invocation inside the context
    raises :class:`SimulatedKill` (before the write, or after it
    completed).  Later invocations pass through untouched — the "crash"
    happened, anything after it in the same armed region is the next
    process's life.  Always restores the original attribute."""
    module, attr = SEAMS[point.seam]
    mod = importlib.import_module(module)
    orig = getattr(mod, attr)
    armed = _Armed(point)

    def crash_site(*args, **kwargs):
        if armed.fired:
            return orig(*args, **kwargs)
        armed.fired = True
        if point.when == "before":
            raise SimulatedKill(point.name)
        result = orig(*args, **kwargs)
        raise SimulatedKill(point.name)

    setattr(mod, attr, crash_site)
    try:
        yield armed
    finally:
        setattr(mod, attr, orig)
