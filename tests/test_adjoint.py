"""Matched-adjoint property tests: <Ax, y> == <x, At y> to fp32 tolerance.

The CGLS/FISTA convergence guarantees rest on ``At`` being the *exact*
adjoint of ``A``.  The ref backend gets this from ``jax.vjp``; the pallas
backend from its transpose-shaped scatter kernel (kernels/bp_matched.py)
that replays the forward kernel's ray weights.  These tests assert the
dot-product identity for every backend x mode x dominance x shape
combination, that the pallas matched path never silently falls back to
the ref vjp, and that CGLS/FISTA converge identically on both backends.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.algorithms import cgls, fista_tv
from repro.core.backend import (clear_dispatch_cache, dispatch_cache_keys,
                                get_backend)
from repro.core.geometry import ConeGeometry, circular_angles, \
    dominant_axis_mask
from repro.core.operator import CTOperator
from repro.core.splitting import MemoryModel

# fp32 accumulation over ~1e4-1e5 products: the relative defect of the
# dot-product identity stays well under 1e-4 when the adjoint is exact
# (observed ~1e-6); a mismatched pair (e.g. the voxel-driven kernel) sits
# at 1e-2 or worse on these geometries.
REL_TOL = 1e-4

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(8)          # mixed x/y dominance
SHAPES = [(16, 16, 16), (18, 24, 24), (20, 25, 25)]


def assert_adjoint_pair(A, At, vol_shape, proj_shape, seed=0,
                        rel_tol=REL_TOL):
    """Assert <A x, y> == <x, At y> for random x, y (fp64 dot products)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(vol_shape).astype(np.float32)
    y = rng.standard_normal(proj_shape).astype(np.float32)
    ax = np.asarray(A(x), np.float64)
    aty = np.asarray(At(y), np.float64)
    lhs = float(np.vdot(ax.ravel(), y.astype(np.float64).ravel()))
    rhs = float(np.vdot(x.astype(np.float64).ravel(), aty.ravel()))
    scale = max(abs(lhs), abs(rhs), 1e-30)
    rel = abs(lhs - rhs) / scale
    assert rel < rel_tol, (f"<Ax,y>={lhs:.8g} vs <x,At y>={rhs:.8g} "
                           f"(rel {rel:.3g} >= {rel_tol:g})")
    return rel


def _tiny_memory(geo, n_angles):
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    return MemoryModel(
        device_bytes=(nz * ny * nx * 4) // 3 + 12 * n_angles * nv * nu,
        usable_fraction=1.0)


def _op(geo, angles, mode, backend, mesh=None):
    kw = dict(mode=mode, bp_weight="matched", backend=backend)
    if mode == "stream":
        kw["memory"] = _tiny_memory(geo, len(angles))
    if mode == "dist":
        kw["mesh"] = mesh
    return CTOperator(geo, angles, **kw)


# --------------------------------------------------------------------------
# the identity, swept over backends x shapes x modes x dominance
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("shape", SHAPES)
def test_adjoint_plain(backend, shape):
    geo = GEO.with_voxels(shape)
    op = _op(geo, ANGLES, "plain", backend)
    assert_adjoint_pair(op.A, lambda p: op.At(p, weight="matched"),
                        shape, (len(ANGLES),) + geo.n_detector)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("dominance", ["x", "y"])
def test_adjoint_single_dominance(backend, dominance):
    """All-x and all-y angle subsets: the y-dominant pallas path runs the
    rotation trick, whose adjoint is the inverse rotation."""
    mask = dominant_axis_mask(ANGLES)
    idx = np.nonzero(mask if dominance == "x" else ~mask)[0]
    sub = ANGLES[idx]
    op = _op(GEO, sub, "plain", backend)
    assert_adjoint_pair(op.A, lambda p: op.At(p, weight="matched"),
                        GEO.n_voxel, (len(sub),) + GEO.n_detector)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("shape", [(16, 16, 16), (18, 24, 24)])
def test_adjoint_stream(backend, shape):
    geo = GEO.with_voxels(shape)
    op = _op(geo, ANGLES, "stream", backend)
    assert op.plan.streams, "budget should force slab splitting"
    assert_adjoint_pair(op.A, lambda p: op.At(p, weight="matched"),
                        shape, (len(ANGLES),) + geo.n_detector)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_adjoint_dist(host_mesh, backend):
    op = _op(GEO, ANGLES, "dist", backend, mesh=host_mesh)
    with host_mesh:
        assert_adjoint_pair(op.A, lambda p: op.At(p, weight="matched"),
                            GEO.n_voxel, (len(ANGLES),) + GEO.n_detector)


def test_adjoint_dist_pallas_odd_angles(host_mesh):
    """Angle count not divisible by the data axis: the padded projections
    must not break the identity (padding rows are zeroed in At)."""
    angles = circular_angles(10)     # 10 % 4 != 0
    op = _op(GEO, angles, "dist", "pallas", mesh=host_mesh)
    with host_mesh:
        assert_adjoint_pair(op.A, lambda p: op.At(p, weight="matched"),
                            GEO.n_voxel, (len(angles),) + GEO.n_detector)


# --------------------------------------------------------------------------
# no silent ref fallback: pallas matched must build zero ref-vjp operators
# --------------------------------------------------------------------------

def test_pallas_matched_builds_no_ref_operators():
    """ISSUE 10 acceptance: ``backend="pallas", weighting="matched"``
    runs Pallas end-to-end — the dispatch table must contain no ref
    entries after exercising A and matched At in plain mode."""
    clear_dispatch_cache()
    op = _op(GEO, ANGLES, "plain", "pallas")
    x = np.ones(GEO.n_voxel, np.float32)
    y = np.ones((len(ANGLES),) + GEO.n_detector, np.float32)
    op.A(x)
    op.At(y, weight="matched")
    keys = dispatch_cache_keys()
    assert keys, "dispatch table unexpectedly empty"
    ref_keys = [k for k in keys if k and k[0] == "ref"]
    assert not ref_keys, f"pallas matched path fell back to ref: {ref_keys}"
    # and the matched entries are the native pallas ones
    kinds = {k[1] for k in keys if k and k[0] == "pallas"}
    assert "at_matched_mixed" in kinds or "bp_matched" in kinds


def test_pallas_matched_stream_builds_no_ref_operators():
    clear_dispatch_cache()
    op = _op(GEO, ANGLES, "stream", "pallas")
    y = np.ones((len(ANGLES),) + GEO.n_detector, np.float32)
    op.At(y, weight="matched")
    ref_keys = [k for k in dispatch_cache_keys() if k and k[0] == "ref"]
    assert not ref_keys, f"streamed pallas matched fell back: {ref_keys}"
    kinds = {k[1] for k in dispatch_cache_keys() if k and k[0] == "pallas"}
    assert "bp_matched" in kinds


def test_matched_pallas_is_custom_vjp_of_forward():
    """grad through the pallas forward must route through the matched
    kernel (custom_vjp), and equal the matched At of the residual."""
    mask = dominant_axis_mask(ANGLES)
    sub = ANGLES[np.nonzero(mask)[0]]
    bk = get_backend("pallas")
    fp = bk.fp(GEO, xdom=True)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(GEO.n_voxel), jnp.float32)
    r = jnp.asarray(rng.standard_normal((len(sub),) + GEO.n_detector),
                    jnp.float32)
    a = jnp.asarray(sub)

    def loss(v):
        return jnp.vdot(fp(v, a, 0), r)

    g = jax.grad(loss)(x)
    want = bk.bp_matched(GEO, planes=GEO.n_voxel[0], xdom=True)(r, a, 0)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# convergence parity: CGLS / FISTA identical trajectories on both backends
# --------------------------------------------------------------------------

def _phantom_projections(geo, angles):
    from repro.core import phantoms
    return phantoms.sphere_projection_analytic(geo, angles)


@pytest.mark.parametrize("alg,n_iter", [(cgls, 6), (fista_tv, 4)])
def test_convergence_parity_pallas_vs_ref(alg, n_iter):
    """Same algorithm, same data: the pallas matched pair must converge
    like the ref vjp pair (CGLS is exquisitely sensitive to adjoint
    mismatch — a broken adjoint diverges within a few iterations)."""
    proj = _phantom_projections(GEO, ANGLES)
    r = np.asarray(alg(proj, GEO, ANGLES, n_iter=n_iter,
                       op=CTOperator(GEO, ANGLES, backend="ref")))
    p = np.asarray(alg(proj, GEO, ANGLES, n_iter=n_iter,
                       op=CTOperator(GEO, ANGLES, backend="pallas")))
    np.testing.assert_allclose(p, r, rtol=2e-3, atol=2e-3)
    # both actually reconstruct: residual well below the data norm
    op = CTOperator(GEO, ANGLES, backend="pallas")
    res = float(np.linalg.norm(np.asarray(op.A(p)) - np.asarray(proj)))
    assert res < 0.5 * float(np.linalg.norm(np.asarray(proj)))
