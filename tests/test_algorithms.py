"""Reconstruction algorithms against analytic phantoms (paper SS3)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import phantoms
from repro.core.algorithms import (asd_pocs, cgls, fdk, fista_tv, ossart,
                                   sart, sirt)
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.operator import CTOperator

GEO = ConeGeometry.nice(32)
ANGLES = circular_angles(64)
VOL = phantoms.sphere(GEO)
PROJ = phantoms.sphere_projection_analytic(GEO, ANGLES)


def _rel(rec):
    return float(np.linalg.norm(np.asarray(rec) - VOL) / np.linalg.norm(VOL))


def test_fdk():
    assert _rel(fdk(jnp.asarray(PROJ), GEO, ANGLES)) < 0.25


def test_cgls_converges():
    errs, xs = [], []
    def cb(it, x, r):
        errs.append(r)
        xs.append(x)
    cgls(PROJ, GEO, ANGLES, n_iter=8, callback=cb)
    assert errs[-1] < errs[0] * 0.5               # residual halves
    assert _rel(xs[-1]) < 0.25                    # one run, both claims


def test_ossart():
    assert _rel(ossart(PROJ, GEO, ANGLES, n_iter=4, subset_size=16)) < 0.25


def test_sirt():
    assert _rel(sirt(PROJ, GEO, ANGLES, n_iter=8)) < 0.35


def test_fista_tv_smoke():
    """Cheap default-run check: fixed L (skips the power iteration), two
    iterations, loose quality bar; full quality runs under -m slow."""
    # L ~= 1.05 * ||A||^2 for this geometry (hard-coded from the power
    # iteration the slow variant still exercises)
    assert _rel(fista_tv(PROJ, GEO, ANGLES, n_iter=2, tv_iters=3,
                         L=118200.0)) < 0.6


@pytest.mark.slow
def test_fista_tv():
    assert _rel(fista_tv(PROJ, GEO, ANGLES, n_iter=4, tv_iters=5)) < 0.4


def test_asd_pocs():
    assert _rel(asd_pocs(PROJ, GEO, ANGLES, n_iter=3, subset_size=16,
                         tv_iters=5)) < 0.3


@pytest.mark.slow
def test_cgls_streaming_backend_matches_plain():
    """The same algorithm on the out-of-core backend (paper's modularity).
    (slow: tier-1 covers the streaming path via
    test_system.test_recon_driver_streaming_out_of_core)"""
    from repro.core.splitting import MemoryModel
    op_stream = CTOperator(GEO, ANGLES, mode="stream",
                           memory=MemoryModel(device_bytes=120 * 1024,
                                              usable_fraction=1.0))
    rec_s = ossart(PROJ, GEO, ANGLES, n_iter=2, subset_size=16,
                   op=op_stream, bp_weight="fdk")
    rec_p = ossart(PROJ, GEO, ANGLES, n_iter=2, subset_size=16,
                   bp_weight="fdk")
    np.testing.assert_allclose(np.asarray(rec_s), np.asarray(rec_p),
                               rtol=2e-3, atol=2e-3)


def test_ossart_distributed_backend(host_mesh):
    op_d = CTOperator(GEO, ANGLES, mode="dist", mesh=host_mesh)
    with host_mesh:
        rec_d = ossart(PROJ, GEO, ANGLES, n_iter=2, subset_size=16, op=op_d)
    rec_p = ossart(PROJ, GEO, ANGLES, n_iter=2, subset_size=16)
    np.testing.assert_allclose(np.asarray(rec_d), np.asarray(rec_p),
                               rtol=2e-3, atol=2e-3)


def test_power_iteration_norm():
    op = CTOperator(GEO, ANGLES, mode="plain", bp_weight="matched")
    lam = op.norm_squared_est(n_iter=2)
    assert lam > 0
