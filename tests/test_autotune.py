"""Measured block-size autotuner: heuristic fallback, memoization, JSON
cache round-trip, the heuristic floor guarantee, and the dispatch/backend
integration (tuned blocks land in kernel_config and dispatch keys).

Measurement itself is monkeypatched to a deterministic cost model in most
tests (tune() would otherwise compile kernels per candidate); one smoke
test runs the real path on a tiny geometry.
"""

import json
import os

import numpy as np
import pytest

from repro.core.backend import get_backend
from repro.core.geometry import ConeGeometry
from repro.kernels import autotune

GEO = ConeGeometry.nice(16)
GEO_ODD = ConeGeometry.nice(16).with_voxels((20, 25, 25))


@pytest.fixture(autouse=True)
def _reset_autotune(monkeypatch):
    """Isolate every test from env state and the process memo table."""
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE", raising=False)
    autotune.enable(None)
    autotune.clear()
    yield
    autotune.enable(None)
    autotune.clear()


@pytest.fixture
def fake_measure(monkeypatch):
    """Deterministic cost model: bigger slab/z blocks are 'faster', so the
    tuner must pick the largest candidate; records every call."""
    calls = []

    def _fake(kind, geo, planes, cfg, interpret, repeats):
        calls.append((kind, dict(cfg)))
        return 1.0 / sum(cfg.values())

    monkeypatch.setattr(autotune, "_measure", _fake)
    return calls


# --------------------------------------------------------------------------
# heuristic (pad-to-divisor escape hatch)
# --------------------------------------------------------------------------

def test_pick_block_divisor_and_pad_fallback():
    assert autotune.pick_block(32, 16) == 16     # exact divisor
    assert autotune.pick_block(18, 16) == 9      # divisor >= preferred/2
    assert autotune.pick_block(17, 16) == 16     # prime: pad, not block=1
    assert autotune.pick_block(25, 16) == 16     # 5 < 8: pad beats tiny
    assert autotune.pick_block(4, 16) == 4       # axis smaller than block


def test_heuristic_blocks_per_kind():
    assert autotune.heuristic_blocks("fp", GEO) == {"slab_planes": 16}
    assert autotune.heuristic_blocks("bp_matched", GEO) == \
        {"slab_planes": 16}
    assert autotune.heuristic_blocks("bp", GEO, planes=8) == \
        {"z_block": 8, "angle_chunk": 8}
    # prime x axis: the escape hatch keeps the preferred slab width
    assert autotune.heuristic_blocks("fp", GEO.with_voxels((16, 16, 17))) \
        == {"slab_planes": 16}
    with pytest.raises(ValueError, match="unknown autotune kind"):
        autotune.heuristic_blocks("conv", GEO)


def test_disabled_returns_heuristic_and_never_measures(fake_measure):
    assert not autotune.enabled()
    got = autotune.get_blocks("fp", GEO)
    assert got == autotune.heuristic_blocks("fp", GEO)
    assert fake_measure == []          # no measurement when disabled
    assert autotune.table() == {}


def test_env_var_enables():
    os.environ["REPRO_AUTOTUNE"] = "1"
    assert autotune.enabled()
    os.environ["REPRO_AUTOTUNE"] = "0"
    assert not autotune.enabled()
    autotune.enable(True)              # explicit override beats env
    assert autotune.enabled()


# --------------------------------------------------------------------------
# tuning: memoization, floor guarantee, fingerprint
# --------------------------------------------------------------------------

def test_tune_memoizes_per_shape_class(fake_measure):
    autotune.enable(True)
    first = autotune.get_blocks("fp", GEO)
    n_measured = len(fake_measure)
    assert n_measured >= 1
    again = autotune.get_blocks("fp", GEO)
    assert again == first
    assert len(fake_measure) == n_measured, "cache hit re-measured"
    # same *shape*, different physical scale -> same memo entry
    import dataclasses
    geo2 = dataclasses.replace(GEO, DSO=900.0)
    assert autotune.get_blocks("fp", geo2) == first
    assert len(fake_measure) == n_measured


def test_tuned_blocks_never_below_heuristic(fake_measure):
    """Candidates are floored at the heuristic, so the winner is >= it
    even when the fake cost model is inverted to prefer small blocks."""
    autotune.enable(True)

    def prefer_small(kind, geo, planes, cfg, interpret, repeats):
        return float(sum(cfg.values()))          # smaller == faster

    import unittest.mock as mock
    with mock.patch.object(autotune, "_measure", prefer_small):
        got = autotune.get_blocks("bp", GEO_ODD, planes=20)
    heur = autotune.heuristic_blocks("bp", GEO_ODD, planes=20)
    for k, v in heur.items():
        assert got[k] >= v, f"{k}: tuned {got[k]} < heuristic {v}"


def test_stale_cache_entry_clamped_to_heuristic(tmp_path, fake_measure):
    """A foreign/stale persisted table with a too-small block must be
    clamped up to the heuristic, never trusted below it."""
    key = autotune.shape_class("fp", GEO, None)
    path = tmp_path / "blocks.json"
    path.write_text(json.dumps({
        "version": 1,
        "entries": {autotune._key_str(key): {"slab_planes": 1}},
    }))
    os.environ["REPRO_AUTOTUNE_CACHE"] = str(path)
    autotune.enable(True)
    got = autotune.get_blocks("fp", GEO)
    assert got["slab_planes"] == 16            # clamped, not 1
    assert fake_measure == []                  # hit: no re-measure


def test_fingerprint_bumps_on_mutations(fake_measure):
    fp0 = autotune.fingerprint()
    autotune.enable(True)
    assert autotune.fingerprint() > fp0        # enable() bumps
    fp1 = autotune.fingerprint()
    autotune.get_blocks("fp", GEO)             # first tune bumps
    assert autotune.fingerprint() > fp1
    fp2 = autotune.fingerprint()
    autotune.get_blocks("fp", GEO)             # memo hit: no bump
    assert autotune.fingerprint() == fp2
    autotune.clear()
    assert autotune.fingerprint() > fp2


def test_cache_roundtrip(tmp_path, fake_measure):
    autotune.enable(True)
    os.environ["REPRO_AUTOTUNE_CACHE"] = str(tmp_path / "blocks.json")
    tuned = autotune.warm(GEO, planes=16)
    assert set(tuned) == {"fp", "bp", "bp_matched"}
    n_measured = len(fake_measure)
    before = autotune.table()
    assert os.path.exists(os.environ["REPRO_AUTOTUNE_CACHE"])

    # a 'new process': empty table, same cache path -> loads, no measuring
    autotune.clear()
    got = autotune.get_blocks("fp", GEO, planes=16)
    assert got == tuned["fp"]
    assert len(fake_measure) == n_measured, "persisted hit re-measured"
    assert autotune.table() == before


def test_load_rejects_garbage(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("not json {")
    assert autotune.load(str(p)) == 0
    p.write_text(json.dumps({"version": 99, "entries": {}}))
    assert autotune.load(str(p)) == 0
    p.write_text(json.dumps({"version": 1,
                             "entries": {"mangled-key": {"z_block": 4},
                                         "fp|cpu|16,16,16|16,16|None":
                                             {"slab_planes": 32}}}))
    assert autotune.load(str(p)) == 1          # good row taken, bad skipped


# --------------------------------------------------------------------------
# backend integration
# --------------------------------------------------------------------------

def test_backend_kernel_config_reports_blocks():
    bk = get_backend("pallas")
    cfg = bk.kernel_config(GEO, planes=16)
    assert cfg["fp.slab_planes"] == 16
    assert cfg["bp_matched.slab_planes"] == 16
    assert cfg["bp.z_block"] == 16
    assert cfg["bp.angle_chunk"] >= 1
    assert cfg["autotuned"] is False
    assert get_backend("ref").kernel_config(GEO) == {}


def test_backend_uses_tuned_blocks_and_distinct_dispatch_keys(fake_measure):
    """Tuned blocks flow into the dispatch key: the same geometry tuned
    to a different slab width must compile a distinct entry."""
    from repro.core.backend import clear_dispatch_cache, dispatch_cache_keys
    clear_dispatch_cache()
    bk = get_backend("pallas")
    bk.fp(GEO, xdom=True)
    keys_heur = [k for k in dispatch_cache_keys()
                 if k[:2] == ("pallas", "fp")]
    assert len(keys_heur) == 1

    autotune.enable(True)              # fake model picks slab_planes=16->16
    cfg = bk.kernel_config(GEO, planes=16)
    assert cfg["autotuned"] is True
    # force a bigger tuned block via a loaded table
    key = autotune.shape_class("fp", GEO, None)
    with autotune._LOCK:
        autotune._TABLE[key] = {"slab_planes": 32}
    bk.fp(GEO, xdom=True)
    keys_now = [k for k in dispatch_cache_keys()
                if k[:2] == ("pallas", "fp")]
    assert len(keys_now) == 2, "tuned config reused the heuristic entry"


def test_real_tune_smoke():
    """End-to-end measured tuning on a tiny geometry (no monkeypatch):
    winner respects the floor and parity versus the heuristic holds."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.fp_ray import fp_ray_pallas
    geo = ConeGeometry.nice(16)
    autotune.enable(True)
    got = autotune.tune("fp", geo, repeats=1)
    assert got["slab_planes"] >= 16
    # tuned config computes the same forward projection
    ang = jnp.asarray(np.linspace(-0.3, 0.3, 4), jnp.float32)
    vol = jax.random.normal(jax.random.PRNGKey(0), geo.n_voxel, jnp.float32)
    a = fp_ray_pallas(vol, geo, ang, slab_planes=16, interpret=True)
    b = fp_ray_pallas(vol, geo, ang, slab_planes=got["slab_planes"],
                      interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# pad-to-divisor escape hatches (prime / awkward axes)
# --------------------------------------------------------------------------

def _xdom_angles(n):
    from repro.core.geometry import circular_angles, dominant_axis_mask
    a = circular_angles(n)
    return a[np.nonzero(dominant_axis_mask(a))[0]]


def test_fp_ray_prime_x_axis_pads():
    """nx=17 (prime) with slab_planes=16: the wrapper pads the marching
    axis with zero planes instead of rejecting non-divisible blocks."""
    import jax
    from repro.kernels import ref
    from repro.kernels.fp_ray import fp_ray_pallas
    geo = ConeGeometry.nice(16).with_voxels((16, 16, 17))
    ax = _xdom_angles(6)
    vol = jax.random.normal(jax.random.PRNGKey(7), geo.n_voxel, jnp.float32)
    got = fp_ray_pallas(vol, geo, ax, slab_planes=16, interpret=True)
    want = ref.fp_ray_ref(vol, geo, ax)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=5e-3)


def test_fp_ray_pad_matches_divisor_blocks():
    """Padding must be numerically invisible up to fp32 re-association:
    the padded x planes are zero and contribute zero, so a dividing
    block and a padding block agree to accumulation-order tolerance."""
    import jax
    from repro.kernels.fp_ray import fp_ray_pallas
    geo = ConeGeometry.nice(32)
    ax = _xdom_angles(4)
    vol = jax.random.normal(jax.random.PRNGKey(8), geo.n_voxel, jnp.float32)
    a = fp_ray_pallas(vol, geo, ax, slab_planes=8, interpret=True)   # 32%8==0
    b = fp_ray_pallas(vol, geo, ax, slab_planes=12, interpret=True)  # pads
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("planes,zb", [(13, 8), (7, 16)])
def test_bp_voxel_prime_z_planes_pads(planes, zb):
    """Prime slab heights used to force z_block=1 (or a ValueError);
    the kernel now pads the z grid and drops the tail planes."""
    import jax
    from repro.core.geometry import circular_angles
    from repro.kernels import ref
    from repro.kernels.bp_voxel import bp_voxel_pallas
    geo = ConeGeometry.nice(16).with_voxels((planes, 16, 16))
    angles = circular_angles(8)
    proj = jax.random.normal(jax.random.PRNGKey(planes),
                             (8,) + geo.n_detector, jnp.float32)
    got = bp_voxel_pallas(proj, geo, angles, z_block=zb, angle_chunk=4,
                          weight="fdk", interpret=True)
    want = ref.bp_voxel_ref(proj, geo, angles, weight="fdk")
    assert got.shape == (planes, 16, 16)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_bp_voxel_prime_angle_count_pads():
    """7 angles with angle_chunk=4: the padded angle rows carry zeroed
    projections, so they add nothing to the backprojection sums."""
    import jax
    from repro.core.geometry import circular_angles
    from repro.kernels import ref
    from repro.kernels.bp_voxel import bp_voxel_pallas
    geo = ConeGeometry.nice(16)
    angles = circular_angles(7)
    proj = jax.random.normal(jax.random.PRNGKey(11),
                             (7,) + geo.n_detector, jnp.float32)
    got = bp_voxel_pallas(proj, geo, angles, z_block=8, angle_chunk=4,
                          weight="fdk", interpret=True)
    want = ref.bp_voxel_ref(proj, geo, angles, weight="fdk")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)
