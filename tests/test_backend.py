"""Kernel-backend registry: ref/pallas parity across modes, shapes and
dominances, plus the cached-jit dispatch regressions (no per-call
retracing anywhere in the kernel path).

Pallas runs in interpret mode on the CPU test rig; tolerances follow
tests/test_kernels.py.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import backend as backend_mod
from repro.core.backend import (available_backends, dispatch_cache_info,
                                get_backend, resolve)
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.operator import CTOperator
from repro.core.plan import plan, plan_cache_info
from repro.core.splitting import MemoryModel
from repro.kernels import ops

RTOL, ATOL = 2e-4, 5e-3

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(8)          # mixed x/y dominance
VOL = np.asarray(jax.random.normal(jax.random.PRNGKey(0), GEO.n_voxel),
                 np.float32)
PROJ = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                    (len(ANGLES),) + GEO.n_detector),
                  np.float32)


def _tiny_memory(geo, n_angles):
    """Budget forcing the plan to split the volume (several slabs): about
    a third of the volume plus room for the projection buffers."""
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    return MemoryModel(
        device_bytes=(nz * ny * nx * 4) // 3 + 12 * n_angles * nv * nu,
        usable_fraction=1.0)


# --------------------------------------------------------------------------
# registry basics
# --------------------------------------------------------------------------

def test_registry_resolve():
    assert set(available_backends()) >= {"ref", "pallas", "auto"}
    # auto picks per JAX backend: ref everywhere but TPU hosts
    expect = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert resolve(None) == expect
    assert resolve("auto") == expect
    assert resolve("ref") == "ref"
    with pytest.raises(ValueError, match="unknown kernel backend"):
        resolve("cuda")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        CTOperator(GEO, ANGLES, backend="nope")


def test_operator_records_backend_and_plan():
    op = CTOperator(GEO, ANGLES, backend="pallas")
    assert op.backend_name == "pallas"
    assert op.plan.n_angles == len(ANGLES)
    assert not op.plan.streams
    # default mode resolves and still runs
    auto = CTOperator(GEO, ANGLES)
    assert auto.backend_name in ("ref", "pallas")


# --------------------------------------------------------------------------
# parity: plain mode (mixed dominance, odd/uneven shapes)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (16, 16, 16),        # even cube
    (18, 24, 24),        # uneven z vs square xy
    (20, 25, 25),        # odd xy extent (block sizes fall back to divisors)
])
def test_plain_parity_shapes(shape):
    geo = GEO.with_voxels(shape)
    vol = np.asarray(jax.random.normal(jax.random.PRNGKey(2), shape),
                     np.float32)
    r = CTOperator(geo, ANGLES, backend="ref")
    p = CTOperator(geo, ANGLES, backend="pallas")
    np.testing.assert_allclose(p.A(vol), r.A(vol), rtol=RTOL, atol=ATOL)
    for w in ("fdk", "pmatched", "none", "matched"):
        np.testing.assert_allclose(p.At(PROJ, weight=w),
                                   r.At(PROJ, weight=w),
                                   rtol=RTOL, atol=ATOL)


def test_plain_parity_single_dominance_subsets():
    """All-x and all-y dominant angle subsets exercise both kernel paths
    (the y-dominant one runs through the rotation trick)."""
    from repro.core.geometry import dominant_axis_mask
    mask = dominant_axis_mask(ANGLES)
    for idx in (np.nonzero(mask)[0], np.nonzero(~mask)[0]):
        sub = ANGLES[idx]
        r = CTOperator(GEO, sub, backend="ref")
        p = CTOperator(GEO, sub, backend="pallas")
        np.testing.assert_allclose(p.A(VOL), r.A(VOL), rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------------------
# parity: stream mode (pallas inside the out-of-core path)
# --------------------------------------------------------------------------

def test_stream_parity():
    mem = _tiny_memory(GEO, len(ANGLES))
    r = CTOperator(GEO, ANGLES, mode="stream", memory=mem, backend="ref")
    p = CTOperator(GEO, ANGLES, mode="stream", memory=mem, backend="pallas")
    assert r.plan.streams, "budget should force slab splitting"
    assert r.plan is p.plan, "memoized plan must be shared across backends"
    np.testing.assert_allclose(p.A(VOL), r.A(VOL), rtol=RTOL, atol=ATOL)
    for w in ("fdk", "matched"):
        np.testing.assert_allclose(p.At(PROJ, weight=w),
                                   r.At(PROJ, weight=w),
                                   rtol=RTOL, atol=ATOL)
    # and the streamed pallas result matches the monolithic plain ref
    plain = CTOperator(GEO, ANGLES, backend="ref")
    np.testing.assert_allclose(p.A(VOL), plain.A(VOL), rtol=RTOL, atol=ATOL)


def test_stream_parity_odd_shape():
    shape = (18, 24, 24)
    geo = GEO.with_voxels(shape)
    vol = np.asarray(jax.random.normal(jax.random.PRNGKey(3), shape),
                     np.float32)
    mem = _tiny_memory(geo, len(ANGLES))
    r = CTOperator(geo, ANGLES, mode="stream", memory=mem, backend="ref")
    p = CTOperator(geo, ANGLES, mode="stream", memory=mem, backend="pallas")
    assert r.plan.streams
    np.testing.assert_allclose(p.A(vol), r.A(vol), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(p.At(PROJ, weight="fdk"),
                               r.At(PROJ, weight="fdk"),
                               rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------------------
# parity: dist mode (pallas inside shard_map)
# --------------------------------------------------------------------------

def test_dist_parity(host_mesh):
    r = CTOperator(GEO, ANGLES, mode="dist", mesh=host_mesh, backend="ref")
    p = CTOperator(GEO, ANGLES, mode="dist", mesh=host_mesh,
                   backend="pallas")
    plain = CTOperator(GEO, ANGLES, backend="ref")
    with host_mesh:
        np.testing.assert_allclose(p.A(VOL), r.A(VOL), rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(p.A(VOL), plain.A(VOL),
                                   rtol=RTOL, atol=ATOL)
        for w in ("fdk", "pmatched", "none", "matched"):
            np.testing.assert_allclose(p.At(PROJ, weight=w),
                                       r.At(PROJ, weight=w),
                                       rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------------------
# hypothesis sweep: random angle sets and uneven shapes, all modes
# --------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:                      # pragma: no cover - CI installs it
    _HYP = False


if _HYP:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 1000), st.sampled_from([16, 18, 20]),
           st.integers(4, 8))
    def test_backend_parity_property(seed, nz, n_angles):
        """Pallas == ref within tolerance for random rotations/shapes in
        plain and (slab-forced) stream modes."""
        rng = np.random.default_rng(seed)
        geo = GEO.with_voxels((nz, 16, 16))
        angles = rng.uniform(0, 2 * np.pi, n_angles).astype(np.float32)
        vol = rng.standard_normal(geo.n_voxel).astype(np.float32)
        proj = rng.standard_normal((n_angles,) + geo.n_detector) \
            .astype(np.float32)
        r = CTOperator(geo, angles, backend="ref")
        p = CTOperator(geo, angles, backend="pallas")
        np.testing.assert_allclose(p.A(vol), r.A(vol), rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(p.At(proj, weight="fdk"),
                                   r.At(proj, weight="fdk"),
                                   rtol=RTOL, atol=ATOL)
        mem = _tiny_memory(geo, n_angles)
        rs = CTOperator(geo, angles, mode="stream", memory=mem,
                        backend="ref")
        ps = CTOperator(geo, angles, mode="stream", memory=mem,
                        backend="pallas")
        np.testing.assert_allclose(ps.A(vol), rs.A(vol),
                                   rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(ps.At(proj, weight="fdk"),
                                   rs.At(proj, weight="fdk"),
                                   rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------------------
# cached-jit dispatch: no per-call rebuild / retrace
# --------------------------------------------------------------------------

def test_ops_wrappers_cache_compiled_fns():
    """Regression for the per-call ``jax.jit(partial(...))`` bug: the
    public kernel wrappers must reuse one compiled callable per static
    key — the second call hits the cache and jax's jit cache stays at one
    entry even when the angle *values* change."""
    from repro.core.geometry import dominant_axis_mask
    ops.clear_cache()
    ax = ANGLES[np.nonzero(dominant_axis_mask(ANGLES))[0]]
    ops.fp_ray_project(jnp.asarray(VOL), GEO, ax, slab_planes=4)
    before = ops.cache_info()["fp"]
    assert before.misses == 1
    # same static key, different angle values: cache hit, no retrace
    ops.fp_ray_project(jnp.asarray(VOL), GEO, ax + 0.01, slab_planes=4)
    after = ops.cache_info()["fp"]
    assert after.hits == before.hits + 1
    assert after.misses == before.misses
    compiled = ops._fp_compiled(GEO, 4, True)
    assert compiled._cache_size() == 1

    ops.bp_voxel_backproject(jnp.asarray(PROJ), GEO, ANGLES, z_block=4,
                             angle_chunk=4)
    ops.bp_voxel_backproject(jnp.asarray(PROJ), GEO, ANGLES + 0.01,
                             z_block=4, angle_chunk=4)
    bp = ops.cache_info()["bp"]
    assert bp.misses == 1 and bp.hits >= 1
    assert ops._bp_compiled(GEO, 4, 4, "fdk", True)._cache_size() == 1


def test_backend_dispatch_table_caches():
    """Two operators over the same geometry share one compiled callable
    per (backend, kind, static args) key."""
    backend_mod.clear_dispatch_cache()
    bk = get_backend("ref")
    f1 = bk.fp(GEO, xdom=True)
    f2 = bk.fp(GEO, xdom=True)
    assert f1 is f2
    info = dispatch_cache_info()
    assert info["hits"] >= 1 and info["misses"] >= 1
    # distinct static args get distinct entries
    assert bk.fp(GEO, xdom=False) is not f1
    # two CTOperator instances share the table
    a = CTOperator(GEO, ANGLES, backend="ref")
    b = CTOperator(GEO, ANGLES, backend="ref")
    assert a._plain_fp(ANGLES) is b._plain_fp(ANGLES)


def test_plan_is_memoized_and_shared():
    mem = MemoryModel(device_bytes=1 << 26, usable_fraction=1.0)
    p1 = plan(GEO, 8, 1, mem)
    before = plan_cache_info().hits
    p2 = plan(GEO, 8, 1, mem)
    assert p1 is p2
    assert plan_cache_info().hits == before + 1
    # the serving cost model goes through the same memo
    from repro.serve.scheduler import estimate_job_footprint
    from repro.serve.job import ReconJob
    job = ReconJob("cgls", GEO, ANGLES, PROJ, n_iter=1)
    estimate_job_footprint(job, mem)
    hits = plan_cache_info().hits
    estimate_job_footprint(job, mem)
    assert plan_cache_info().hits > hits


def test_plan_structure():
    mem = _tiny_memory(GEO, len(ANGLES))
    p = plan(GEO, len(ANGLES), 1, mem)
    assert p.streams and p.step_passes > 1.0
    assert p.slab_ranges[0][0] == 0
    assert p.slab_ranges[-1][1] == GEO.n_voxel[0]
    assert p.stream_bytes_on_device <= mem.usable
    assert p.transfer_bytes == (p.transfer_bytes_forward
                                + p.transfer_bytes_backward)
    assert p.transfer_bytes_forward >= p.vol_bytes + p.proj_bytes
    assert "streams=True" in p.describe()
    big = plan(GEO, len(ANGLES), 1, MemoryModel())
    assert not big.streams and big.step_passes == 1.0
