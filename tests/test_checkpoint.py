"""Checkpoint substrate: roundtrip, atomic commit, elastic resharding,
async manager."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (16, 8), jnp.float32),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "opt": {"m": jnp.ones((16, 8)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 3, tree)
    assert latest_step(str(tmp_path)) == 3
    got = restore_checkpoint(str(tmp_path), 3, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_uncommitted_checkpoint_ignored(tmp_path):
    tree = _tree()
    out = save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    os.remove(os.path.join(str(tmp_path), "step_00000002", "COMMIT"))
    assert latest_step(str(tmp_path)) == 1


def test_gc_keeps_latest(tmp_path):
    tree = _tree()
    for s in range(5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = sorted(d for d in os.listdir(str(tmp_path))
                   if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"


def test_elastic_resharding(tmp_path, host_mesh, mesh82):
    """Save under one mesh sharding, restore under a different one."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    sh1 = NamedSharding(host_mesh, P("data", None))
    x1 = jax.device_put(x, sh1)
    save_checkpoint(str(tmp_path), 0, {"x": x1})
    sh2 = NamedSharding(mesh82, P(None, "model"))
    got = restore_checkpoint(
        str(tmp_path), 0, {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
        shardings={"x": sh2})
    assert got["x"].sharding == sh2
    np.testing.assert_array_equal(np.asarray(got["x"]), np.asarray(x))


def test_manager_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    mgr.save(0, tree)
    mgr.save(1, tree)          # joins previous write first
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1
    step, got = mgr.restore_latest(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    assert step == 1
    np.testing.assert_array_equal(
        np.asarray(got["params"]["w"]), np.asarray(tree["params"]["w"]))


def test_restore_missing_leaf_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        restore_checkpoint(str(tmp_path), 0,
                           {"a": jax.ShapeDtypeStruct((2,), jnp.float32),
                            "b": jax.ShapeDtypeStruct((2,), jnp.float32)})


def test_restore_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 0,
                           {"a": jax.ShapeDtypeStruct((3,), jnp.float32)})
