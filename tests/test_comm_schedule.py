"""CommSchedule IR: the explicit staging/compute/reduce schedule.

* the overlap executors (prefetch depth >= 1) are bit-identical to the
  serial no-prefetch reference across backends, odd shapes, multiple
  devices and matched weighting (the schedule changes *when* bytes move,
  never the accumulation order);
* ``plan()`` memoization round-trips the schedule fields (distinct cache
  entries per prefetch depth, same-args identity);
* the dominance-split dist FP matches the both-variants baseline exactly
  and never materialises the unused kernel variant (dispatch-key
  counters);
* reduction-tree selection and the schedule-derived transfer cost model.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import backend as bk
from repro.core.distributed import dist_forward_project
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.plan import choose_reduction, hier_group_size, plan
from repro.core.splitting import MemoryModel
from repro.core.streaming import stream_backward, stream_forward

KIB = 1024

# (voxel shape, n_angles, budget KiB): even, odd, prime-ish odd
GRID = [((32, 32, 32), 12, 48),
        ((18, 24, 24), 10, 40),
        ((20, 25, 25), 9, 36)]


def _case(shape, na, kib):
    geo = ConeGeometry.nice(32).with_voxels(shape)
    angles = circular_angles(na)
    mem = MemoryModel(device_bytes=kib * KIB, usable_fraction=1.0)
    rng = np.random.default_rng(hash(shape) % 1000)
    vol = rng.standard_normal(geo.n_voxel).astype(np.float32)
    proj = rng.standard_normal((na,) + geo.n_detector).astype(np.float32)
    return geo, angles, mem, vol, proj


# --------------------------------------------------------------------------
# schedule structure + cost model
# --------------------------------------------------------------------------

def test_schedule_structure_and_describe():
    geo, angles, mem, _, _ = _case(*GRID[0])
    p = plan(geo, len(angles), 1, mem, angle_chunk_fp=4, angle_chunk_bp=4)
    c = p.comm
    assert c.prefetch_depth == 1 and c.n_buffers == 2
    assert p.streams and not c.bp_chunk_reuse      # 3 chunks > 2 buffers
    # every step kind appears; compute steps reference staged slabs only
    kinds = {s.kind for s in c.fp_steps} | {s.kind for s in c.bp_steps}
    assert kinds == {"h2d", "compute", "d2h"}
    # FP h2d traffic = whole volume once per device; d2h = projections once
    nz, ny, nx = geo.n_voxel
    nv, nu = geo.n_detector
    fp_h2d = sum(s.nbytes for s in c.fp_steps if s.kind == "h2d")
    fp_d2h = sum(s.nbytes for s in c.fp_steps if s.kind == "d2h")
    assert fp_h2d == nz * ny * nx * 4
    assert fp_d2h == len(angles) * nv * nu * 4
    # a deeper schedule marks the lookahead stages as prefetch
    deep = p.with_prefetch(3).comm
    assert deep.n_buffers == 4
    assert any(s.prefetch for s in deep.fp_steps)
    assert not any(s.prefetch for s in p.with_prefetch(0).comm.fp_steps)
    d = c.describe()
    assert "CommSchedule" in d and "fp:" in d and "bp:" in d
    assert "ExecutionPlan" in p.describe() and "reduce=" in p.describe()


def test_bp_chunk_reuse_drops_restage_traffic():
    geo, angles, _, _, _ = _case(*GRID[0])
    # 150 KiB: the volume still splits (3 slabs) but the whole 12-angle
    # projection set fits one resident chunk
    mem = MemoryModel(device_bytes=150 * KIB, usable_fraction=1.0)
    p = plan(geo, len(angles), 1, mem, angle_chunk_fp=4, angle_chunk_bp=32)
    c = p.comm
    assert c.bp_chunk_reuse
    n_slabs = p.backward.n_slabs
    assert n_slabs > 1
    h2d = [s for s in c.bp_steps if s.kind == "h2d"]
    assert len(h2d) == 1        # staged once, reused by every later slab
    # the no-reuse schedule re-stages per slab
    p4 = plan(geo, len(angles), 1, mem, angle_chunk_fp=4, angle_chunk_bp=4)
    assert not p4.comm.bp_chunk_reuse
    assert len([s for s in p4.comm.bp_steps if s.kind == "h2d"]) > 1


def test_transfer_seconds_cost_model():
    geo, angles, mem, _, _ = _case(*GRID[0])
    p = plan(geo, len(angles), 1, mem, angle_chunk_fp=4, angle_chunk_bp=4)
    c = p.comm
    assert c.bytes_moved() == c.bytes_moved("fp") + c.bytes_moved("bp")
    # single device: all bytes on one lane
    assert c.transfer_seconds(1e6) == pytest.approx(c.bytes_moved() / 1e6)
    with pytest.raises(ValueError, match="positive"):
        c.transfer_seconds(0.0)
    # two devices split the FP d2h + BP slab traffic: busiest-lane time
    # is strictly less than the single-device serialization
    p2 = plan(geo, len(angles), 2, mem, angle_chunk_fp=4, angle_chunk_bp=4)
    assert p2.comm.transfer_seconds(1e6) < c.transfer_seconds(1e6)


def test_reduction_tree_selection():
    assert choose_reduction(1) == "psum" and choose_reduction(2) == "psum"
    assert choose_reduction(3) == "ring" and choose_reduction(7) == "ring"
    assert choose_reduction(4) == "hier" and choose_reduction(6) == "hier"
    assert hier_group_size(4) == 2 and hier_group_size(9) == 3
    assert hier_group_size(12) == 3 and hier_group_size(5) == 1


# --------------------------------------------------------------------------
# plan() memoization round-trips the schedule
# --------------------------------------------------------------------------

def test_plan_memo_roundtrips_comm_fields():
    geo, angles, mem, _, _ = _case(*GRID[1])
    p1 = plan(geo, len(angles), 1, mem)
    assert p1 is plan(geo, len(angles), 1, mem)        # same-args identity
    p2 = plan(geo, len(angles), 1, mem, prefetch_depth=2)
    assert p2 is not p1                                # distinct memo entry
    assert p2.comm.prefetch_depth == 2 and p2.comm.n_buffers == 3
    assert p1.comm.prefetch_depth == 1                 # default untouched
    assert p2 is plan(geo, len(angles), 1, mem, prefetch_depth=2)
    # with_prefetch derives the same schedule the memo would build
    assert (p1.with_prefetch(2).comm.fp_steps == p2.comm.fp_steps)
    assert (p1.with_prefetch(2).comm.bp_steps == p2.comm.bp_steps)


# --------------------------------------------------------------------------
# overlap executors == serial no-prefetch reference (bit-identical)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape,na,kib", GRID)
def test_stream_overlap_bit_identical_ref(shape, na, kib):
    geo, angles, mem, vol, proj = _case(shape, na, kib)
    p = plan(geo, na, 1, mem, angle_chunk_fp=4, angle_chunk_bp=4)
    serial = p.with_prefetch(0)
    fp0 = stream_forward(vol, geo, angles, serial)
    bp0 = stream_backward(proj, geo, angles, serial, weight="fdk")
    bpm0 = stream_backward(proj, geo, angles, serial, weight="matched")
    for depth in (1, 3):
        pd = p.with_prefetch(depth)
        np.testing.assert_array_equal(
            fp0, stream_forward(vol, geo, angles, pd))
        np.testing.assert_array_equal(
            bp0, stream_backward(proj, geo, angles, pd, weight="fdk"))
        np.testing.assert_array_equal(
            bpm0, stream_backward(proj, geo, angles, pd, weight="matched"))


def test_stream_backward_angle_subset_rebuilds_steps():
    """A caller may backproject a *subset* of the plan's angles through
    the same memoized plan (OS-SART builds per-subset norm factors this
    way).  The interpreter must rebuild the step list for the angles
    actually passed instead of indexing chunks that do not exist."""
    geo, angles, mem, vol, proj = _case(*GRID[0])
    na = len(angles)
    p = plan(geo, na, 1, mem, angle_chunk_fp=4, angle_chunk_bp=4)
    sub = np.arange(0, na, 3)          # 4 of 12 angles -> fewer chunks
    want = stream_backward(proj[sub], geo, angles[sub],
                           p.backward, weight="fdk")
    got = stream_backward(proj[sub], geo, angles[sub], p, weight="fdk")
    np.testing.assert_array_equal(want, got)


def test_bp_subset_rebuild_counted_full_set_reuses_schedule(monkeypatch):
    """The rebuild is surgical: a full-set backprojection through the
    memoized plan executes the stored schedule verbatim (zero
    ``_bp_comm_steps`` calls), a subset rebuilds exactly once per call —
    for the angle count actually passed, at the plan's prefetch depth."""
    import repro.core.streaming as streaming

    geo, angles, mem, vol, proj = _case(*GRID[0])
    na = len(angles)
    p = plan(geo, na, 1, mem, angle_chunk_fp=4, angle_chunk_bp=4)
    sub = np.arange(0, na, 3)
    want = stream_backward(proj[sub], geo, angles[sub],
                           p.backward, weight="fdk")   # before counting
    calls = []
    orig = streaming._bp_comm_steps

    def counted(bp, g, n_ang, depth):
        calls.append((n_ang, depth))
        return orig(bp, g, n_ang, depth)

    monkeypatch.setattr(streaming, "_bp_comm_steps", counted)
    stream_backward(proj, geo, angles, p, weight="fdk")
    assert calls == []                  # memoized schedule reused as-is
    got = stream_backward(proj[sub], geo, angles[sub], p, weight="fdk")
    assert calls == [(len(sub), p.comm.prefetch_depth)]
    np.testing.assert_array_equal(want, got)
    stream_backward(proj[sub], geo, angles[sub], p, weight="fdk")
    assert len(calls) == 2              # per call; nothing mutates the plan


def test_ossart_norm_factors_through_memoized_plan(monkeypatch):
    """OS-SART's per-subset normalisation factors flow angle *subsets*
    through the operator's single memoized ExecutionPlan: the FP side
    streams volume slabs (angle-count agnostic, no rebuild), the BP side
    rebuilds its step list once per subset ``At`` — and the factors are
    bit-identical to the serial no-prefetch schedule, including the
    uneven tail subset."""
    import repro.core.streaming as streaming
    from repro.core.algorithms.sart import _norm_factors
    from repro.core.operator import CTOperator

    geo, angles, mem, _, _ = _case(*GRID[0])
    na = len(angles)
    p = plan(geo, na, 1, mem, angle_chunk_fp=4, angle_chunk_bp=4)
    op = CTOperator(geo, angles, mode="stream", memory=mem, plan=p)
    serial = CTOperator(geo, angles, mode="stream", memory=mem,
                        plan=p.with_prefetch(0))
    subs = op.subset_indices(5)
    assert [len(s) for s in subs] == [5, 5, 2]

    calls = []
    orig = streaming._bp_comm_steps

    def counted(bp, g, n_ang, depth):
        calls.append((n_ang, depth))
        return orig(bp, g, n_ang, depth)

    monkeypatch.setattr(streaming, "_bp_comm_steps", counted)
    for idx in subs:
        W, V = _norm_factors(op, idx)
        W0, V0 = _norm_factors(serial, idx)
        np.testing.assert_array_equal(np.asarray(W), np.asarray(W0))
        np.testing.assert_array_equal(np.asarray(V), np.asarray(V0))
    # one BP rebuild per streamed At, alternating overlap/serial depth
    assert calls == [(5, 1), (5, 0), (5, 1), (5, 0), (2, 1), (2, 0)]


def test_stream_overlap_bit_identical_two_devices():
    geo, angles, mem, vol, proj = _case(*GRID[2])
    devs = jax.local_devices()[:2]
    p = plan(geo, len(angles), 2, mem, angle_chunk_fp=4, angle_chunk_bp=4)
    serial = p.with_prefetch(0)
    fp0 = stream_forward(vol, geo, angles, serial, devices=devs)
    bp0 = stream_backward(proj, geo, angles, serial, weight="fdk",
                          devices=devs)
    np.testing.assert_array_equal(
        fp0, stream_forward(vol, geo, angles, p, devices=devs))
    np.testing.assert_array_equal(
        bp0, stream_backward(proj, geo, angles, p, weight="fdk",
                             devices=devs))


def test_stream_overlap_bit_identical_pallas():
    geo, angles, mem, vol, proj = _case(*GRID[1])
    p = plan(geo, len(angles), 1, mem, angle_chunk_fp=4, angle_chunk_bp=4)
    serial = p.with_prefetch(0)
    fp0 = stream_forward(vol, geo, angles, serial, backend="pallas")
    bp0 = stream_backward(proj, geo, angles, serial, weight="fdk",
                          backend="pallas")
    np.testing.assert_array_equal(
        fp0, stream_forward(vol, geo, angles, p, backend="pallas"))
    np.testing.assert_array_equal(
        bp0, stream_backward(proj, geo, angles, p, weight="fdk",
                             backend="pallas"))


# --------------------------------------------------------------------------
# dominance split: exact vs both-variants baseline, lazy kernel build
# --------------------------------------------------------------------------

def test_dominance_split_matches_both_variants(host_mesh):
    geo = ConeGeometry.nice(32)
    angles = circular_angles(16)       # mixed dominance
    rng = np.random.default_rng(7)
    vol = jnp.asarray(rng.standard_normal(geo.n_voxel).astype(np.float32))
    with host_mesh:
        split = dist_forward_project(host_mesh, geo, backend="pallas")
        both = dist_forward_project(host_mesh, geo, backend="pallas",
                                    dominance_split=False)
        a = np.asarray(split(vol, jnp.asarray(angles)))
        b = np.asarray(both(vol, jnp.asarray(angles)))
    # same kernels on the same shards — the host-level regrouping must
    # not perturb a single bit
    np.testing.assert_array_equal(a, b)


def test_dominance_split_skips_unused_variant(host_mesh):
    """The 2x-FP fix, asserted via dispatch counters: an all-x-dominant
    workload through the non-ref dist FP must never materialise the
    y-dominant kernel variant."""
    geo = ConeGeometry.nice(32)
    rng = np.random.default_rng(3)
    vol = jnp.asarray(rng.standard_normal(geo.n_voxel).astype(np.float32))
    xdom = np.asarray([0.0, 0.1, -0.1, 0.05, 0.2, -0.2, 0.15, -0.05],
                      np.float32)      # all x-dominant
    bk.clear_dispatch_cache()
    with host_mesh:
        fp = dist_forward_project(host_mesh, geo, backend="pallas")
        fp(vol, jnp.asarray(xdom)).block_until_ready()
    fp_keys = [k for k in bk.dispatch_cache_keys() if k[1] == "fp"]
    assert fp_keys, "no FP kernel was built at all"
    assert all(k[3] is True for k in fp_keys), \
        f"unused y-dominant variant was built: {fp_keys}"


def test_dist_reduction_schedules_match(mesh82):
    """ring and hierarchical reduction orders on 4 model shards produce
    the psum baseline's result."""
    geo = ConeGeometry.nice(32)
    angles = circular_angles(8)
    rng = np.random.default_rng(5)
    vol = jnp.asarray(rng.standard_normal(geo.n_voxel).astype(np.float32))
    outs = {}
    with mesh82:
        for r in ("psum", "ring", "hier"):
            f = dist_forward_project(mesh82, geo, reduce=r, backend="ref")
            outs[r] = np.asarray(f(vol, jnp.asarray(angles)))
    np.testing.assert_allclose(outs["ring"], outs["psum"],
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(outs["hier"], outs["psum"],
                               rtol=1e-6, atol=1e-6)
