"""Data pipeline: exact determinism (the checkpoint-resume invariant),
shard independence, distributional sanity."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data import TokenPipeline, TokenPipelineConfig
from repro.data.tokens import feature_batch


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 3))
def test_batch_deterministic(step, seed):
    cfg = TokenPipelineConfig(vocab=1000, seq_len=64, global_batch=4,
                              seed=seed)
    a = TokenPipeline(cfg).batch(step)
    b = TokenPipeline(cfg).batch(step)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_labels_are_shifted_tokens():
    cfg = TokenPipelineConfig(vocab=1000, seq_len=64, global_batch=4)
    toks, labels = TokenPipeline(cfg).batch(0)
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


def test_steps_differ():
    cfg = TokenPipelineConfig(vocab=1000, seq_len=64, global_batch=4)
    pipe = TokenPipeline(cfg)
    assert not np.array_equal(pipe.batch(0)[0], pipe.batch(1)[0])


def test_shards_differ_and_are_deterministic():
    kw = dict(vocab=1000, seq_len=64, global_batch=8, n_shards=2)
    s0 = TokenPipeline(TokenPipelineConfig(shard=0, **kw))
    s1 = TokenPipeline(TokenPipelineConfig(shard=1, **kw))
    assert s0.cfg.local_batch == 4
    a0, a1 = s0.batch(5)[0], s1.batch(5)[0]
    assert not np.array_equal(a0, a1)
    np.testing.assert_array_equal(
        a0, TokenPipeline(TokenPipelineConfig(shard=0, **kw)).batch(5)[0])


def test_vocab_bounds():
    cfg = TokenPipelineConfig(vocab=100, seq_len=256, global_batch=8)
    toks, labels = TokenPipeline(cfg).batch(0)
    assert toks.min() >= 0 and toks.max() < 100
    assert labels.min() >= 0 and labels.max() < 100


def test_zipf_skew():
    """Low token ids should dominate (Zipf unigram)."""
    cfg = TokenPipelineConfig(vocab=1000, seq_len=512, global_batch=16)
    toks, _ = TokenPipeline(cfg).batch(0)
    assert (toks < 100).mean() > 0.5


def test_feature_batch_deterministic():
    cfg = TokenPipelineConfig(vocab=504, seq_len=32, global_batch=4)
    f1, l1 = feature_batch(cfg, 3, d_model=64)
    f2, l2 = feature_batch(cfg, 3, d_model=64)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(l1, l2)
    assert f1.shape == (4, 32, 64) and l1.max() < 504
