"""shard_map distributed operators == plain operators; halo exchange."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.distributed import (dist_backproject, dist_forward_project,
                                    halo_exchange, pad_angles)
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.projector import backproject_voxel, forward_project

GEO = ConeGeometry.nice(32)
ANGLES = circular_angles(16)


def test_dist_forward_matches_plain(host_mesh):
    vol = jax.random.normal(jax.random.PRNGKey(0), GEO.n_voxel)
    fp = dist_forward_project(host_mesh, GEO)
    with host_mesh:
        got = fp(vol, jnp.asarray(ANGLES))
    want = forward_project(vol, GEO, ANGLES)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dist_forward_ring_schedule(host_mesh):
    vol = jax.random.normal(jax.random.PRNGKey(1), GEO.n_voxel)
    fp = dist_forward_project(host_mesh, GEO, reduce="ring")
    with host_mesh:
        got = fp(vol, jnp.asarray(ANGLES))
    want = forward_project(vol, GEO, ANGLES)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("weight", ["fdk", "none"])
def test_dist_backproject_matches_plain(host_mesh, weight):
    proj = jax.random.normal(jax.random.PRNGKey(2),
                             (len(ANGLES),) + GEO.n_detector)
    bp = dist_backproject(host_mesh, GEO, weight=weight)
    with host_mesh:
        got = bp(proj, jnp.asarray(ANGLES))
    want = backproject_voxel(proj, GEO, jnp.asarray(ANGLES), weight=weight)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_pad_angles():
    a, valid = pad_angles(np.asarray([0.1, 0.2, 0.3], np.float32), 4)
    assert len(a) == 4 and valid.tolist() == [True, True, True, False]
    a2, v2 = pad_angles(np.asarray([0.1, 0.2], np.float32), 2)
    assert len(a2) == 2 and v2.all()


def test_operator_dist_consumes_pad_mask(host_mesh):
    """Regression: a non-divisible angle count through the dist operator
    must match the plain operator — padded duplicate angles must neither
    appear in the forward output nor pollute the backprojection sums."""
    from repro.core.operator import CTOperator
    angles = circular_angles(13)          # 13 % data_axis(4) != 0
    op = CTOperator(GEO, angles, mode="dist", mesh=host_mesh)

    vol = jax.random.normal(jax.random.PRNGKey(5), GEO.n_voxel)
    with host_mesh:
        got_fp = np.asarray(op.A(vol))
    want_fp = np.asarray(forward_project(vol, GEO, angles))
    assert got_fp.shape[0] == len(angles)
    np.testing.assert_allclose(got_fp, want_fp, rtol=1e-4, atol=1e-4)

    proj = jax.random.normal(jax.random.PRNGKey(6),
                             (len(angles),) + GEO.n_detector)
    with host_mesh:
        got_bp = np.asarray(op.At(proj, weight="fdk"))
    want_bp = np.asarray(backproject_voxel(proj, GEO, jnp.asarray(angles),
                                           weight="fdk"))
    np.testing.assert_allclose(got_bp, want_bp, rtol=2e-4, atol=2e-3)


def test_dist_backproject_matched_is_exact_adjoint(host_mesh):
    """The distributed matched BP equals the plain exact (vjp) adjoint, so
    CGLS keeps its guarantees on the dist backend (incl. padded angles)."""
    from repro.core.operator import CTOperator
    angles = circular_angles(13)          # also exercises pad plumbing
    op_d = CTOperator(GEO, angles, mode="dist", mesh=host_mesh)
    op_p = CTOperator(GEO, angles, mode="plain")
    proj = jax.random.normal(jax.random.PRNGKey(7),
                             (len(angles),) + GEO.n_detector)
    with host_mesh:
        got = np.asarray(op_d.At(proj, weight="matched"))
    want = np.asarray(op_p.At(proj, weight="matched"))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_halo_exchange(host_mesh):
    """Each shard's halo == its neighbours' boundary planes; zeros at the
    global ends."""
    n_model = host_mesh.shape["model"]
    planes = 4
    x = jnp.arange(n_model * planes * 2 * 2, dtype=jnp.float32).reshape(
        n_model * planes, 2, 2)

    from jax.sharding import PartitionSpec as P

    def body(xs):
        return halo_exchange(xs, 2, "model")

    from repro.core.compat import shard_map
    fn = jax.jit(shard_map(body, mesh=host_mesh,
                           in_specs=P("model", None, None),
                           out_specs=P("model", None, None),
                           check_vma=False))
    with host_mesh:
        out = np.asarray(fn(x))
    out = out.reshape(n_model, planes + 4, 2, 2)
    xs = np.asarray(x).reshape(n_model, planes, 2, 2)
    for i in range(n_model):
        if i == 0:
            np.testing.assert_array_equal(out[i, :2], 0.0)
        else:
            np.testing.assert_array_equal(out[i, :2], xs[i - 1, -2:])
        np.testing.assert_array_equal(out[i, 2:2 + planes], xs[i])
        if i == n_model - 1:
            np.testing.assert_array_equal(out[i, -2:], 0.0)
        else:
            np.testing.assert_array_equal(out[i, -2:], xs[i + 1, :2])
