"""Documentation must not rot: intra-repo markdown links resolve, and
the fenced examples in README.md / docs/serve.md execute under doctest
(the CI docs job runs the same checks via tools/check_docs.py)."""

import importlib.util
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", ROOT / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_required_docs_exist():
    for rel in ("README.md", "docs/serve.md", "ROADMAP.md"):
        assert (ROOT / rel).is_file(), f"{rel} missing"


def test_markdown_links_resolve():
    assert _check_docs().check_links(ROOT) == []


def test_doc_examples_run_under_doctest():
    assert _check_docs().run_doctests(ROOT) == []
