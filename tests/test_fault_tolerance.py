"""Fault-tolerance: straggler watchdog, heartbeat failure detection,
preemption -> checkpoint -> exact resume (end-to-end), and the serving
layer's crash-point injection matrix: a simulated kill -9 at EVERY
registered write seam (tests/faultpoints.py) across the snapshot /
export / import / drain sequences, after which a disk-only restore must
hold zero-loss — every job present exactly once, no completed iteration
lost, no work double-executed, results bit-identical to an
uninterrupted run."""

import functools
import time

import numpy as np
import pytest

from faultpoints import SimulatedKill, all_points, kill_at
from repro.checkpoint import PreemptionGuard
from repro.core import phantoms
from repro.core.algorithms import cgls
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel
from repro.distributed import Heartbeat, StepWatchdog
from repro.serve import (MultiPodScheduler, Pod, PodSpec, ReconJob,
                         Scheduler, drain_pod)

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(12)
PROJ = phantoms.sphere_projection_analytic(GEO, ANGLES)
KIB = 1024


def _mem(kib=100):
    return MemoryModel(device_bytes=kib * KIB, usable_fraction=1.0)


def _job(n_iter=4):
    return ReconJob("cgls", GEO, ANGLES, PROJ, n_iter=n_iter)


@functools.lru_cache(maxsize=None)
def _ref(n_iter):
    """Uninterrupted single-shot reference the restored runs must match
    bit-for-bit."""
    return np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=n_iter))


def test_watchdog_flags_stragglers():
    dog = StepWatchdog(window=20, threshold=3.0, min_steps=5)
    for _ in range(10):
        assert not dog.observe(0.10)
    assert dog.observe(0.50)                   # 5x the median
    assert dog.stragglers == [10]
    assert not dog.observe(0.11)               # normal again


def test_watchdog_baseline_not_poisoned():
    dog = StepWatchdog(window=20, threshold=3.0, min_steps=5)
    for _ in range(8):
        dog.observe(0.1)
    for _ in range(3):
        dog.observe(2.0)                       # stragglers excluded
    assert dog.observe(2.0)                    # still flagged


def test_heartbeat_dead_host(tmp_path):
    hb0 = Heartbeat(str(tmp_path), host_id=0, timeout=0.2)
    hb1 = Heartbeat(str(tmp_path), host_id=1, timeout=0.2)
    hb0.beat(0)
    hb1.beat(0)
    assert hb0.dead_hosts(2) == []
    now = time.time() + 1.0                    # 1s later, no beats
    assert hb0.dead_hosts(2, now=now) == [0, 1]
    time.sleep(0.25)                           # host 1 goes silent
    hb0.beat(1)                                # host 0 keeps beating
    assert hb0.dead_hosts(2, now=time.time()) == [1]
    # host 2 never registered
    assert 2 in hb0.dead_hosts(3)


def test_preemption_guard_manual_trigger():
    g = PreemptionGuard(install_handler=False)
    assert not g.preempted
    g.trigger()
    assert g.preempted


@pytest.mark.slow
def test_preempt_checkpoint_resume_exact(tmp_path):
    """Kill training via the preemption guard at step k, restart, and check
    the resumed run produces the SAME losses as an uninterrupted run --
    exact resume = deterministic data + committed checkpoint."""
    from repro.launch.train import train

    ckpt = str(tmp_path / "ckpt")
    # uninterrupted reference
    _, _, ref_losses = train("stablelm-1.6b", steps=6, batch=2, seq=32,
                             ckpt_dir=None, verbose=False)

    class TriggerAt(PreemptionGuard):
        def __init__(self, at):
            super().__init__(install_handler=False)
            self.at = at
            self.count = 0

        @property
        def preempted(self):
            self.count += 1
            return self.count > self.at

    # run 1: preempted partway (checkpoints every 3 anyway)
    _, _, losses1 = train("stablelm-1.6b", steps=6, batch=2, seq=32,
                          ckpt_dir=ckpt, ckpt_every=3, verbose=False,
                          guard=TriggerAt(4))
    assert len(losses1) < 6
    # run 2: resumes from the committed checkpoint and finishes
    _, _, losses2 = train("stablelm-1.6b", steps=6, batch=2, seq=32,
                          ckpt_dir=ckpt, ckpt_every=3, verbose=False)
    combined = losses1[:len(losses1)] + losses2
    # the resumed tail must match the uninterrupted run's tail exactly-ish
    np.testing.assert_allclose(combined[-len(losses2):],
                               ref_losses[-len(losses2):], rtol=1e-4)


# --------------------------------------------------------------------------
# crash-point injection matrix (tests/faultpoints.py)
#
# Each phase test arms one registered (seam, when) crash site, runs the
# phase's durable operation until the simulated kill lands (or the
# operation completes — a seam the sequence never reaches is the
# crash-free row of the same matrix), then THROWS AWAY every live object
# and rebuilds purely from disk.  The invariants are identical across
# the whole matrix:
#
#   * every submitted job is restored exactly once (none lost, none
#     duplicated onto two pods),
#   * no completed iteration is lost: restored progress >= the progress
#     the last clean snapshot had durably committed,
#   * no work is double-executed: progress never exceeds what had
#     actually run,
#   * the restored fleet finishes every job bit-identically to an
#     uninterrupted single-shot run.
# --------------------------------------------------------------------------

_IDS = [p.name for p in all_points()]


def _run_killed(point, op):
    """Run ``op`` with ``point`` armed; the simulated kill (if the seam
    is reached) is the process dying mid-write."""
    with kill_at(point):
        try:
            op()
        except SimulatedKill:
            pass


@pytest.mark.parametrize("point", all_points(), ids=_IDS)
def test_crash_matrix_snapshot(tmp_path, point):
    """Kill inside a periodic snapshot (running jobs included): the
    previous committed snapshot must survive intact."""
    snap = str(tmp_path / "snap")
    sched = Scheduler(n_devices=1, memory=_mem(220), snapshot_dir=snap)
    jobs = [sched.submit(_job(n_iter=4)) for _ in range(2)]
    sched.step_quantum()                      # admit + first iterations
    baseline = {j: sched.records[j].iterations_done for j in jobs}
    assert sched.snapshot(snap) >= 1          # clean durable baseline
    sched.step_quantum()                      # progress past the baseline
    _run_killed(point, lambda: sched.snapshot(snap))
    ran = {j: sched.records[j].iterations_done for j in jobs}

    fresh = Scheduler(n_devices=1, memory=_mem(220))
    assert fresh.restore(snap) == len(jobs)
    for j in jobs:
        got = fresh.records[j].iterations_done
        assert baseline[j] <= got <= ran[j]   # zero loss, zero replay
    fresh.run()
    for j in jobs:
        np.testing.assert_array_equal(fresh.result(j), _ref(4))


def _fleet(tmp_path, n_iter=4):
    """Two-pod fleet with durable snapshots: job 0 running on the victim
    (one quantum of progress), job 1 parked there, both committed to
    disk by a clean fleet snapshot."""
    root = str(tmp_path / "fleet")
    transfer = str(tmp_path / "transfer")
    mps = MultiPodScheduler(
        [Pod(PodSpec("v", n_devices=1, memory=_mem())),
         Pod(PodSpec("t", n_devices=1, memory=_mem()))],
        steal=False, transfer_dir=transfer, snapshot_root=root)
    jobs = [mps.submit(_job(n_iter), pod="v") for _ in range(2)]
    vict = next(p for p in mps.pods if p.name == "v")
    thief = next(p for p in mps.pods if p.name == "t")
    vict.scheduler.step_quantum()
    assert mps.snapshot_fleet() == len(jobs)
    return mps, root, transfer, vict, thief, jobs


def _check_fleet_recovery(tmp_path, root, transfer, jobs, baseline, ran,
                          n_iter=4):
    """Disk-only rebuild + the matrix invariants."""
    mps2 = MultiPodScheduler.restore_fleet(root, transfer_dir=transfer)
    for j in jobs:
        owners = [p.name for p in mps2.pods if j in p.scheduler.records]
        assert len(owners) == 1, \
            f"job {j} restored on {owners or 'no pod'}"
        got = mps2.record(j).iterations_done
        assert baseline[j] <= got <= ran[j]
    mps2.run()
    for j in jobs:
        np.testing.assert_array_equal(mps2.result(j), _ref(n_iter))


@pytest.mark.parametrize("point", all_points(), ids=_IDS)
def test_crash_matrix_export(tmp_path, point):
    """Kill inside the victim's export half of a steal: the job must
    come back exactly once — from the victim's snapshot (hand-off never
    durably left) or from the transfer copy (it did)."""
    mps, root, transfer, vict, thief, jobs = _fleet(tmp_path)
    baseline = {j: mps.record(j).iterations_done for j in jobs}
    ran = dict(baseline)
    _run_killed(point,
                lambda: vict.scheduler.export_job(jobs[1], transfer))
    _check_fleet_recovery(tmp_path, root, transfer, jobs, baseline, ran)


@pytest.mark.parametrize("point", all_points(), ids=_IDS)
def test_crash_matrix_import(tmp_path, point):
    """Kill inside the thief's import half (after a clean export): the
    orphaned transfer copy must be re-adopted, a half-consumed one must
    not resurrect a duplicate."""
    mps, root, transfer, vict, thief, jobs = _fleet(tmp_path)
    baseline = {j: mps.record(j).iterations_done for j in jobs}
    ran = dict(baseline)
    assert vict.scheduler.export_job(jobs[1], transfer)
    _run_killed(point,
                lambda: thief.scheduler.import_job(transfer, jobs[1]))
    _check_fleet_recovery(tmp_path, root, transfer, jobs, baseline, ran)


@pytest.mark.parametrize("point", all_points(), ids=_IDS)
def test_crash_matrix_drain(tmp_path, point):
    """Kill inside a scale-down drain (preempt -> export -> import per
    job): every job lands exactly once whether it had moved, was on the
    wire, or never left."""
    mps, root, transfer, vict, thief, jobs = _fleet(tmp_path)
    baseline = {j: mps.record(j).iterations_done for j in jobs}
    ran = dict(baseline)
    _run_killed(point, lambda: drain_pod(vict, [thief], transfer,
                                         timeout=30.0))
    _check_fleet_recovery(tmp_path, root, transfer, jobs, baseline, ran)
