"""Fault-tolerance: straggler watchdog, heartbeat failure detection,
preemption -> checkpoint -> exact resume (end-to-end)."""

import time

import numpy as np
import pytest

from repro.checkpoint import PreemptionGuard
from repro.distributed import Heartbeat, StepWatchdog


def test_watchdog_flags_stragglers():
    dog = StepWatchdog(window=20, threshold=3.0, min_steps=5)
    for _ in range(10):
        assert not dog.observe(0.10)
    assert dog.observe(0.50)                   # 5x the median
    assert dog.stragglers == [10]
    assert not dog.observe(0.11)               # normal again


def test_watchdog_baseline_not_poisoned():
    dog = StepWatchdog(window=20, threshold=3.0, min_steps=5)
    for _ in range(8):
        dog.observe(0.1)
    for _ in range(3):
        dog.observe(2.0)                       # stragglers excluded
    assert dog.observe(2.0)                    # still flagged


def test_heartbeat_dead_host(tmp_path):
    hb0 = Heartbeat(str(tmp_path), host_id=0, timeout=0.2)
    hb1 = Heartbeat(str(tmp_path), host_id=1, timeout=0.2)
    hb0.beat(0)
    hb1.beat(0)
    assert hb0.dead_hosts(2) == []
    now = time.time() + 1.0                    # 1s later, no beats
    assert hb0.dead_hosts(2, now=now) == [0, 1]
    time.sleep(0.25)                           # host 1 goes silent
    hb0.beat(1)                                # host 0 keeps beating
    assert hb0.dead_hosts(2, now=time.time()) == [1]
    # host 2 never registered
    assert 2 in hb0.dead_hosts(3)


def test_preemption_guard_manual_trigger():
    g = PreemptionGuard(install_handler=False)
    assert not g.preempted
    g.trigger()
    assert g.preempted


@pytest.mark.slow
def test_preempt_checkpoint_resume_exact(tmp_path):
    """Kill training via the preemption guard at step k, restart, and check
    the resumed run produces the SAME losses as an uninterrupted run --
    exact resume = deterministic data + committed checkpoint."""
    from repro.launch.train import train

    ckpt = str(tmp_path / "ckpt")
    # uninterrupted reference
    _, _, ref_losses = train("stablelm-1.6b", steps=6, batch=2, seq=32,
                             ckpt_dir=None, verbose=False)

    class TriggerAt(PreemptionGuard):
        def __init__(self, at):
            super().__init__(install_handler=False)
            self.at = at
            self.count = 0

        @property
        def preempted(self):
            self.count += 1
            return self.count > self.at

    # run 1: preempted partway (checkpoints every 3 anyway)
    _, _, losses1 = train("stablelm-1.6b", steps=6, batch=2, seq=32,
                          ckpt_dir=ckpt, ckpt_every=3, verbose=False,
                          guard=TriggerAt(4))
    assert len(losses1) < 6
    # run 2: resumes from the committed checkpoint and finishes
    _, _, losses2 = train("stablelm-1.6b", steps=6, batch=2, seq=32,
                          ckpt_dir=ckpt, ckpt_every=3, verbose=False)
    combined = losses1[:len(losses1)] + losses2
    # the resumed tail must match the uninterrupted run's tail exactly-ish
    np.testing.assert_allclose(combined[-len(losses2):],
                               ref_losses[-len(losses2):], rtol=1e-4)
