"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Every Pallas kernel is validated over a sweep of shapes and dtypes; the
fp/bp kernels also over geometry variations.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.geometry import ConeGeometry, circular_angles, \
    dominant_axis_mask
from repro.kernels import ref
from repro.kernels.bp_voxel import bp_voxel_pallas
from repro.kernels.fp_ray import fp_ray_pallas
from repro.kernels.tv_grad import tv_grad_pallas
from repro.kernels.flash_attention import flash_attention


def _xdom_angles(n):
    a = circular_angles(n)
    return a[np.nonzero(dominant_axis_mask(a))[0]]


@pytest.mark.parametrize("n,slab", [(16, 4), (32, 8), (32, 16), (48, 8)])
def test_fp_ray_shapes(n, slab):
    geo = ConeGeometry.nice(n)
    ax = _xdom_angles(8)
    vol = jax.random.normal(jax.random.PRNGKey(n), geo.n_voxel, jnp.float32)
    got = fp_ray_pallas(vol, geo, ax, slab_planes=slab, interpret=True)
    want = ref.fp_ray_ref(vol, geo, ax)
    # atol covers volume-boundary rays (one interpolation tap outside)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=5e-3)


@pytest.mark.parametrize("nv,nu", [(16, 32), (32, 16)])
def test_fp_ray_rect_detector(nv, nu):
    geo = ConeGeometry.nice(32, n_detector=(nv, nu))
    ax = _xdom_angles(4)
    vol = jax.random.normal(jax.random.PRNGKey(1), geo.n_voxel, jnp.float32)
    got = fp_ray_pallas(vol, geo, ax, slab_planes=8, interpret=True)
    want = ref.fp_ray_ref(vol, geo, ax)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("n,zb,ac", [(16, 4, 4), (32, 8, 4), (32, 16, 8)])
@pytest.mark.parametrize("weight", ["fdk", "pmatched", "none"])
def test_bp_voxel_shapes(n, zb, ac, weight):
    geo = ConeGeometry.nice(n)
    angles = circular_angles(8)
    proj = jax.random.normal(jax.random.PRNGKey(n), (8,) + geo.n_detector,
                             jnp.float32)
    got = bp_voxel_pallas(proj, geo, angles, z_block=zb, angle_chunk=ac,
                          weight=weight, interpret=True)
    want = ref.bp_voxel_ref(proj, geo, angles, weight=weight)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("shape", [(16, 16, 16), (32, 16, 24), (48, 8, 8)])
@pytest.mark.parametrize("zb", [4, 8])
def test_tv_grad_shapes(shape, zb):
    if shape[0] % zb:
        pytest.skip("nz % zb != 0")
    vol = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    got = tv_grad_pallas(vol, z_block=zb, interpret=True)
    want = ref.tv_grad_ref(vol)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 4, 128, 32), (2, 8, 2, 256, 64), (1, 8, 1, 128, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(b, hq, hkv, s, d, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window,softcap", [(64, None), (None, 30.0),
                                            (64, 30.0)])
def test_flash_attention_window_softcap(window, softcap):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 2, 256, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 2, 256, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          softcap=softcap, block_q=64, block_kv=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 4, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 4, 128, 64), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4))
def test_fp_slab_split_matches_kernel(seed, n_splits):
    """Hypothesis: the Pallas FP kernel's grid accumulation over marching
    slabs equals the oracle regardless of slab count."""
    n = 24
    geo = ConeGeometry.nice(n)
    ax = _xdom_angles(4)
    slab = n // n_splits if n % n_splits == 0 else n
    if n % slab:
        slab = n
    vol = jax.random.normal(jax.random.PRNGKey(seed), geo.n_voxel,
                            jnp.float32)
    got = fp_ray_pallas(vol, geo, ax, slab_planes=slab, interpret=True)
    want = ref.fp_ray_ref(vol, geo, ax)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)
