"""Per-arch smoke tests (deliverable f): reduced configs, one forward +
one train step on CPU, asserting shapes and no NaNs; decode==forward
consistency in fp32."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, cell_skip_reason, get_config, reduced
from repro.models.lm import make_model

B, S = 2, 32
KEY = jax.random.PRNGKey(0)

# tier-1 keeps one cheap representative arch; the full matrix runs with
# ``-m slow`` (large reduced configs dominate the suite's wall-clock)
_FAST_ARCHS = ("stablelm-1.6b",)


def _arch_params(names):
    return [n if n in _FAST_ARCHS
            else pytest.param(n, marks=pytest.mark.slow) for n in names]


def _inputs(cfg, key=KEY, b=B, s=S):
    if cfg.encoder_only or cfg.family == "audio":
        tokens = jax.random.normal(key, (b, s, cfg.d_model), cfg.dtype)
    else:
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab)
    ctx = (jax.random.normal(key, (b, cfg.n_ctx_tokens, cfg.d_model),
                             cfg.dtype) if cfg.family == "vlm" else None)
    return tokens, labels, ctx


@pytest.mark.parametrize("name", _arch_params(ARCH_NAMES))
def test_arch_forward_smoke(name):
    cfg = reduced(name)
    model = make_model(cfg)
    p = model.init(KEY)
    tokens, labels, ctx = _inputs(cfg)
    hidden, _, aux = model.forward(p, tokens, ctx=ctx)
    assert hidden.shape == (B, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(hidden)))
    logits = model.logits(p, hidden)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("name", _arch_params(ARCH_NAMES))
def test_arch_train_step_smoke(name):
    """One real SGD step decreases nothing catastrophic: loss finite,
    grads finite, params updated."""
    cfg = reduced(name)
    model = make_model(cfg)
    p = model.init(KEY)
    tokens, labels, ctx = _inputs(cfg)
    loss, grads = jax.value_and_grad(
        lambda pp: model.loss(pp, tokens, labels, ctx=ctx))(p)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in leaves)
    gnorm = float(jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                               for g in leaves)))
    assert gnorm > 0


@pytest.mark.parametrize("name", _arch_params(
    [n for n in ARCH_NAMES if not get_config(n).encoder_only]))
def test_arch_decode_matches_forward_fp32(name):
    cfg = dataclasses.replace(reduced(name), dtype=jnp.float32)
    model = make_model(cfg)
    p = model.init(KEY)
    s = 12
    tokens, _, ctx = _inputs(cfg, s=s)
    hidden, _, _ = model.forward(p, tokens, ctx=ctx, remat=False)
    want = model.logits(p, hidden)
    caches = model.init_cache(B, s)
    dec = jax.jit(model.decode_step)
    for t in range(s):
        got, caches = dec(p, tokens[:, t:t + 1], jnp.asarray(t, jnp.int32),
                          caches, ctx=ctx)
        np.testing.assert_allclose(got[:, 0], want[:, t], rtol=1e-3,
                                   atol=1e-4)


def test_ring_cache_equals_full_cache_for_window_layer():
    """gemma2 local layers: decoding past the window with the ring cache
    gives the same logits as a full cache (the ring only drops positions
    the mask excludes anyway)."""
    cfg = dataclasses.replace(reduced("gemma2-9b"), dtype=jnp.float32,
                              window=8)
    model = make_model(cfg)
    p = model.init(KEY)
    s = 24
    tokens = jax.random.randint(KEY, (B, s), 0, cfg.vocab)
    hidden, _, _ = model.forward(p, tokens, remat=False)
    want = model.logits(p, hidden)
    caches = model.init_cache(B, s)        # local layers get ring size 8
    dec = jax.jit(model.decode_step)
    for t in range(s):
        got, caches = dec(p, tokens[:, t:t + 1], jnp.asarray(t, jnp.int32),
                          caches)
        np.testing.assert_allclose(got[:, 0], want[:, t], rtol=1e-3,
                                   atol=1e-4)


def test_unroll_matches_scan():
    cfg = dataclasses.replace(reduced("codeqwen1.5-7b"), dtype=jnp.float32)
    model = make_model(cfg)
    p = model.init(KEY)
    tokens, labels, _ = _inputs(cfg)
    l_scan = model.loss(p, tokens, labels, unroll=False)
    l_unroll = model.loss(p, tokens, labels, unroll=True)
    np.testing.assert_allclose(float(l_scan), float(l_unroll), rtol=1e-5)


def test_cell_skips_documented():
    skips = []
    for name in ARCH_NAMES:
        cfg = get_config(name)
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if cell_skip_reason(cfg, shape):
                skips.append((name, shape))
    # encoder-only: hubert decode+long; long_500k for all but zamba2/xlstm
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    assert ("zamba2-7b", "long_500k") not in skips
    assert ("xlstm-350m", "long_500k") not in skips
    assert len(skips) == 9


def test_full_configs_match_assignment():
    """The published numbers from the assignment table."""
    c = get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab,
            c.ssm_state) == (81, 3584, 32, 32, 14336, 32000, 64)
    c = get_config("gemma2-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (42, 3584, 16, 8, 14336, 256000)
    c = get_config("codeqwen1.5-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (32, 4096, 32, 32, 13440, 92416)
    c = get_config("stablelm-1.6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (24, 2048, 32, 32, 5632, 100352)
    c = get_config("minicpm3-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (62, 2560, 40, 6400, 73448)
    c = get_config("hubert-xlarge")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (48, 1280, 16, 5120, 504)
    c = get_config("llama-3.2-vision-11b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (40, 4096, 32, 8, 14336, 128256)
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.n_layers, c.d_model, c.vocab, c.n_experts, c.top_k,
            c.d_expert) == (48, 2048, 163840, 64, 6, 1408)
    c = get_config("deepseek-moe-16b")
    assert (c.n_layers, c.d_model, c.vocab, c.n_experts, c.top_k,
            c.n_shared, c.d_expert) == (28, 2048, 102400, 64, 6, 2, 1408)
    c = get_config("xlstm-350m")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == \
        (24, 1024, 4, 50304)


def test_pattern_layer_counts():
    for name in ARCH_NAMES:
        cfg = get_config(name)
        assert len(cfg.prelude) + cfg.n_repeats * len(cfg.pattern) == \
            cfg.n_layers, name
