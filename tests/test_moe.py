"""MoE layer invariants (hypothesis): gate normalisation, capacity
behaviour, dispatch/combine consistency, aux loss."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.common import ShardingRules
from repro.models.moe import MoEConfig, init_moe, moe_fwd

RULES = ShardingRules()


def _cfg(**kw):
    base = dict(d_model=32, d_expert=16, n_experts=8, top_k=2, n_shared=0)
    base.update(kw)
    return MoEConfig(**base)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_moe_finite_and_shaped(seed):
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, 32))
    out, aux = moe_fwd(p, x, cfg, RULES)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) >= 0


def test_moe_no_drop_when_capacity_ample():
    """With capacity >= T every token gets exactly its top-k gates; the
    output must equal the dense per-token mixture computed by hand."""
    cfg = _cfg(capacity_factor=100.0)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    out, _ = moe_fwd(p, x, cfg, RULES)

    xt = x.reshape(-1, 32)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((32,))
        for j in range(cfg.top_k):
            e = int(ei[t, j])
            h = jax.nn.silu(xt[t] @ p["w_gate"][e]) * (xt[t] @ p["w_up"][e])
            acc = acc + gv[t, j] * (h @ p["w_down"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(out.reshape(-1, 32), want, rtol=2e-4,
                               atol=2e-5)


def test_moe_shared_experts_always_on():
    """Zeroing the router must leave exactly the shared-expert output."""
    cfg = _cfg(n_shared=2)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # make routed experts output zero by zeroing w_down
    p = dict(p)
    p["w_down"] = jnp.zeros_like(p["w_down"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 32))
    out, _ = moe_fwd(p, x, cfg, RULES)
    from repro.models.ffn import ffn_fwd
    want = ffn_fwd(p["shared"], x.reshape(1, -1, 32), cfg.shared_cfg,
                   RULES)[0].reshape(2, 4, 32)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_overflow():
    """With capacity 1 slot/expert and concentrated routing, most tokens
    drop -> output norm much smaller than ample-capacity output."""
    cfg = _cfg(capacity_factor=1e-9)       # floor gives min(t, 64)=t ... so
    # force tiny capacity via many tokens: t=128, floor min(128,64)=64 >
    # statistical; instead compare 2 slots vs full
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.broadcast_to(jax.random.normal(jax.random.PRNGKey(1), (1, 1, 32)),
                         (1, 128, 32))     # identical tokens -> same expert
    out_small, _ = moe_fwd(p, x, cfg, RULES)
    cfg_big = _cfg(capacity_factor=100.0)
    out_big, _ = moe_fwd(p, x, cfg_big, RULES)
    # identical tokens all route to the same experts; with 64-slot floor
    # half of the 128 drop
    n_small = float(jnp.linalg.norm(out_small))
    n_big = float(jnp.linalg.norm(out_big))
    assert n_small < n_big


def test_moe_aux_loss_balanced_vs_skewed():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64, 32))
    _, aux_rand = moe_fwd(p, x, cfg, RULES)
    x_same = jnp.broadcast_to(x[:1, :1], (4, 64, 32))
    _, aux_skew = moe_fwd(p, x_same, cfg, RULES)
    assert float(aux_skew) > float(aux_rand)
